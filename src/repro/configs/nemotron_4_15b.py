"""nemotron-4-15b: 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 —
GQA, squared-ReLU MLP [arXiv:2402.16819; unverified]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=24576, vocab=256000,
    activation="squared_relu")


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=96, n_heads=6,
                               n_kv_heads=2, d_ff=256, vocab=512)
