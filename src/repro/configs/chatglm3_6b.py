"""chatglm3-6b: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 —
2d RoPE (half-dim rotary), GQA [arXiv:2406.12793; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024,
    activation="swiglu", rope_fraction=0.5)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=160, vocab=128)
