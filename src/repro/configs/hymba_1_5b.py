"""hymba-1.5b: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads in every block
[arXiv:2411.13676; hf]. Sliding-window attention (global-attention layers
of the paper are approximated as windowed; see DESIGN.md) makes the arch
sub-quadratic, so the long_500k cell runs."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001, head_dim=64,
    activation="swiglu", hybrid_parallel=True, sliding_window=1024,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=128, sliding_window=32,
        ssm_state=8, ssm_head_dim=16, ssm_chunk=16)
