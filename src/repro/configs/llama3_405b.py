"""llama3-405b: 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256 —
GQA, 128k vocab [arXiv:2407.21783; unverified]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256,
    activation="swiglu", rope_theta=500000.0)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=128, n_heads=8,
                               n_kv_heads=2, d_ff=384, vocab=256)
