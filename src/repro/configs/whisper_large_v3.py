"""whisper-large-v3: 32L d_model=1280 20H d_ff=5120 vocab=51866 — encoder-
decoder; the conv/mel frontend is a STUB: input_specs provides precomputed
frame embeddings (B, 1500, d_model) [arXiv:2212.04356; unverified].
long_500k is skipped (full attention, enc-dec)."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio", n_layers=32, d_model=1280,
    n_heads=20, n_kv_heads=20, d_ff=5120, vocab=51866,
    activation="gelu", rope_fraction=0.0, enc_dec=True, enc_layers=32,
    enc_frames=1500)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab=128,
                               enc_layers=2, enc_frames=16,
                               dec_positions=256)
