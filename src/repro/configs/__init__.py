"""Architecture config registry: --arch <id> resolution."""

import importlib

ARCHS = [
    "granite-3-2b", "chatglm3-6b", "llama3-405b", "nemotron-4-15b",
    "mamba2-130m", "hymba-1.5b", "qwen3-moe-235b-a22b",
    "granite-moe-1b-a400m", "chameleon-34b", "whisper-large-v3",
]


def _module(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str):
    return _module(arch).CONFIG


def get_reduced(arch: str):
    return _module(arch).reduced()
