"""chameleon-34b: 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 —
early-fusion VLM: VQ image tokens are ordinary vocabulary entries, so the
backbone is a dense decoder with qk-norm; the image tokenizer frontend is a
stub (input_specs provides token ids) [arXiv:2405.09818; unverified]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536,
    activation="swiglu", qk_norm=True)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=192, vocab=256)
