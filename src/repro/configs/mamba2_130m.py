"""mamba2-130m: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    tie_embeddings=True)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, vocab=128,
                               ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
