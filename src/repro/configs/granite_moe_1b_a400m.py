"""granite-moe-1b-a400m: 24L d_model=1024 16H (GQA kv=8) d_ff=512 (per
expert) vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, d_ff=512, vocab=49155,
    activation="swiglu", n_experts=32, top_k=8, tie_embeddings=True)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=128, n_experts=4, top_k=2, capacity_factor=8.0)
