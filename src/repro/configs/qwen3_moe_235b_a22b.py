"""qwen3-moe-235b-a22b: 94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per
expert) vocab=151936, MoE 128 experts top-8, head_dim=128, qk-norm
[hf:Qwen/Qwen3-30B-A3B; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_ff=1536, vocab=151936, head_dim=128,
    activation="swiglu", qk_norm=True, n_experts=128, top_k=8)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32, vocab=128, n_experts=8, top_k=2, capacity_factor=8.0)
