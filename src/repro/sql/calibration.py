"""Cross-query selectivity calibration (paper section 3.2 follow-on).

The planner's weakest estimates are expression predicates no zone map
can bound — it falls back to a constant selectivity guess, and the
adaptive layer only corrects the damage at the *next* stage barrier.
Recurring predicates deserve better: after a scan pipeline (a pure
``scan → filter… → project…`` chain) completes, the engine records the
*observed* selectivity of its full predicate chain under
``(table, predicate-chain hash)`` in the store's low-latency KV tier —
the same tier the result registry lives in, so calibration spans every
session sharing a store. The next compile of the same predicate seeds
``PhysicalPlanner._est`` with the observation and sizes exchange
fan-outs and fleets correctly *before* any barrier.

Calibration is applied **downward-only** (``min(static, observed)``):
it tightens over-estimates — the direction that wastes money on
over-provisioned fleets — while under-estimates keep the conservative
static figure, preserving the invariant that adaptive fleets never
exceed their statically planned size. Observations are folded with an
exponential moving average so drifting data converges instead of
flapping.
"""

from __future__ import annotations

import hashlib
import json

import msgpack

from repro.storage.object_store import ObjectStore


def predicate_key(pred_dicts: list[dict]) -> str:
    """Stable hash of a predicate chain (serialized expression dicts,
    order-insensitive — filter pushdown may reorder conjuncts)."""
    canon = sorted(json.dumps(p, sort_keys=True, separators=(",", ":"))
                   for p in pred_dicts)
    return hashlib.sha256("\n".join(canon).encode()).hexdigest()[:24]


def scan_filter_signature(op: dict) -> tuple[str, str] | None:
    """(table, predicate key) when ``op`` is a calibratable fragment op
    tree: a pure scan → filter/project chain with at least one filter.
    Anything else (aggregates, joins) changes the output cardinality, so
    its rows-out is not a selectivity observation."""
    preds: list[dict] = []
    cur = op
    while True:
        t = cur.get("t")
        if t == "filter":
            preds.append(cur["pred"])
        elif t == "scan_table":
            return (cur["table"], predicate_key(preds)) if preds else None
        elif t != "project":
            return None
        cur = cur["child"]


class SelectivityCalibration:
    """Persistent per-(table, predicate) selectivity observations."""

    def __init__(self, store: ObjectStore, namespace: str = "calibration",
                 alpha: float = 0.5):
        self.store = store.with_tier("dynamodb")
        self.namespace = namespace
        self.alpha = alpha          # EMA weight of the newest observation

    def _key(self, table: str, pred_key: str) -> str:
        return f"{self.namespace}/{table}/{pred_key}"

    def lookup(self, table: str, pred_key: str) -> float | None:
        try:
            entry = msgpack.unpackb(
                self.store.get(self._key(table, pred_key)).data)
        except (KeyError, FileNotFoundError):
            return None
        return float(entry["sel"])

    def record(self, table: str, pred_key: str, selectivity: float) -> None:
        sel = min(1.0, max(float(selectivity), 1e-4))
        prev = self.lookup(table, pred_key)
        if prev is not None:
            sel = self.alpha * sel + (1.0 - self.alpha) * prev
        self.store.put(self._key(table, pred_key),
                       msgpack.packb({"sel": sel}))
