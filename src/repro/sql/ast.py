"""Expression and statement AST shared by the parser, binder, and planner.

Every node is a frozen dataclass with a canonical ``key()`` serialization,
which the result registry hashes (paper section 3.4: cache identifiers are
computed from the plan after logical optimization).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence


class Expr:
    def key(self) -> Any:
        raise NotImplementedError

    def children(self) -> tuple["Expr", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class Col(Expr):
    name: str

    def key(self):
        return ("col", self.name)


@dataclasses.dataclass(frozen=True)
class Lit(Expr):
    value: Any          # python int/float/str; dates pre-parsed to int days
    kind: str = "num"   # num | str | date

    def key(self):
        return ("lit", self.kind, self.value)


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str             # + - * /
    left: Expr
    right: Expr

    def key(self):
        return ("bin", self.op, self.left.key(), self.right.key())

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class Cmp(Expr):
    op: str             # < <= > >= = <>
    left: Expr
    right: Expr

    def key(self):
        return ("cmp", self.op, self.left.key(), self.right.key())

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class And(Expr):
    terms: tuple[Expr, ...]

    def key(self):
        return ("and",) + tuple(t.key() for t in self.terms)

    def children(self):
        return self.terms


@dataclasses.dataclass(frozen=True)
class Or(Expr):
    terms: tuple[Expr, ...]

    def key(self):
        return ("or",) + tuple(t.key() for t in self.terms)

    def children(self):
        return self.terms


@dataclasses.dataclass(frozen=True)
class Not(Expr):
    term: Expr

    def key(self):
        return ("not", self.term.key())

    def children(self):
        return (self.term,)


@dataclasses.dataclass(frozen=True)
class Case(Expr):
    cond: Expr
    then: Expr
    orelse: Expr

    def key(self):
        return ("case", self.cond.key(), self.then.key(), self.orelse.key())

    def children(self):
        return (self.cond, self.then, self.orelse)


@dataclasses.dataclass(frozen=True)
class InList(Expr):
    term: Expr
    values: tuple[Expr, ...]

    def key(self):
        return ("in", self.term.key()) + tuple(v.key() for v in self.values)

    def children(self):
        return (self.term,) + self.values


@dataclasses.dataclass(frozen=True)
class Between(Expr):
    term: Expr
    lo: Expr
    hi: Expr

    def key(self):
        return ("between", self.term.key(), self.lo.key(), self.hi.key())

    def children(self):
        return (self.term, self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class Like(Expr):
    term: Expr
    pattern: str

    def key(self):
        return ("like", self.term.key(), self.pattern)

    def children(self):
        return (self.term,)


@dataclasses.dataclass(frozen=True)
class Agg(Expr):
    fn: str             # sum | avg | count | min | max
    arg: Expr | None    # None for count(*)

    def key(self):
        return ("agg", self.fn, self.arg.key() if self.arg else None)

    def children(self):
        return (self.arg,) if self.arg is not None else ()


# -- statements ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SelectItem:
    expr: Expr
    alias: str | None


@dataclasses.dataclass(frozen=True)
class JoinClause:
    table: str
    on: Expr            # equality predicate


@dataclasses.dataclass(frozen=True)
class OrderItem:
    expr: Expr
    desc: bool


@dataclasses.dataclass(frozen=True)
class SelectStmt:
    items: tuple[SelectItem, ...]
    tables: tuple[str, ...]
    joins: tuple[JoinClause, ...]
    where: Expr | None
    group_by: tuple[Expr, ...]
    order_by: tuple[OrderItem, ...]
    limit: int | None


def walk(e: Expr):
    yield e
    for c in e.children():
        yield from walk(c)


def collect_columns(e: Expr) -> list[str]:
    return [n.name for n in walk(e) if isinstance(n, Col)]


def collect_aggs(e: Expr) -> list[Agg]:
    out, seen = [], set()
    for n in walk(e):
        if isinstance(n, Agg) and n.key() not in seen:
            seen.add(n.key())
            out.append(n)
    return out


def map_expr(e: Expr, fn) -> Expr:
    """Bottom-up structural rewrite: fn applied to each node after its
    children have been rewritten."""
    if isinstance(e, BinOp):
        e = BinOp(e.op, map_expr(e.left, fn), map_expr(e.right, fn))
    elif isinstance(e, Cmp):
        e = Cmp(e.op, map_expr(e.left, fn), map_expr(e.right, fn))
    elif isinstance(e, And):
        e = And(tuple(map_expr(t, fn) for t in e.terms))
    elif isinstance(e, Or):
        e = Or(tuple(map_expr(t, fn) for t in e.terms))
    elif isinstance(e, Not):
        e = Not(map_expr(e.term, fn))
    elif isinstance(e, Case):
        e = Case(map_expr(e.cond, fn), map_expr(e.then, fn),
                 map_expr(e.orelse, fn))
    elif isinstance(e, InList):
        e = InList(map_expr(e.term, fn),
                   tuple(map_expr(v, fn) for v in e.values))
    elif isinstance(e, Between):
        e = Between(map_expr(e.term, fn), map_expr(e.lo, fn),
                    map_expr(e.hi, fn))
    elif isinstance(e, Like):
        e = Like(map_expr(e.term, fn), e.pattern)
    elif isinstance(e, Agg):
        e = Agg(e.fn, map_expr(e.arg, fn) if e.arg is not None else None)
    return fn(e)


def conjuncts(e: Expr | None) -> list[Expr]:
    if e is None:
        return []
    if isinstance(e, And):
        out = []
        for t in e.terms:
            out.extend(conjuncts(t))
        return out
    return [e]


def make_and(terms: Sequence[Expr]) -> Expr | None:
    terms = list(terms)
    if not terms:
        return None
    if len(terms) == 1:
        return terms[0]
    return And(tuple(terms))
