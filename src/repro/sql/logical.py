"""Logical query plan (LQP) and the binder (paper Fig. 2, green boxes).

The binder validates a parsed statement against the external catalog,
resolves column types, rewrites string literals on dictionary columns to
dictionary codes (including LIKE prefix patterns → IN code lists), folds
date/interval arithmetic, extracts join edges from WHERE/ON equality
predicates, and emits a logical plan tree.

TPC-H-scoped simplifications (documented in DESIGN.md): equi-joins must be
FK→PK (the build side's key is its primary key — true for every TPC-H join
we target), no NULL semantics (TPC-H data has no NULLs), group-by keys are
plain columns.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import hashlib
from typing import Any

import numpy as np

from repro.data.catalog import Catalog
from repro.sql import ast

# Primary keys for build-side uniqueness reasoning.
PRIMARY_KEYS = {
    "orders": "o_orderkey", "customer": "c_custkey", "part": "p_partkey",
    "supplier": "s_suppkey", "nation": "n_nationkey",
    "region": "r_regionkey",
}


@dataclasses.dataclass(frozen=True)
class ColType:
    kind: str                       # num | dict | bytes
    dtype: str                      # numpy dtype string
    dictionary: tuple[str, ...] | None = None


Schema = dict[str, ColType]


# -- logical nodes ------------------------------------------------------------

class LNode:
    def key(self) -> Any:
        raise NotImplementedError

    def children(self) -> tuple["LNode", ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class LScan(LNode):
    table: str
    schema_cols: tuple[str, ...]

    def key(self):
        return ("scan", self.table, self.schema_cols)


@dataclasses.dataclass(frozen=True)
class LFilter(LNode):
    child: LNode
    pred: ast.Expr

    def key(self):
        return ("filter", self.child.key(), self.pred.key())

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class LProject(LNode):
    child: LNode
    exprs: tuple[tuple[str, ast.Expr], ...]   # (output name, expr)

    def key(self):
        return ("project", self.child.key(),
                tuple((n, e.key()) for n, e in self.exprs))

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class LJoin(LNode):
    """Equi-join; ``right`` is the build side whose key is unique (PK)."""
    left: LNode
    right: LNode
    left_key: str
    right_key: str

    def key(self):
        return ("join", self.left.key(), self.right.key(), self.left_key,
                self.right_key)

    def children(self):
        return (self.left, self.right)


@dataclasses.dataclass(frozen=True)
class LAggregate(LNode):
    child: LNode
    group_cols: tuple[str, ...]
    # (output name, fn, arg expr or None for count(*))
    aggs: tuple[tuple[str, str, ast.Expr | None], ...]

    def key(self):
        return ("agg", self.child.key(), self.group_cols,
                tuple((n, f, a.key() if a else None)
                      for n, f, a in self.aggs))

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class LSort(LNode):
    child: LNode
    keys: tuple[tuple[str, bool], ...]        # (column name, desc)

    def key(self):
        return ("sort", self.child.key(), self.keys)

    def children(self):
        return (self.child,)


@dataclasses.dataclass(frozen=True)
class LLimit(LNode):
    child: LNode
    n: int

    def key(self):
        return ("limit", self.child.key(), self.n)

    def children(self):
        return (self.child,)


def semantic_hash(node: LNode) -> str:
    """Cache identifier: hash of the logical plan structure (section 3.4)."""
    return hashlib.sha256(repr(node.key()).encode()).hexdigest()[:24]


# -- binder -------------------------------------------------------------------

class BindError(Exception):
    pass


_EPOCH = np.datetime64("1970-01-01")


def _date_to_int(s: str) -> int:
    return int((np.datetime64(s) - _EPOCH).astype(int))


def _shift_date(days: int, n: int, unit: str, sign: int) -> int:
    d = _EPOCH + np.timedelta64(days, "D")
    if unit == "day":
        return int(((d + sign * np.timedelta64(n, "D")) - _EPOCH
                    ).astype(int))
    months = {"year": 12 * n, "month": n}[unit]
    m = d.astype("datetime64[M]") + sign * np.timedelta64(months, "M")
    frac = (d - d.astype("datetime64[M]").astype("datetime64[D]"))
    return int(((m.astype("datetime64[D]") + frac) - _EPOCH).astype(int))


class Binder:
    def __init__(self, catalog: Catalog):
        self.catalog = catalog

    # .. column/typing helpers ..
    def _table_schema(self, table: str) -> Schema:
        meta = self.catalog.table(table)
        return {c.name: ColType(c.kind, c.dtype, c.dictionary)
                for c in meta.schema}

    def bind(self, stmt: ast.SelectStmt) -> tuple[LNode, Schema]:
        tables = list(stmt.tables) + [j.table for j in stmt.joins]
        for t in tables:
            self.catalog.table(t)  # existence check
        schemas = {t: self._table_schema(t) for t in tables}
        col_home: dict[str, str] = {}
        for t in tables:
            for c in schemas[t]:
                if c in col_home:
                    raise BindError(f"ambiguous column {c}")
                col_home[c] = t

        env: Schema = {}
        for t in tables:
            env.update(schemas[t])

        def fold(e: ast.Expr) -> ast.Expr:
            return map_fold(e, env)

        # Split WHERE into join edges and filters.
        where = fold(stmt.where) if stmt.where is not None else None
        join_edges: list[tuple[str, str, str, str]] = []
        filters: list[ast.Expr] = []
        for c in ast.conjuncts(where):
            edge = self._as_join_edge(c, col_home)
            if edge is not None:
                join_edges.append(edge)
            else:
                filters.append(c)
        for j in stmt.joins:
            edge = self._as_join_edge(fold(j.on), col_home)
            if edge is None:
                raise BindError(f"JOIN ON must be col = col: {j.on}")
            join_edges.append(edge)

        plan = self._plan_joins(tables, schemas, col_home, join_edges,
                                filters)

        # Aggregation / projection.
        group_cols = []
        for g in stmt.group_by:
            if not isinstance(g, ast.Col):
                raise BindError("GROUP BY supports plain columns only")
            if g.name not in env:
                raise BindError(f"unknown group column {g.name}")
            group_cols.append(g.name)

        out_names: list[str] = []
        out_exprs: list[ast.Expr] = []
        for i, item in enumerate(stmt.items):
            e = fold(item.expr)
            name = item.alias or (e.name if isinstance(e, ast.Col)
                                  else f"col{i}")
            out_names.append(name)
            out_exprs.append(e)

        agg_terms: list[ast.Agg] = []
        for e in out_exprs:
            agg_terms.extend(a for a in ast.collect_aggs(e)
                             if a.key() not in
                             [x.key() for x in agg_terms])

        out_schema: Schema = {}
        if agg_terms or group_cols:
            # avg → sum/count decomposition for distributed merging
            phys_aggs: list[tuple[str, str, ast.Expr | None]] = []

            def agg_slot(a: ast.Agg) -> ast.Expr:
                if a.fn == "avg":
                    s = _intern(phys_aggs, "sum", a.arg)
                    c = _intern(phys_aggs, "count", a.arg)
                    return ast.BinOp("/", ast.Col(s), ast.Col(c))
                return ast.Col(_intern(phys_aggs, a.fn, a.arg))

            def replace_aggs(e: ast.Expr) -> ast.Expr:
                return ast.map_expr(
                    e, lambda n: agg_slot(n) if isinstance(n, ast.Agg)
                    else n)

            final_exprs = [replace_aggs(e) for e in out_exprs]
            plan = LAggregate(plan, tuple(group_cols), tuple(phys_aggs))
            agg_env: Schema = {c: env[c] for c in group_cols}
            for name, fn, arg in phys_aggs:
                agg_env[name] = ColType("num", "<f8" if fn != "count"
                                        else "<i8")
            out_schema = {}
            exprs = []
            for name, e in zip(out_names, final_exprs):
                exprs.append((name, e))
                out_schema[name] = _expr_type(e, agg_env)
            plan = LProject(plan, tuple(exprs))
        else:
            exprs = list(zip(out_names, out_exprs))
            plan = LProject(plan, tuple(exprs))
            out_schema = {n: _expr_type(e, env) for n, e in exprs}

        if stmt.order_by:
            keys = []
            for o in stmt.order_by:
                e = fold(o.expr)
                if isinstance(e, ast.Col) and e.name in out_schema:
                    keys.append((e.name, o.desc))
                else:
                    raise BindError("ORDER BY must reference output columns")
            plan = LSort(plan, tuple(keys))
        if stmt.limit is not None:
            plan = LLimit(plan, stmt.limit)
        return plan, out_schema

    # .. join graph ..
    def _as_join_edge(self, e: ast.Expr, col_home: dict[str, str]):
        if (isinstance(e, ast.Cmp) and e.op == "="
                and isinstance(e.left, ast.Col)
                and isinstance(e.right, ast.Col)):
            lt, rt = col_home.get(e.left.name), col_home.get(e.right.name)
            if lt is None or rt is None:
                raise BindError(f"unknown column in {e}")
            if lt != rt:
                return (lt, e.left.name, rt, e.right.name)
        return None

    def _plan_joins(self, tables, schemas, col_home, join_edges, filters):
        # Per-table filter pushdown happens here (pre-optimizer) simply by
        # attaching filters to their home scan; the rule optimizer handles
        # the general (post-join) case.
        table_filters: dict[str, list[ast.Expr]] = {t: [] for t in tables}
        cross_filters: list[ast.Expr] = []
        for f in filters:
            home = {col_home[c] for c in ast.collect_columns(f)
                    if c in col_home}
            if len(home) == 1:
                table_filters[next(iter(home))].append(f)
            else:
                cross_filters.append(f)

        def scan(t: str) -> LNode:
            node: LNode = LScan(t, tuple(schemas[t].keys()))
            pred = ast.make_and(table_filters[t])
            if pred is not None:
                node = LFilter(node, pred)
            return node

        if len(tables) == 1:
            plan = scan(tables[0])
        else:
            # Greedy: start from the largest table (fact side), repeatedly
            # join a connected table; build side key must be its PK.
            sizes = {t: self.catalog.table(t).rows for t in tables}
            edges = list(join_edges)
            current = max(tables, key=lambda t: sizes[t])
            joined = {current}
            plan = scan(current)
            while len(joined) < len(tables):
                cand = None
                for e in edges:
                    lt, lk, rt, rk = e
                    if lt in joined and rt not in joined:
                        cand = (rt, lk, rk, e)
                    elif rt in joined and lt not in joined:
                        cand = (lt, rk, lk, e)
                    else:
                        continue
                    break
                if cand is None:
                    raise BindError("join graph is disconnected")
                new_t, probe_key, build_key, e = cand
                if PRIMARY_KEYS.get(new_t) != build_key:
                    raise BindError(
                        f"build side {new_t}.{build_key} is not a PK "
                        "(only FK→PK joins are supported)")
                plan = LJoin(plan, scan(new_t), probe_key, build_key)
                joined.add(new_t)
                edges.remove(e)
            # surviving edges are extra equality constraints → filters
            for lt, lk, rt, rk in edges:
                cross_filters.append(ast.Cmp("=", ast.Col(lk), ast.Col(rk)))
        pred = ast.make_and(cross_filters)
        if pred is not None:
            plan = LFilter(plan, pred)
        return plan


def _intern(phys_aggs: list, fn: str, arg: ast.Expr | None) -> str:
    for name, f, a in phys_aggs:
        if f == fn and ((a is None and arg is None)
                        or (a is not None and arg is not None
                            and a.key() == arg.key())):
            return name
    name = f"_agg{len(phys_aggs)}"
    phys_aggs.append((name, fn, arg))
    return name


def _expr_type(e: ast.Expr, env: Schema) -> ColType:
    if isinstance(e, ast.Col):
        if e.name not in env:
            raise BindError(f"unknown column {e.name}")
        return env[e.name]
    if isinstance(e, ast.Lit):
        if e.kind == "date":
            return ColType("num", "<i4")
        if e.kind == "str":
            return ColType("bytes", "S32")
        return ColType("num", "<i8" if isinstance(e.value, int) else "<f8")
    if isinstance(e, (ast.Cmp, ast.And, ast.Or, ast.Not, ast.Between,
                      ast.InList, ast.Like)):
        return ColType("num", "|b1")
    if isinstance(e, ast.BinOp):
        lt = _expr_type(e.left, env)
        rt = _expr_type(e.right, env)
        if e.op == "/" or "f" in lt.dtype or "f" in rt.dtype:
            return ColType("num", "<f8")
        return ColType("num", "<i8")
    if isinstance(e, ast.Case):
        return _expr_type(e.then, env)
    if isinstance(e, ast.Agg):
        return ColType("num", "<i8" if e.fn == "count" else "<f8")
    raise BindError(f"cannot type {e}")


# -- constant folding & dictionary rewriting ----------------------------------

def map_fold(e: ast.Expr, env: Schema) -> ast.Expr:
    """Fold dates/intervals/constants and rewrite dict-column literals."""

    def fold_node(n: ast.Expr) -> ast.Expr:
        if isinstance(n, ast.Lit) and n.kind == "date":
            return ast.Lit(_date_to_int(n.value), "num")
        if isinstance(n, ast.BinOp) and isinstance(n.right, ast.Lit) \
                and n.right.kind == "interval":
            if not (isinstance(n.left, ast.Lit) and n.left.kind == "num"):
                raise BindError("interval arithmetic needs a date literal")
            nval, unit = n.right.value
            sign = 1 if n.op == "+" else -1
            return ast.Lit(_shift_date(n.left.value, nval, unit, sign),
                           "num")
        if isinstance(n, ast.BinOp) and isinstance(n.left, ast.Lit) \
                and isinstance(n.right, ast.Lit) \
                and n.left.kind == "num" and n.right.kind == "num":
            a, b = n.left.value, n.right.value
            v = {"+": a + b, "-": a - b, "*": a * b,
                 "/": a / b if b else 0.0}[n.op]
            return ast.Lit(v, "num")
        if isinstance(n, ast.Cmp):
            rewritten = _rewrite_dict_cmp(n, env)
            if rewritten is not None:
                return rewritten
        if isinstance(n, ast.InList):
            rewritten = _rewrite_dict_in(n, env)
            if rewritten is not None:
                return rewritten
        if isinstance(n, ast.Like):
            return _rewrite_like(n, env)
        if isinstance(n, ast.Between):
            return ast.And((ast.Cmp(">=", n.term, n.lo),
                            ast.Cmp("<=", n.term, n.hi)))
        return n

    return ast.map_expr(e, fold_node)


def _dict_code(ct: ColType, value: str) -> int:
    try:
        return ct.dictionary.index(value)
    except ValueError:
        return -1  # never matches


def _rewrite_dict_cmp(n: ast.Cmp, env: Schema):
    for a, b, flip in ((n.left, n.right, False), (n.right, n.left, True)):
        if isinstance(a, ast.Col) and isinstance(b, ast.Lit) \
                and b.kind == "str" and a.name in env \
                and env[a.name].kind == "dict":
            if n.op not in ("=", "<>"):
                raise BindError(
                    f"only =/<> comparisons on dict column {a.name}")
            code = _dict_code(env[a.name], b.value)
            return ast.Cmp(n.op, a, ast.Lit(code, "num"))
    return None


def _rewrite_dict_in(n: ast.InList, env: Schema):
    if isinstance(n.term, ast.Col) and n.term.name in env \
            and env[n.term.name].kind == "dict":
        codes = []
        for v in n.values:
            if not (isinstance(v, ast.Lit) and v.kind == "str"):
                raise BindError("IN list on dict column must be strings")
            codes.append(ast.Lit(_dict_code(env[n.term.name], v.value),
                                 "num"))
        return ast.InList(n.term, tuple(codes))
    return None


def _rewrite_like(n: ast.Like, env: Schema) -> ast.Expr:
    if not (isinstance(n.term, ast.Col) and n.term.name in env
            and env[n.term.name].kind == "dict"):
        raise BindError("LIKE is supported on dictionary columns only")
    pattern = n.pattern.replace("%", "*").replace("_", "?")
    ct = env[n.term.name]
    codes = [i for i, v in enumerate(ct.dictionary)
             if fnmatch.fnmatchcase(v, pattern)]
    if not codes:
        return ast.Cmp("=", ast.Lit(0, "num"), ast.Lit(1, "num"))
    return ast.InList(n.term, tuple(ast.Lit(c, "num") for c in codes))
