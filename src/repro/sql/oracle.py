"""Single-node numpy reference engine ("oracle") over logical plans.

Used by tests and benchmarks to validate the distributed serverless engine:
both engines evaluate the same bound + optimized LQP, so any divergence is
an execution bug, not a semantics mismatch.
"""

from __future__ import annotations

import numpy as np

from repro.sql import ast
from repro.sql.logical import (LAggregate, LFilter, LJoin, LLimit, LNode,
                               LProject, LScan, LSort)

Table = dict[str, np.ndarray]


def eval_expr(e: ast.Expr, cols: Table) -> np.ndarray:
    if isinstance(e, ast.Col):
        return cols[e.name]
    if isinstance(e, ast.Lit):
        return np.asarray(e.value)
    if isinstance(e, ast.BinOp):
        a, b = eval_expr(e.left, cols), eval_expr(e.right, cols)
        if e.op == "+":
            return a + b
        if e.op == "-":
            return a - b
        if e.op == "*":
            return a * b
        if e.op == "/":
            return a / b
    if isinstance(e, ast.Cmp):
        a, b = eval_expr(e.left, cols), eval_expr(e.right, cols)
        return {"<": a < b, "<=": a <= b, ">": a > b, ">=": a >= b,
                "=": a == b, "<>": a != b}[e.op]
    if isinstance(e, ast.And):
        out = eval_expr(e.terms[0], cols)
        for t in e.terms[1:]:
            out = out & eval_expr(t, cols)
        return out
    if isinstance(e, ast.Or):
        out = eval_expr(e.terms[0], cols)
        for t in e.terms[1:]:
            out = out | eval_expr(t, cols)
        return out
    if isinstance(e, ast.Not):
        return ~eval_expr(e.term, cols)
    if isinstance(e, ast.Case):
        c = eval_expr(e.cond, cols)
        return np.where(c, eval_expr(e.then, cols),
                        eval_expr(e.orelse, cols))
    if isinstance(e, ast.InList):
        t = eval_expr(e.term, cols)
        out = np.zeros(t.shape, bool)
        for v in e.values:
            out |= (t == eval_expr(v, cols))
        return out
    raise TypeError(f"oracle cannot evaluate {e}")


def run(plan: LNode, tables: dict[str, Table]) -> Table:
    if isinstance(plan, LScan):
        t = tables[plan.table]
        return {c: t[c] for c in plan.schema_cols}
    if isinstance(plan, LFilter):
        t = run(plan.child, tables)
        mask = eval_expr(plan.pred, t)
        return {c: v[mask] for c, v in t.items()}
    if isinstance(plan, LProject):
        t = run(plan.child, tables)
        out = {}
        for name, e in plan.exprs:
            v = eval_expr(e, t)
            if v.ndim == 0:
                n = len(next(iter(t.values()))) if t else 1
                v = np.broadcast_to(v, (n,)).copy()
            out[name] = v
        return out
    if isinstance(plan, LJoin):
        left = run(plan.left, tables)
        right = run(plan.right, tables)
        bkeys = right[plan.right_key]
        order = np.argsort(bkeys, kind="stable")
        skeys = bkeys[order]
        probe = left[plan.left_key]
        pos = np.searchsorted(skeys, probe)
        pos_c = np.clip(pos, 0, max(len(skeys) - 1, 0))
        hit = (len(skeys) > 0) & (skeys[pos_c] == probe)
        out = {c: v[hit] for c, v in left.items()}
        sel = order[pos_c[hit]]
        for c, v in right.items():
            if c not in out:
                out[c] = v[sel]
        return out
    if isinstance(plan, LAggregate):
        t = run(plan.child, tables)
        n = len(next(iter(t.values()))) if t else 0
        if plan.group_cols:
            keys = np.stack([t[c] for c in plan.group_cols], axis=1)
            uniq, inv = np.unique(keys, axis=0, return_inverse=True)
            g = uniq.shape[0]
        else:
            uniq = None
            inv = np.zeros(n, dtype=np.int64)
            g = 1
        out: Table = {}
        if uniq is not None:
            for i, c in enumerate(plan.group_cols):
                out[c] = uniq[:, i].astype(t[c].dtype)
        for name, fn, arg in plan.aggs:
            if fn == "count":
                vals = np.ones(n)
            else:
                vals = eval_expr(arg, t).astype(np.float64)
                if vals.ndim == 0:
                    vals = np.broadcast_to(vals, (n,)).copy()
            if fn in ("sum", "count"):
                r = np.bincount(inv, weights=vals, minlength=g)
                out[name] = r.astype(np.int64) if fn == "count" else r
            elif fn == "min":
                r = np.full(g, np.inf)
                np.minimum.at(r, inv, vals)
                out[name] = r
            elif fn == "max":
                r = np.full(g, -np.inf)
                np.maximum.at(r, inv, vals)
                out[name] = r
            else:
                raise TypeError(fn)
        return out
    if isinstance(plan, LSort):
        t = run(plan.child, tables)
        keys = []
        for name, desc in reversed(plan.keys):
            k = t[name]
            keys.append(-k if desc else k)
        order = np.lexsort(keys) if keys else np.arange(0)
        return {c: v[order] for c, v in t.items()}
    if isinstance(plan, LLimit):
        t = run(plan.child, tables)
        return {c: v[:plan.n] for c, v in t.items()}
    raise TypeError(plan)
