"""SQL tokenizer and recursive-descent parser for the TPC-H subset.

Supported grammar (sufficient for TPC-H Q1/Q3/Q4-rewrite/Q5/Q6/Q12/Q14 and
generated property-test queries):

    SELECT item [, item]*
    FROM table [, table]* [JOIN table ON col = col]*
    [WHERE pred]
    [GROUP BY expr [, expr]*]
    [ORDER BY expr [ASC|DESC] [, ...]]
    [LIMIT n]

Expressions: + - * /, comparisons, AND/OR/NOT, BETWEEN, IN (...), LIKE,
CASE WHEN .. THEN .. ELSE .. END, DATE 'yyyy-mm-dd', INTERVAL 'n' unit,
aggregates SUM/AVG/MIN/MAX/COUNT(*).
"""

from __future__ import annotations

import re

from repro.sql import ast

_TOKEN_RE = re.compile(r"""
    \s*(?:
      (?P<num>\d+\.\d+|\.\d+|\d+)
    | (?P<str>'(?:[^']|'')*')
    | (?P<op><>|<=|>=|=|<|>|\+|-|\*|/|\(|\)|,|\.)
    | (?P<word>[A-Za-z_][A-Za-z_0-9]*)
    )""", re.VERBOSE)

KEYWORDS = {
    "select", "from", "where", "group", "by", "order", "limit", "and", "or",
    "not", "between", "in", "like", "case", "when", "then", "else", "end",
    "as", "asc", "desc", "date", "interval", "year", "month", "day", "join",
    "on", "sum", "avg", "count", "min", "max", "distinct",
}


class Token:
    def __init__(self, kind: str, value):
        self.kind = kind      # num | str | op | word | kw | eof
        self.value = value

    def __repr__(self):
        return f"Token({self.kind},{self.value!r})"


def tokenize(sql: str) -> list[Token]:
    out, pos = [], 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m or m.end() == pos:
            if sql[pos:].strip() == "":
                break
            raise SyntaxError(f"bad token at: {sql[pos:pos+20]!r}")
        pos = m.end()
        if m.group("num") is not None:
            text = m.group("num")
            out.append(Token("num", float(text) if "." in text
                             else int(text)))
        elif m.group("str") is not None:
            out.append(Token("str", m.group("str")[1:-1].replace("''", "'")))
        elif m.group("op") is not None:
            out.append(Token("op", m.group("op")))
        else:
            w = m.group("word").lower()
            out.append(Token("kw" if w in KEYWORDS else "word", w))
    out.append(Token("eof", None))
    return out


class Parser:
    def __init__(self, sql: str):
        self.toks = tokenize(sql)
        self.i = 0

    # -- plumbing ------------------------------------------------------------
    def peek(self) -> Token:
        return self.toks[self.i]

    def next(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept(self, kind: str, value=None) -> Token | None:
        t = self.peek()
        if t.kind == kind and (value is None or t.value == value):
            return self.next()
        return None

    def expect(self, kind: str, value=None) -> Token:
        t = self.accept(kind, value)
        if t is None:
            raise SyntaxError(
                f"expected {kind} {value!r}, got {self.peek()!r}")
        return t

    # -- statement -----------------------------------------------------------
    def parse(self) -> ast.SelectStmt:
        self.expect("kw", "select")
        items = [self._select_item()]
        while self.accept("op", ","):
            items.append(self._select_item())
        self.expect("kw", "from")
        tables = [self.expect("word").value]
        joins = []
        while True:
            if self.accept("op", ","):
                tables.append(self.expect("word").value)
            elif self.accept("kw", "join"):
                tbl = self.expect("word").value
                self.expect("kw", "on")
                cond = self._expr()
                joins.append(ast.JoinClause(tbl, cond))
            else:
                break
        where = self._expr() if self.accept("kw", "where") else None
        group_by: list[ast.Expr] = []
        if self.accept("kw", "group"):
            self.expect("kw", "by")
            group_by.append(self._expr())
            while self.accept("op", ","):
                group_by.append(self._expr())
        order_by: list[ast.OrderItem] = []
        if self.accept("kw", "order"):
            self.expect("kw", "by")
            order_by.append(self._order_item())
            while self.accept("op", ","):
                order_by.append(self._order_item())
        limit = None
        if self.accept("kw", "limit"):
            limit = int(self.expect("num").value)
        self.expect("eof")
        return ast.SelectStmt(tuple(items), tuple(tables), tuple(joins),
                              where, tuple(group_by), tuple(order_by), limit)

    def _select_item(self) -> ast.SelectItem:
        e = self._expr()
        alias = None
        if self.accept("kw", "as"):
            alias = self.expect("word").value
        elif self.peek().kind == "word":
            alias = self.next().value
        return ast.SelectItem(e, alias)

    def _order_item(self) -> ast.OrderItem:
        e = self._expr()
        desc = False
        if self.accept("kw", "desc"):
            desc = True
        else:
            self.accept("kw", "asc")
        return ast.OrderItem(e, desc)

    # -- expressions (precedence climbing) ------------------------------------
    def _expr(self) -> ast.Expr:
        return self._or()

    def _or(self) -> ast.Expr:
        terms = [self._and()]
        while self.accept("kw", "or"):
            terms.append(self._and())
        return terms[0] if len(terms) == 1 else ast.Or(tuple(terms))

    def _and(self) -> ast.Expr:
        terms = [self._not()]
        while self.accept("kw", "and"):
            terms.append(self._not())
        return terms[0] if len(terms) == 1 else ast.And(tuple(terms))

    def _not(self) -> ast.Expr:
        if self.accept("kw", "not"):
            return ast.Not(self._not())
        return self._predicate()

    def _predicate(self) -> ast.Expr:
        left = self._additive()
        t = self.peek()
        if t.kind == "op" and t.value in ("<", "<=", ">", ">=", "=", "<>"):
            self.next()
            return ast.Cmp(t.value, left, self._additive())
        if t.kind == "kw" and t.value == "between":
            self.next()
            lo = self._additive()
            self.expect("kw", "and")
            hi = self._additive()
            return ast.Between(left, lo, hi)
        if t.kind == "kw" and t.value == "in":
            self.next()
            self.expect("op", "(")
            vals = [self._additive()]
            while self.accept("op", ","):
                vals.append(self._additive())
            self.expect("op", ")")
            return ast.InList(left, tuple(vals))
        if t.kind == "kw" and t.value == "like":
            self.next()
            pat = self.expect("str").value
            return ast.Like(left, pat)
        return left

    def _additive(self) -> ast.Expr:
        e = self._multiplicative()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("+", "-"):
                self.next()
                e = ast.BinOp(t.value, e, self._multiplicative())
            else:
                return e

    def _multiplicative(self) -> ast.Expr:
        e = self._unary()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("*", "/"):
                self.next()
                e = ast.BinOp(t.value, e, self._unary())
            else:
                return e

    def _unary(self) -> ast.Expr:
        if self.accept("op", "-"):
            return ast.BinOp("-", ast.Lit(0), self._unary())
        return self._primary()

    def _primary(self) -> ast.Expr:
        t = self.peek()
        if t.kind == "num":
            self.next()
            return ast.Lit(t.value)
        if t.kind == "str":
            self.next()
            return ast.Lit(t.value, "str")
        if self.accept("op", "("):
            e = self._expr()
            self.expect("op", ")")
            return e
        if t.kind == "kw" and t.value == "date":
            self.next()
            return ast.Lit(self.expect("str").value, "date")
        if t.kind == "kw" and t.value == "interval":
            self.next()
            n = self.expect("str").value
            unit = self.expect("kw").value
            if unit not in ("year", "month", "day"):
                raise SyntaxError(f"bad interval unit {unit}")
            return ast.Lit((int(n), unit), "interval")
        if t.kind == "kw" and t.value in ("sum", "avg", "min", "max",
                                          "count"):
            fn = self.next().value
            self.expect("op", "(")
            if fn == "count" and self.accept("op", "*"):
                self.expect("op", ")")
                return ast.Agg("count", None)
            self.accept("kw", "distinct")  # tolerated, not semantically used
            arg = self._expr()
            self.expect("op", ")")
            return ast.Agg(fn, arg)
        if t.kind == "kw" and t.value == "case":
            self.next()
            self.expect("kw", "when")
            cond = self._expr()
            self.expect("kw", "then")
            then = self._expr()
            if self.accept("kw", "else"):
                orelse = self._expr()
            else:
                orelse = ast.Lit(0)
            self.expect("kw", "end")
            return ast.Case(cond, then, orelse)
        if t.kind == "word":
            self.next()
            # qualified name t.col → treat the column name as canonical
            if self.accept("op", "."):
                return ast.Col(self.expect("word").value)
            return ast.Col(t.value)
        raise SyntaxError(f"unexpected token {t!r}")


def parse(sql: str) -> ast.SelectStmt:
    return Parser(sql).parse()
