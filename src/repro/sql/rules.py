"""Rule-based logical optimizer (paper section 3.2).

Conventional heuristics oblivious of the serverless execution environment:
predicate pushdown, projection pruning, and trivial-filter elimination.
Join ordering happens during binding (greedy, FK→PK); subquery flattening
is unnecessary for the supported grammar.
"""

from __future__ import annotations

import dataclasses

from repro.sql import ast
from repro.sql.logical import (LAggregate, LFilter, LJoin, LLimit, LNode,
                               LProject, LScan, LSort)


def _columns_of(node: LNode) -> set[str]:
    """Output columns of a logical node."""
    if isinstance(node, LScan):
        return set(node.schema_cols)
    if isinstance(node, LFilter):
        return _columns_of(node.child)
    if isinstance(node, LProject):
        return {n for n, _ in node.exprs}
    if isinstance(node, LJoin):
        return _columns_of(node.left) | _columns_of(node.right)
    if isinstance(node, LAggregate):
        return set(node.group_cols) | {n for n, _, _ in node.aggs}
    if isinstance(node, (LSort, LLimit)):
        return _columns_of(node.child)
    raise TypeError(node)


def _ordered_columns_of(node: LNode) -> list[str]:
    """Output columns in *schema* order — LScan's tuple order is the
    catalog's column order (keys first), so callers that must keep "one
    arbitrary column" alive pick deterministically, and pick a key
    column rather than whatever a hash-randomized set yields first
    (string-typed payload columns cannot enter an XLA block)."""
    if isinstance(node, LScan):
        return list(node.schema_cols)
    if isinstance(node, (LFilter, LSort, LLimit)):
        return _ordered_columns_of(node.child)
    if isinstance(node, LProject):
        return [n for n, _ in node.exprs]
    if isinstance(node, LJoin):
        return (_ordered_columns_of(node.left)
                + _ordered_columns_of(node.right))
    if isinstance(node, LAggregate):
        return list(node.group_cols) + [n for n, _, _ in node.aggs]
    raise TypeError(node)


# -- rule: predicate pushdown -------------------------------------------------

def _subst_cols(e: ast.Expr, mapping: dict[str, str]) -> ast.Expr:
    """Rewrite column references through a rename map (frozen dataclass
    expressions are rebuilt bottom-up)."""
    if isinstance(e, ast.Col):
        return ast.Col(mapping.get(e.name, e.name))
    if not e.children():
        return e
    kw = {}
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        if isinstance(v, ast.Expr):
            kw[f.name] = _subst_cols(v, mapping)
        elif isinstance(v, tuple) and v and isinstance(v[0], ast.Expr):
            kw[f.name] = tuple(_subst_cols(x, mapping) for x in v)
        else:
            kw[f.name] = v
    return type(e)(**kw)


def push_filters(node: LNode) -> LNode:
    if isinstance(node, LFilter):
        child = push_filters(node.child)
        terms = ast.conjuncts(node.pred)
        if isinstance(child, LJoin):
            left_cols = _columns_of(child.left)
            right_cols = _columns_of(child.right)
            to_left, to_right, stay = [], [], []
            for t in terms:
                cols = set(ast.collect_columns(t))
                if cols <= left_cols:
                    to_left.append(t)
                elif cols <= right_cols:
                    to_right.append(t)
                else:
                    stay.append(t)
            left, right = child.left, child.right
            if to_left:
                left = push_filters(
                    LFilter(left, ast.make_and(to_left)))
            if to_right:
                right = push_filters(
                    LFilter(right, ast.make_and(to_right)))
            out: LNode = LJoin(left, right, child.left_key, child.right_key)
            if stay:
                out = LFilter(out, ast.make_and(stay))
            return out
        if isinstance(child, LProject):
            # Push terms below pure column-rename projections: per-row
            # renames create/delete no rows, so the filter commutes once
            # its column references are mapped to pre-projection names.
            # This exposes build-side selectivity to the scan (zone-map
            # pruning and row estimates feeding the semi-join cost gate).
            rename = {n: e.name for n, e in child.exprs
                      if isinstance(e, ast.Col)}
            down, stay = [], []
            for t in terms:
                if set(ast.collect_columns(t)) <= set(rename):
                    down.append(_subst_cols(t, rename))
                else:
                    stay.append(t)
            if down:
                inner = push_filters(
                    LFilter(child.child, ast.make_and(down)))
                out = LProject(inner, child.exprs)
                if stay:
                    return LFilter(out, ast.make_and(stay))
                return out
        if isinstance(child, LFilter):
            merged = ast.make_and(ast.conjuncts(child.pred) + terms)
            return push_filters(LFilter(child.child, merged))
        return LFilter(child, node.pred)
    if isinstance(node, LProject):
        return LProject(push_filters(node.child), node.exprs)
    if isinstance(node, LJoin):
        return LJoin(push_filters(node.left), push_filters(node.right),
                     node.left_key, node.right_key)
    if isinstance(node, LAggregate):
        return LAggregate(push_filters(node.child), node.group_cols,
                          node.aggs)
    if isinstance(node, LSort):
        return LSort(push_filters(node.child), node.keys)
    if isinstance(node, LLimit):
        return LLimit(push_filters(node.child), node.n)
    return node


# -- rule: projection pruning -------------------------------------------------

def prune_columns(node: LNode, needed: set[str] | None = None) -> LNode:
    """Top-down pass narrowing scans to the transitively required columns."""
    if needed is None:
        needed = _columns_of(node)

    if isinstance(node, LScan):
        cols = tuple(c for c in node.schema_cols if c in needed)
        return LScan(node.table, cols)
    if isinstance(node, LFilter):
        child_needed = needed | set(ast.collect_columns(node.pred))
        return LFilter(prune_columns(node.child, child_needed), node.pred)
    if isinstance(node, LProject):
        kept = tuple((n, e) for n, e in node.exprs if n in needed)
        kept = kept or node.exprs[:1]
        child_needed = set()
        for _, e in kept:
            child_needed |= set(ast.collect_columns(e))
        return LProject(prune_columns(node.child, child_needed), kept)
    if isinstance(node, LJoin):
        need = set(needed) | {node.left_key, node.right_key}
        left_cols = _columns_of(node.left)
        right_cols = _columns_of(node.right)
        return LJoin(prune_columns(node.left, need & left_cols),
                     prune_columns(node.right, need & right_cols),
                     node.left_key, node.right_key)
    if isinstance(node, LAggregate):
        child_needed = set(node.group_cols)
        for _, _, arg in node.aggs:
            if arg is not None:
                child_needed |= set(ast.collect_columns(arg))
        if not child_needed:
            # count(*) over no columns: keep one column alive — the
            # schema-order first (a key column), deterministically
            child_needed = set(_ordered_columns_of(node.child)[:1])
        return LAggregate(prune_columns(node.child, child_needed),
                          node.group_cols, node.aggs)
    if isinstance(node, LSort):
        child_needed = needed | {k for k, _ in node.keys}
        return LSort(prune_columns(node.child, child_needed), node.keys)
    if isinstance(node, LLimit):
        return LLimit(prune_columns(node.child, needed), node.n)
    raise TypeError(node)


# -- rule: trivial filter elimination ------------------------------------------

def _is_true(e: ast.Expr) -> bool:
    return isinstance(e, ast.Lit) and bool(e.value)


def drop_trivial_filters(node: LNode) -> LNode:
    if isinstance(node, LFilter):
        child = drop_trivial_filters(node.child)
        terms = [t for t in ast.conjuncts(node.pred) if not _is_true(t)]
        if not terms:
            return child
        return LFilter(child, ast.make_and(terms))
    if isinstance(node, LProject):
        return LProject(drop_trivial_filters(node.child), node.exprs)
    if isinstance(node, LJoin):
        return LJoin(drop_trivial_filters(node.left),
                     drop_trivial_filters(node.right),
                     node.left_key, node.right_key)
    if isinstance(node, LAggregate):
        return LAggregate(drop_trivial_filters(node.child), node.group_cols,
                          node.aggs)
    if isinstance(node, LSort):
        return LSort(drop_trivial_filters(node.child), node.keys)
    if isinstance(node, LLimit):
        return LLimit(drop_trivial_filters(node.child), node.n)
    return node


def optimize(plan: LNode) -> LNode:
    """Apply the rule set to fixpoint (bounded)."""
    for _ in range(4):
        new = drop_trivial_filters(prune_columns(push_filters(plan)))
        if new.key() == plan.key():
            return new
        plan = new
    return plan
