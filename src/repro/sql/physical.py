"""Physical optimizer and plan (paper section 3.2, blue boxes; Fig. 3).

Maps the optimized logical plan to pipelines of physical operators for
data-parallel execution by serverless workers:

  * logical→physical operator mapping (repartition vs. broadcast join,
    direct vs. sort aggregation strategies),
  * pipeline-breaker identification and shuffle-point insertion,
  * worker counts per pipeline from input size and per-function network
    burst capacity,
  * shuffle tier selection (standard vs. hot/express storage) from the
    object-request-rate reasoning of the paper,
  * per-pipeline *semantic hashes* — computed from the logical subtree a
    pipeline completes plus the catalog's file lists, *before* physical
    properties (worker counts, partition fan-out, exchange tier) are
    attached, so cached results match across physical configurations
    (section 3.4).

All artifacts are JSON/msgpack-serializable: fragment specs are the
function invocation payloads (section 3.3).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

import numpy as np

from repro.data.catalog import Catalog
from repro.exec.expr import expr_from_dict, expr_to_dict
from repro.sql import ast
from repro.sql.logical import (LAggregate, LFilter, LJoin, LLimit, LNode,
                               LProject, LScan, LSort)

DIRECT_AGG_MAX_GROUPS = 4096


@dataclasses.dataclass
class PlannerConfig:
    # Per-worker input target: the network burst capacity of one function
    # (paper: worker count is input size / burst capacity).
    bytes_per_worker: int = 32 << 20
    max_workers: int = 2500
    # Build sides smaller than this are broadcast instead of repartitioned.
    broadcast_threshold_bytes: int = 16 << 20
    # Exchange fan-out (defaults derived from producer width if None).
    exchange_partitions: int | None = None
    # Above this many shuffle objects, tier the exchange to hot storage.
    hot_shuffle_object_threshold: int = 64
    filter_selectivity_guess: float = 0.3
    # Force one shuffle strategy for every hash exchange
    # ("direct" | "combining" | "multilevel"); None → the cost model
    # picks per exchange via ``CostModel.exchange_cost``.
    exchange_strategy: str | None = None
    # Annotate repartition joins for semi-join filter pushdown (the
    # build side publishes a Bloom filter over the join key; eligible
    # probe exchanges apply it before partitioning when
    # ``CostModel.semijoin_benefit`` projects a saving).
    semijoin: bool = True


@dataclasses.dataclass
class Partitioning:
    kind: str                      # none | hash
    keys: tuple[str, ...] = ()
    n_dest: int = 1
    tier: str = "s3-standard"
    # Shuffle strategy (repro.exec.exchange registry). The *intent*; the
    # materialized layout consumers dispatch on is recorded in the
    # registry entry at publish time ("layout": grid | combined).
    strategy: str = "direct"
    # Multilevel only: storage tier of the short-lived l0 intermediates
    # (producer spill before the merge wave). None → same as ``tier``;
    # ``CostModel.l0_tier_choice`` routes them to the express tier when
    # cheaper, and the engine deletes the l0 prefix once the wave lands.
    l0_tier: str | None = None

    def to_dict(self):
        return {"kind": self.kind, "keys": list(self.keys),
                "n_dest": self.n_dest, "tier": self.tier,
                "strategy": self.strategy, "l0_tier": self.l0_tier}

    @classmethod
    def from_dict(cls, d):
        return cls(d["kind"], tuple(d["keys"]), d["n_dest"], d["tier"],
                   d.get("strategy", "direct"), d.get("l0_tier"))


@dataclasses.dataclass
class ExecutionParams:
    """Mutable physical execution properties of one pipeline.

    Everything here may be re-decided *after* planning: the planner
    writes its compile-time choices and estimates, and the runtime
    re-optimizer (``repro.core.adaptive``) overwrites them at the stage
    barrier once upstream pipelines have published observed statistics.
    The logical content of the owning :class:`Pipeline` (op tree,
    semantic hash, dependencies, schema) is never touched at runtime —
    semantic hashing guarantees a re-parameterized pipeline still caches
    and dedups against its statically planned twin (section 3.4).
    """

    n_fragments: int
    partitioning: Partitioning
    # planner estimates (est vs actual shown by EXPLAIN ANALYZE)
    est_in_bytes: int = 0
    est_out_rows: int = -1              # -1 = no basis for an estimate
    est_out_bytes: int = -1
    # runtime adaptation state (set by core.adaptive at the barrier):
    # exchange sources to read broadcast (mode=all) instead of aligned
    # partitions — the shuffle→broadcast join downgrade
    broadcast_sources: list[str] = dataclasses.field(default_factory=list)
    # per-fragment upstream partition ids (shared by every aligned
    # partition-mode source); None = the static 1:1 fragment↔partition map
    partition_assignment: list[list[int]] | None = None
    # per-source surviving (non-empty) partition ids for pruning reads
    source_partitions: dict[str, list[int]] = \
        dataclasses.field(default_factory=dict)
    # estimated producer-side storage requests of this pipeline's output
    # exchange under the chosen strategy (EXPLAIN ANALYZE est vs actual)
    est_exchange_requests: int = 0
    # Semi-join filter pushdown (probe side of an annotated repartition
    # join): the build pipeline's sem hash, key columns, key mode, the
    # cost gate's verdict and estimates. The Reoptimizer may flip
    # ``enabled`` at pilot-K time from the observed build cardinality;
    # the sem hash already folds the build side, so filtered and
    # unfiltered runs share one cache entry.
    semijoin: dict | None = None
    # Build side of the same join: instructs the fleet to construct a
    # Bloom filter over its exchange keys and publish the merged words
    # through the partial-manifest protocol.
    bloom: dict | None = None


@dataclasses.dataclass
class Pipeline:
    """One pipeline: an immutable logical core plus mutable
    :class:`ExecutionParams`.

    After ``compile_query`` returns, the logical fields (``op``,
    ``sem_hash``, ``deps``, ``output_schema``, ``scan_units``) are
    frozen by contract; all runtime adaptation goes through ``params``.
    """

    pid: int
    sem_hash: str
    op: dict                       # serializable operator tree
    deps: list[int]
    params: ExecutionParams
    output_schema: list[dict]      # ColumnSpec dicts
    scan_units: list[str]          # table files (scan pipelines only)
    final: bool = False
    # fused Pallas kernel the fragment hot loop lowers to, or None — the
    # exec.lower pattern match is decided at plan time so EXPLAIN and
    # per-pipeline reports can show the dispatch without executing.
    # Misses carry the matcher's reason; matches the roofline-chosen
    # tiling estimates (block/resident rows, arithmetic intensity).
    kernel: str | None = None
    kernel_miss_reason: str | None = None
    kernel_roofline: dict | None = None

    # -- convenience views over the mutable params ------------------------
    @property
    def n_fragments(self) -> int:
        return self.params.n_fragments

    @property
    def partitioning(self) -> Partitioning:
        return self.params.partitioning

    @property
    def input_bytes(self) -> int:
        return self.params.est_in_bytes


@dataclasses.dataclass
class PhysicalPlan:
    pipelines: dict[int, Pipeline]
    root_pid: int
    output_names: list[str]

    def stages(self) -> list[list[int]]:
        """Topological stage order (pipelines grouped by dependency depth)."""
        depth: dict[int, int] = {}

        def d(pid: int) -> int:
            if pid not in depth:
                deps = self.pipelines[pid].deps
                depth[pid] = 1 + max((d(x) for x in deps), default=-1)
            return depth[pid]

        for pid in self.pipelines:
            d(pid)
        stages: dict[int, list[int]] = {}
        for pid, dep in depth.items():
            stages.setdefault(dep, []).append(pid)
        return [sorted(stages[k]) for k in sorted(stages)]


def _h(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:24]


def _schema_dicts(names_types) -> list[dict]:
    return [{"name": n, "kind": k, "dtype": dt} for n, k, dt in names_types]


class PhysicalPlanner:
    def __init__(self, catalog: Catalog,
                 config: PlannerConfig | None = None,
                 cost_model=None, calibration=None):
        # cost_model: repro.core.cost.CostModel (built lazily when absent)
        # calibration: repro.sql.calibration.SelectivityCalibration | None
        self.catalog = catalog
        self.config = config or PlannerConfig()
        if cost_model is None:
            from repro.core.cost import CostModel
            cost_model = CostModel()
        self.cost_model = cost_model
        self.calibration = calibration
        self.pipelines: dict[int, Pipeline] = {}
        self._next_pid = 0

    # -- helpers ------------------------------------------------------------
    def _tables_version(self, node: LNode) -> str:
        tables = sorted({n.table for n in _walk(node)
                         if isinstance(n, LScan)})
        return _h([(t, tuple(self.catalog.table(t).files))
                   for t in tables])

    def _est(self, node: LNode) -> tuple[float, float]:
        """(rows, bytes) output estimate of a logical subtree.

        Replaces the old ``_subtree_bytes`` guess, which ignored
        ``LJoin.right`` entirely and charged one constant selectivity
        per filter node: joins now account for both sides (FK→PK match
        fraction from the build side's own selectivity), and filter
        selectivity is estimated per conjunct from catalog zone-map
        hints (numeric min/max ranges, dictionary cardinalities) when
        available, falling back to ``filter_selectivity_guess``.
        """
        if isinstance(node, LScan):
            meta = self.catalog.table(node.table)
            frac = len(node.schema_cols) / max(len(meta.schema), 1)
            return float(meta.rows), meta.total_bytes * frac
        if isinstance(node, LFilter):
            r, b = self._est(node.child)
            sel = self._selectivity(node.pred, node.child)
            cal = self._calibrated_est(node)
            if cal is not None:
                # downward-only: calibration tightens over-estimates;
                # under-estimates keep the conservative static figure so
                # adaptive fleets never exceed their static twin's size
                return min(r * sel, cal[0]), min(b * sel, cal[1])
            return r * sel, b * sel
        if isinstance(node, LProject):
            r, b = self._est(node.child)
            width = len(node.exprs) / max(
                len(_columns_of_logical(node.child)), 1)
            return r, b * min(1.0, width)
        if isinstance(node, LJoin):
            lr, lb = self._est(node.left)
            rr, rb = self._est(node.right)
            base = self._base_rows(node.right)
            match = min(1.0, rr / base) if base > 0 else 1.0
            jr = lr * match
            width = (lb / lr if lr > 0 else 0.0) + \
                (rb / rr if rr > 0 else 0.0)
            return jr, jr * width
        if isinstance(node, LAggregate):
            r, _ = self._est(node.child)
            _, sizes = self._agg_strategy(node)
            if not node.group_cols:
                k = 1.0
            elif sizes:
                k = float(np.prod(sizes))
            else:
                k = float(DIRECT_AGG_MAX_GROUPS)
            rows = min(r, k)
            width = 8.0 * (len(node.group_cols) + len(node.aggs))
            return rows, rows * width
        if isinstance(node, LLimit):
            r, b = self._est(node.child)
            per_row = b / r if r > 0 else 0.0
            rows = min(r, float(node.n))
            return rows, rows * per_row
        kids = node.children()
        if not kids:
            return 0.0, 0.0
        ests = [self._est(c) for c in kids]
        return sum(r for r, _ in ests), sum(b for _, b in ests)

    def _base_rows(self, node: LNode) -> float:
        """Unfiltered row count of the dominant base relation under
        ``node`` (the FK→PK match-fraction denominator)."""
        rows = [self.catalog.table(n.table).rows for n in _walk(node)
                if isinstance(n, LScan)]
        return float(max(rows)) if rows else 0.0

    # -- selectivity estimation ------------------------------------------------
    def _selectivity(self, pred: ast.Expr, child: LNode) -> float:
        sel = 1.0
        for c in ast.conjuncts(pred):
            sel *= self._conjunct_selectivity(c, child)
        return min(1.0, max(sel, 1e-4))

    def _conjunct_selectivity(self, c: ast.Expr, child: LNode) -> float:
        guess = self.config.filter_selectivity_guess
        if isinstance(c, ast.InList) and isinstance(c.term, ast.Col):
            ct = _column_type(child, c.term.name, self.catalog)
            if ct is not None and ct[0] == "dict" and ct[2]:
                return min(1.0, len(c.values) / max(len(ct[2]), 1))
            return guess
        if not isinstance(c, ast.Cmp):
            return guess
        if isinstance(c.left, ast.Col) and isinstance(c.right, ast.Lit):
            col, op, v = c.left.name, c.op, c.right.value
        elif isinstance(c.right, ast.Col) and isinstance(c.left, ast.Lit):
            flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
                    "=": "=", "<>": "<>"}
            col, op, v = c.right.name, flip[c.op], c.left.value
        else:
            return guess            # column-column / expression compare
        if not isinstance(v, (int, float)):
            return guess
        hint = self._column_hint(child, col)
        if hint is None:
            return guess
        lo, hi = hint
        span = float(hi) - float(lo)
        if span <= 0:               # constant column: predicate is 0/1
            ops = {"<": v > lo, "<=": v >= lo, ">": v < lo, ">=": v <= lo,
                   "=": v == lo, "<>": v != lo}
            return 1.0 if ops[op] else 1e-4
        eq = 1.0 / (span + 1.0)     # uniform over an integer-ish domain
        frac = {
            "<": (v - lo) / span,
            "<=": (v - lo) / span + eq,
            ">": (hi - v) / span,
            ">=": (hi - v) / span + eq,
            "=": eq,
            "<>": 1.0 - eq,
        }[op]
        return min(1.0, max(frac, 1e-4))

    def _column_hint(self, node: LNode,
                     col: str) -> tuple[float, float] | None:
        """(min, max) range hint for a column produced by a subtree:
        catalog zone-map roll-ups for base columns, dictionary domains
        for dict columns, None for derived expressions."""
        if isinstance(node, LScan):
            meta = self.catalog.table(node.table)
            if not meta.has_column(col):
                return None
            r = meta.column_range(col)
            if r is not None:
                return r
            spec = meta.spec(col)
            if spec.kind == "dict" and spec.dictionary:
                return (0.0, float(len(spec.dictionary) - 1))
            return None
        if isinstance(node, (LFilter, LSort, LLimit, LAggregate)):
            return self._column_hint(node.child, col)
        if isinstance(node, LProject):
            for n, e in node.exprs:
                if n == col:
                    if isinstance(e, ast.Col):
                        return self._column_hint(node.child, e.name)
                    return None
            return None
        if isinstance(node, LJoin):
            return self._column_hint(node.left, col) or \
                self._column_hint(node.right, col)
        return None

    def _calibrated_est(self, node: LFilter) -> tuple[float, float] | None:
        """(rows, bytes) from a persisted cross-query selectivity
        observation of this exact filter chain over a base scan."""
        if self.calibration is None:
            return None
        from repro.sql.calibration import predicate_key
        preds: list = []
        cur: LNode = node
        while isinstance(cur, LFilter):
            preds.append(cur.pred)
            cur = cur.child
        if not isinstance(cur, LScan):
            return None
        sel = self.calibration.lookup(
            cur.table, predicate_key([expr_to_dict(p) for p in preds]))
        if sel is None:
            return None
        meta = self.catalog.table(cur.table)
        frac = len(cur.schema_cols) / max(len(meta.schema), 1)
        return meta.rows * sel, meta.total_bytes * frac * sel

    def _workers_for_bytes(self, nbytes: int) -> int:
        c = self.config
        return max(1, min(c.max_workers,
                          -(-nbytes // c.bytes_per_worker)))

    def _tier_for_objects(self, objects: int) -> str:
        if objects > self.config.hot_shuffle_object_threshold:
            return "s3-express"
        return "s3-standard"

    def _pick_exchange(self, producers: int, keys, n_dest: int,
                       est_bytes: float) -> tuple[Partitioning, int]:
        """Choose the shuffle strategy (and tier) of one hash exchange
        via ``CostModel.exchange_cost``; returns the partitioning plus
        the estimated producer-side request count for EXPLAIN."""
        from repro.exec.exchange import get_strategy
        forced = self.config.exchange_strategy
        nbytes = max(float(est_bytes), 0.0)
        if forced:
            strat = get_strategy(forced)
            tier = self._tier_for_objects(
                strat.written_objects(producers, n_dest))
            cost = self.cost_model.exchange_cost(
                producers, n_dest, nbytes, strategy=forced, tier=tier)
        else:
            cost, _ = self.cost_model.choose_exchange_strategy(
                producers, n_dest, nbytes, tier_for=self._tier_for_objects)
        strat = get_strategy(cost.strategy)
        part = Partitioning("hash", tuple(keys), n_dest, cost.tier,
                            cost.strategy)
        if cost.strategy == "multilevel":
            part.l0_tier = self.cost_model.l0_tier_choice(
                producers, nbytes, base_tier=cost.tier)
        return part, strat.producer_requests(producers, n_dest)

    def _new_pid(self) -> int:
        pid = self._next_pid
        self._next_pid += 1
        return pid

    # -- main entry ----------------------------------------------------------
    def compile(self, lqp: LNode) -> PhysicalPlan:
        # Peel final-stage nodes (project/sort/limit above aggregation).
        node = lqp
        limit = None
        sort_keys: list[tuple[str, bool]] = []
        if isinstance(node, LLimit):
            limit = node.n
            node = node.child
        if isinstance(node, LSort):
            sort_keys = list(node.keys)
            node = node.child
        post_project: tuple[tuple[str, ast.Expr], ...] | None = None
        agg_node = None
        if isinstance(node, LProject) and isinstance(node.child, LAggregate):
            post_project = node.exprs
            agg_node = node.child
            output_names = [n for n, _ in post_project]
        elif isinstance(node, LProject):
            output_names = [n for n, _ in node.exprs]
        else:
            output_names = sorted(_columns_of_logical(node))

        if agg_node is not None:
            root = self._compile_aggregate(agg_node, post_project,
                                           sort_keys, limit)
        else:
            root = self._compile_streaming_query(node, sort_keys, limit)
        return PhysicalPlan(self.pipelines, root, output_names)

    # -- streaming (no aggregate) ---------------------------------------------
    def _compile_streaming_query(self, node: LNode, sort_keys, limit) -> int:
        op, deps, units, in_bytes, sub = self._stream(node)
        sem = _h(("stream", sub.key(), self._tables_version(sub)))
        n_frag = min(self._workers_for_bytes(in_bytes),
                     max(len(units), 1)) if units else 1
        er, eb = self._est(node)
        schema = _output_schema_of(node, self.catalog)
        needs_final = bool(sort_keys) or limit is not None
        pid = self._new_pid()
        self.pipelines[pid] = Pipeline(
            pid, sem, op, deps,
            ExecutionParams(n_frag, Partitioning("none"),
                            est_in_bytes=in_bytes, est_out_rows=int(er),
                            est_out_bytes=int(eb)),
            schema, units, final=not needs_final)
        if not needs_final:
            return pid
        fsem = _h(("final", sub.key(), sort_keys, limit,
                   self._tables_version(sub)))
        fop = {"t": "final",
               "child": {"t": "scan_exchange", "source": sem,
                         "mode": "all"},
               "project": None,
               "sort_keys": [[k, d] for k, d in sort_keys],
               "limit": limit}
        fpid = self._new_pid()
        fr = min(er, limit) if limit is not None else er
        self.pipelines[fpid] = Pipeline(
            fpid, fsem, fop, [pid],
            ExecutionParams(1, Partitioning("none"),
                            est_in_bytes=int(eb), est_out_rows=int(fr)),
            schema, [], final=True)
        return fpid

    # -- aggregation queries ----------------------------------------------------
    def _compile_aggregate(self, agg: LAggregate, post_project,
                           sort_keys, limit) -> int:
        op, deps, units, in_bytes, sub = self._stream(agg.child)
        strategy, sizes = self._agg_strategy(agg)
        aggs_ser = [[name, fn, expr_to_dict(arg) if arg else None]
                    for name, fn, arg in agg.aggs]
        partial_op = {"t": "partial_agg", "child": op,
                      "group_cols": list(agg.group_cols),
                      "aggs": aggs_ser, "strategy": strategy,
                      "sizes": sizes}
        tv = self._tables_version(agg)
        partial_sem = _h(("partial_agg", agg.key(), tv))
        n_frag = min(self._workers_for_bytes(in_bytes),
                     max(len(units), 1)) if units else 1

        partial_schema = _agg_schema(agg, self.catalog)
        if strategy == "direct" or not agg.group_cols:
            n_dest, merge_frags = 1, 1
        else:
            n_dest = self.config.exchange_partitions or \
                max(1, min(n_frag, 16))
            merge_frags = n_dest
        er_child, eb_child = self._est(agg.child)
        ar, ab = self._est(agg)
        partial_rows = min(er_child, ar * n_frag)
        partial_bytes = min(eb_child, ab * n_frag)
        if n_dest > 1:
            part, est_xreq = self._pick_exchange(
                n_frag, agg.group_cols, n_dest, partial_bytes)
        else:
            part, est_xreq = Partitioning("none"), 0
        ppid = self._new_pid()
        self.pipelines[ppid] = Pipeline(
            ppid, partial_sem, partial_op, deps,
            ExecutionParams(n_frag, part, est_in_bytes=in_bytes,
                            est_out_rows=int(partial_rows),
                            est_out_bytes=int(partial_bytes),
                            est_exchange_requests=est_xreq),
            partial_schema, units)

        merge_aggs = [[name, {"sum": "sum", "count": "sum", "min": "min",
                              "max": "max"}[fn],
                       expr_to_dict(ast.Col(name))]
                      for name, fn, _ in agg.aggs]
        merge_child = {"t": "scan_exchange", "source": partial_sem,
                       "mode": "partition" if n_dest > 1 else "all"}
        merge_op: dict = {"t": "merge_agg", "child": merge_child,
                          "group_cols": list(agg.group_cols),
                          "aggs": merge_aggs, "strategy": strategy,
                          "sizes": sizes}
        if post_project is not None:
            merge_op = {"t": "project", "child": merge_op,
                        "exprs": [[n, expr_to_dict(e)]
                                  for n, e in post_project]}
            partial_types = {s["name"]: s for s in partial_schema}
            out_schema = []
            for n, e in post_project:
                if isinstance(e, ast.Col) and e.name in partial_types:
                    src = partial_types[e.name]
                    out_schema.append({"name": n, "kind": src["kind"],
                                       "dtype": src["dtype"]})
                else:
                    out_schema.append({"name": n, "kind": "num",
                                       "dtype": "<f8"})
        else:
            out_schema = partial_schema

        fold_final = merge_frags == 1
        merge_sem = _h(("merge_agg", agg.key(),
                        tuple((n, e.key()) for n, e in (post_project or ())),
                        tuple(sort_keys) if fold_final else (),
                        limit if fold_final else None, tv))
        if fold_final and (sort_keys or limit is not None):
            merge_op = {"t": "final", "child": merge_op, "project": None,
                        "sort_keys": [[k, d] for k, d in sort_keys],
                        "limit": limit}
        mpid = self._new_pid()
        mr = min(ar, limit) if fold_final and limit is not None else ar
        self.pipelines[mpid] = Pipeline(
            mpid, merge_sem, merge_op, [ppid],
            ExecutionParams(merge_frags, Partitioning("none"),
                            est_in_bytes=int(partial_bytes),
                            est_out_rows=int(mr), est_out_bytes=int(ab)),
            out_schema, [], final=fold_final)
        if fold_final:
            return mpid

        fsem = _h(("final", agg.key(),
                   tuple((n, e.key()) for n, e in (post_project or ())),
                   tuple(sort_keys), limit, tv))
        fop = {"t": "final",
               "child": {"t": "scan_exchange", "source": merge_sem,
                         "mode": "all"},
               "project": None,
               "sort_keys": [[k, d] for k, d in sort_keys],
               "limit": limit}
        fpid = self._new_pid()
        fr = min(ar, limit) if limit is not None else ar
        self.pipelines[fpid] = Pipeline(
            fpid, fsem, fop, [mpid],
            ExecutionParams(1, Partitioning("none"),
                            est_in_bytes=int(ab), est_out_rows=int(fr)),
            out_schema, [], final=True)
        return fpid

    def _agg_strategy(self, agg: LAggregate):
        sizes = []
        for c in agg.group_cols:
            ct = _column_type(agg.child, c, self.catalog)
            if ct is not None and ct[0] == "dict":
                sizes.append(len(ct[2]))
            else:
                return "sort", None
        import numpy as _np
        if not sizes:
            return "direct", []
        if int(_np.prod(sizes)) <= DIRECT_AGG_MAX_GROUPS:
            return "direct", sizes
        return "sort", None

    # -- streaming segment construction ------------------------------------------
    def _stream(self, node: LNode):
        """Compile a streamable subtree; returns
        (op_dict, pipeline_deps, scan_units, input_bytes, logical_subtree)."""
        if isinstance(node, LScan):
            meta = self.catalog.table(node.table)
            op = {"t": "scan_table", "table": node.table,
                  "columns": list(node.schema_cols), "zone_preds": []}
            frac = len(node.schema_cols) / max(len(meta.schema), 1)
            return op, [], list(meta.files), int(meta.total_bytes * frac), \
                node
        if isinstance(node, LFilter):
            op, deps, units, nbytes, sub = self._stream(node.child)
            if op["t"] == "scan_table":
                op["zone_preds"].extend(_zone_preds(node.pred))
            return ({"t": "filter", "child": op,
                     "pred": expr_to_dict(node.pred)},
                    deps, units, nbytes, node)
        if isinstance(node, LProject):
            op, deps, units, nbytes, sub = self._stream(node.child)
            return ({"t": "project", "child": op,
                     "exprs": [[n, expr_to_dict(e)] for n, e in node.exprs]},
                    deps, units, nbytes, node)
        if isinstance(node, LJoin):
            return self._stream_join(node)
        raise TypeError(f"not streamable: {node}")

    def _stream_join(self, node: LJoin):
        probe_op, probe_deps, units, in_bytes, _ = self._stream(node.left)
        prr, prb = self._est(node.left)      # probe exchange payload est
        brr, brb = self._est(node.right)     # build exchange payload est
        payload = sorted(_columns_of_logical(node.right))
        tv_b = self._tables_version(node.right)
        build_sem = _h(("build", node.right.key(), tv_b))

        bop, bdeps, bunits, bbytes, _ = self._stream(node.right)
        build_schema = _output_schema_of(node.right, self.catalog)
        bfrags = min(self._workers_for_bytes(bbytes),
                     max(len(bunits), 1)) if bunits else 1

        if brb <= self.config.broadcast_threshold_bytes:
            # Broadcast join: build side materializes unpartitioned; every
            # probe fragment reads all of it.
            bpid = self._new_pid()
            self.pipelines[bpid] = Pipeline(
                bpid, build_sem, bop, bdeps,
                ExecutionParams(bfrags, Partitioning("none"),
                                est_in_bytes=bbytes,
                                est_out_rows=int(brr),
                                est_out_bytes=int(brb)),
                build_schema, bunits)
            join_op = {"t": "join",
                       "probe": probe_op,
                       "build": {"t": "scan_exchange", "source": build_sem,
                                 "mode": "all"},
                       "probe_key": node.left_key,
                       "build_key": node.right_key,
                       "payload": payload}
            return join_op, probe_deps + [bpid], units, in_bytes, node

        # Repartition join: both sides exchange on the join key; the join
        # runs in a new pipeline with one fragment per hash bucket. The
        # fan-out is sized from the estimated *exchange payload* (filtered
        # output), not the scanned input.
        n_dest = self.config.exchange_partitions or \
            max(1, min(self._workers_for_bytes(int(max(prb, brb))), 16))
        probe_sem = _h(("exchange", node.left.key(), node.left_key,
                        self._tables_version(node.left)))
        probe_schema = _output_schema_of(node.left, self.catalog)
        pfrags = min(self._workers_for_bytes(in_bytes),
                     max(len(units), 1)) if units else 1
        ppart, pxreq = self._pick_exchange(pfrags, (node.left_key,),
                                           n_dest, prb)
        # Semi-join filter pushdown: when the build side's key admits a
        # side-consistent hash (dictionary codes don't — each side owns
        # its own code space), annotate the probe exchange with the
        # build's filter and fold the build identity into the probe sem
        # hash. The fold is unconditional for annotated joins — even if
        # the cost gate says no — so gate-on, gate-off, and runtime-
        # adopted runs share one cache entry, and a filtered probe
        # exchange can never be consumed by a query joining a different
        # build side. ``enabled`` is only the plan-time verdict; the
        # Reoptimizer revisits it at pilot-K time.
        sj = None
        if self.config.semijoin:
            sj_mode = _semijoin_mode(node, self.catalog)
            if sj_mode is not None:
                base = self._base_rows(node.right)
                match = min(1.0, brr / base) if base > 0 else 1.0
                distinct = max(int(brr), 1)
                ben = self.cost_model.semijoin_benefit(
                    producers=pfrags, n_dest=n_dest,
                    probe_bytes=max(prb, 0.0), match_fraction=match,
                    build_distinct=distinct, strategy=ppart.strategy,
                    tier=ppart.tier)
                sj = {"build": build_sem, "key": [node.left_key],
                      "mode": sj_mode,
                      "enabled": bool(ben["benefit_cents"] > 0),
                      "est_match": match, "est_distinct": distinct,
                      "est_rows": int(prr), "base_rows": base,
                      "n_dest": n_dest,
                      "benefit_cents": ben["benefit_cents"],
                      "kept_fraction": ben["kept_fraction"],
                      "fpr": ben["fpr"]}
                probe_sem = _h(("semijoin", probe_sem, build_sem))
        ppid = self._new_pid()
        self.pipelines[ppid] = Pipeline(
            ppid, probe_sem, probe_op, probe_deps,
            ExecutionParams(
                pfrags, ppart,
                est_in_bytes=in_bytes, est_out_rows=int(prr),
                est_out_bytes=int(prb), est_exchange_requests=pxreq,
                semijoin=sj),
            probe_schema, units)
        bpart, bxreq = self._pick_exchange(bfrags, (node.right_key,),
                                           n_dest, brb)
        bpid = self._new_pid()
        self.pipelines[bpid] = Pipeline(
            bpid, build_sem, bop, bdeps,
            ExecutionParams(
                bfrags, bpart,
                est_in_bytes=bbytes, est_out_rows=int(brr),
                est_out_bytes=int(brb), est_exchange_requests=bxreq,
                bloom=({"mode": sj["mode"],
                        "est_distinct": sj["est_distinct"]}
                       if sj else None)),
            build_schema, bunits)
        join_op = {"t": "join",
                   "probe": {"t": "scan_exchange", "source": probe_sem,
                             "mode": "partition"},
                   "build": {"t": "scan_exchange", "source": build_sem,
                             "mode": "partition"},
                   "probe_key": node.left_key,
                   "build_key": node.right_key,
                   "payload": payload}
        # The join continues streaming in a pipeline with n_dest fragments;
        # callers embed join_op and set deps/n_fragments accordingly via
        # the _JoinSegment marker.
        return join_op, [ppid, bpid, ("_n_frag", n_dest)], [], \
            in_bytes, node


# -- logical schema helpers ----------------------------------------------------

def _walk(node: LNode):
    yield node
    for c in node.children():
        yield from _walk(c)


def _columns_of_logical(node: LNode) -> set[str]:
    if isinstance(node, LScan):
        return set(node.schema_cols)
    if isinstance(node, LFilter):
        return _columns_of_logical(node.child)
    if isinstance(node, LProject):
        return {n for n, _ in node.exprs}
    if isinstance(node, LJoin):
        return _columns_of_logical(node.left) | \
            _columns_of_logical(node.right)
    if isinstance(node, LAggregate):
        return set(node.group_cols) | {n for n, _, _ in node.aggs}
    return _columns_of_logical(node.child)


def _column_type(node: LNode, col: str, catalog: Catalog):
    """(kind, dtype, dictionary) for a column produced by a subtree."""
    if isinstance(node, LScan):
        meta = catalog.table(node.table)
        if meta.has_column(col):
            s = meta.spec(col)
            return (s.kind, s.dtype, s.dictionary)
        return None
    if isinstance(node, (LFilter, LSort, LLimit)):
        return _column_type(node.child, col, catalog)
    if isinstance(node, LProject):
        for n, e in node.exprs:
            if n == col:
                if isinstance(e, ast.Col):
                    return _column_type(node.child, e.name, catalog)
                return ("num", "<f8", None)
        return None
    if isinstance(node, LJoin):
        return _column_type(node.left, col, catalog) or \
            _column_type(node.right, col, catalog)
    if isinstance(node, LAggregate):
        if col in node.group_cols:
            return _column_type(node.child, col, catalog)
        for n, fn, _ in node.aggs:
            if n == col:
                return ("num", "<i8" if fn == "count" else "<f8", None)
        return None
    raise TypeError(node)


def _semijoin_mode(node: LJoin, catalog: Catalog) -> str | None:
    """Key-hash mode for a semi-join filter on ``node``'s join key, or
    None if the key cannot be hashed consistently on both sides.

    Dictionary-encoded keys are ineligible: each side assigns its own
    code space, so hashing codes risks false *negatives* — the one
    failure mode a semi-join filter must never have. ``u32`` (truncating
    cast, kernel-eligible) needs integer keys on both sides; any other
    numeric pair falls back to the 64-bit column hash.
    """
    lt = _column_type(node.left, node.left_key, catalog)
    rt = _column_type(node.right, node.right_key, catalog)
    if not lt or not rt or lt[0] != "num" or rt[0] != "num":
        return None

    def _is_int(dtype: str) -> bool:
        return "i" in dtype or "u" in dtype

    if _is_int(lt[1]) and _is_int(rt[1]):
        return "u32"
    return "hash64"


def _output_schema_of(node: LNode, catalog: Catalog) -> list[dict]:
    out = []
    for c in sorted(_columns_of_logical(node)):
        ct = _column_type(node, c, catalog)
        kind, dtype, _ = ct if ct else ("num", "<f8", None)
        if kind == "bytes":
            continue  # opaque strings are pruned before execution
        out.append({"name": c, "kind": kind, "dtype": dtype})
    return out


def _agg_schema(agg: LAggregate, catalog: Catalog) -> list[dict]:
    out = []
    for c in agg.group_cols:
        ct = _column_type(agg.child, c, catalog)
        kind, dtype, _ = ct if ct else ("num", "<i8", None)
        out.append({"name": c, "kind": kind, "dtype": "<i8"})
    for name, fn, _ in agg.aggs:
        out.append({"name": name, "kind": "num", "dtype": "<f8"})
    return out


def _project_schema(exprs) -> list[dict]:
    return [{"name": n, "kind": "num", "dtype": "<f8"} for n, _ in exprs]


def _zone_preds(pred: ast.Expr) -> list[list]:
    """Extract (col, op, literal) conjuncts usable for row-group pruning."""
    out = []
    for c in ast.conjuncts(pred):
        if isinstance(c, ast.Cmp) and isinstance(c.left, ast.Col) \
                and isinstance(c.right, ast.Lit) and c.op != "<>":
            op = "==" if c.op == "=" else c.op
            out.append([c.left.name, op, c.right.value])
        elif isinstance(c, ast.Cmp) and isinstance(c.right, ast.Col) \
                and isinstance(c.left, ast.Lit) and c.op != "<>":
            flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "=": "=="}
            out.append([c.right.name, flip[c.op], c.left.value])
        elif isinstance(c, ast.InList) and isinstance(c.term, ast.Col) \
                and all(isinstance(v, ast.Lit) for v in c.values):
            out.append([c.term.name, "in",
                        [v.value for v in c.values]])
    return out


def compile_query(lqp: LNode, catalog: Catalog,
                  config: PlannerConfig | None = None,
                  cost_model=None, calibration=None) -> PhysicalPlan:
    planner = PhysicalPlanner(catalog, config, cost_model=cost_model,
                              calibration=calibration)
    plan = planner.compile(lqp)
    _fix_join_segments(plan, planner)
    _annotate_kernels(plan)
    return plan


def _annotate_kernels(plan: PhysicalPlan) -> None:
    """Record which pipelines the kernel dispatch layer will lower —
    kernel name and roofline tiling on a match, the matcher's miss
    reason otherwise (``final`` ops dispatch on their child when the
    top-k arm misses; ``kernel_info`` handles that)."""
    from repro.exec.lower import enabled, kernel_info
    if not enabled():
        return
    for p in plan.pipelines.values():
        info = kernel_info(p.op)
        p.kernel = info["kernel"]
        p.kernel_miss_reason = info["miss"]
        p.kernel_roofline = info["tiling"]


def _fix_join_segments(plan: PhysicalPlan,
                       planner: PhysicalPlanner) -> None:
    """Resolve the ('_n_frag', D) markers emitted for repartition joins:
    the pipeline embedding such a join must have D fragments and no scan
    units — and its own output exchange, if any, is re-picked for the
    corrected producer count."""
    for p in plan.pipelines.values():
        markers = [d for d in p.deps if isinstance(d, tuple)]
        if markers:
            p.deps = [d for d in p.deps if not isinstance(d, tuple)]
            p.params.n_fragments = markers[0][1]
            p.scan_units = []
            part = p.params.partitioning
            if part.kind == "hash":
                p.params.partitioning, p.params.est_exchange_requests = \
                    planner._pick_exchange(p.params.n_fragments,
                                           part.keys, part.n_dest,
                                           p.params.est_out_bytes)
