"""Kernel dispatch: lower matched fragment op trees to fused Pallas kernels.

The physical→fragment compilation path runs every fragment as a generic
jit-compiled jnp operator chain (``repro.exec.fragment``). This module is
the dispatch layer on top: a pattern matcher over the serialized fragment
op tree recognizes supported hot-loop chains and emits a kernel-backed
program with the exact same ``blocks → (columns, mask)`` signature, so the
caller swaps it in transparently and falls back to the generic chain — bit
compatibly — for every unmatched shape.

Matched patterns (paper section 3.3's one-pass vectorized worker loop):

  ``scan → [filter…] → partial_agg/merge_agg`` (direct, no groups)
      → :func:`repro.kernels.ops.fused_filter_agg` — predicate and
        aggregate inputs evaluate inside the kernel over VMEM column
        tiles; one (1, A) accumulator tile crosses the row-block grid.
        TPC-H Q6 is the canonical instance.

  ``scan → [filter…] → partial_agg/merge_agg`` (direct, K groups)
      → :func:`repro.kernels.ops.fused_groupby` when every aggregate is
        a sum/count (pure one-hot matmul on the MXU, TPC-H Q1), else
        :func:`~repro.kernels.ops.fused_groupby_minmax`, which adds
        masked broadcast min/max reductions over the same one-hot tile.

  ``scan → [filter…] → partial_agg`` (sort strategy)
      → :func:`repro.kernels.ops.fused_sort_agg` — fully VMEM-resident
        bitonic sort by group keys plus a segmented scan; large or
        unsized group domains that the one-hot kernels reject.

  ``join(probe=[filter…]→scan, build=scan) → [filter…] → partial_agg``
      → :func:`repro.kernels.ops.fused_join_probe_agg` — the sorted
        build side stays VMEM-resident; each probe block binary-searches
        it in-kernel and folds straight into the aggregation tile
        (TPC-H Q12/Q14/Q19).

  ``final`` with ORDER BY + LIMIT over ``[filter…]→scan``
      → :func:`repro.kernels.ops.fused_topk` — bitonic sort with per-key
        descending directions; only the top ``limit`` rows stay valid,
        and the coordinator's host sort is idempotent on them (Q3's
        final pipeline).

Block sizes and resident capacities are not hand constants: every arm
asks ``repro.analysis.roofline`` for a :class:`KernelTiling` derived from
the kernel's working set, the VMEM budget, and its arithmetic intensity
relative to the machine balance. The tiling key joins the compiled-
program cache key (``dispatch_signature``) and its estimates surface in
EXPLAIN via :func:`kernel_info`.

Lowering is value-semantics-preserving: predicates/arguments are the same
compiled expressions the generic path uses, and in interpret mode (CPU CI)
the kernels accumulate in float64 like the jnp path. ``set_enabled`` /
``disabled()`` switch the layer off globally — used by the parity tests
and the fused-vs-generic benchmark rows.

Adding a new fused kernel: add a match arm in :func:`match_fragment_ex`
(return a :class:`Match` with a roofline tiling, or a precise miss
reason), add the kernel factory under ``repro.kernels`` plus its tiling
model in ``repro.analysis.roofline``, and emit the lowered program in
:func:`lower_fragment`; everything downstream (jit caching, stats,
explain output, the fusion benchmark) picks it up from the returned
:class:`Lowered`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline
from repro.exec import operators as xops
from repro.exec.expr import compile_expr, expr_from_dict
from repro.exec.operators import decode_group_ids, mixed_radix_strides
from repro.kernels import ops as kops

# One-hot grouped aggregation materializes a (block, K) matrix in VMEM;
# the cap is the largest K whose tile fits the roofline VMEM budget at
# the minimum block (4096 on v5e — well below the planner's direct-agg
# strategy bound).
MAX_KERNEL_GROUPS = roofline.onehot_group_capacity()
AGG_FNS = frozenset({"sum", "count", "min", "max"})
ONEHOT_AGG_FNS = frozenset({"sum", "count"})  # pure-matmul groupby subset

# Back-compat aliases (pre-PR9 names).
UNGROUPED_AGG_FNS = AGG_FNS
GROUPED_AGG_FNS = ONEHOT_AGG_FNS

_LEAF_OPS = ("scan_table", "scan_exchange")

_enabled = os.environ.get("SKYRISE_DISABLE_FUSED", "") not in ("1", "true")


def enabled() -> bool:
    return _enabled


def set_enabled(flag: bool) -> bool:
    """Toggle kernel dispatch globally; returns the previous setting."""
    global _enabled
    prev, _enabled = _enabled, bool(flag)
    return prev


@contextlib.contextmanager
def disabled():
    """Run a scope on the generic jnp path (parity tests, benchmarks)."""
    prev = set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)


_interpret_gate = os.environ.get(
    "SKYRISE_INTERPRET_COST_GATE", "1") not in ("0", "false")


def set_interpret_gate(flag: bool) -> bool:
    """Toggle the interpret-mode cost gate; returns the previous setting."""
    global _interpret_gate
    prev, _interpret_gate = _interpret_gate, bool(flag)
    return prev


@contextlib.contextmanager
def interpret_gate_disabled():
    """Match compute-bound resident kernels even on interpreted backends
    (kernel parity tests exercise them regardless of dispatch policy)."""
    prev = set_interpret_gate(False)
    try:
        yield
    finally:
        set_interpret_gate(prev)


@dataclasses.dataclass
class Match:
    kernel: str                  # kernel name (see module docstring)
    leaf: dict                   # probe-side scan op feeding the chain
    preds: list[dict]            # post-join/agg-level predicate dicts
    group_cols: list[str]
    sizes: list[int]
    aggs: list                   # [name, fn, arg expr dict | None]
    tiling: roofline.KernelTiling
    # join_probe_agg only:
    build_leaf: dict | None = None
    probe_preds: list = dataclasses.field(default_factory=list)
    build_preds: list = dataclasses.field(default_factory=list)
    probe_key: str | None = None
    build_key: str | None = None
    payload: list = dataclasses.field(default_factory=list)
    # topk only:
    sort_keys: list = dataclasses.field(default_factory=list)
    limit: int | None = None
    # bloom_filter only (probe_key doubles as the bloom key column):
    bloom_bits: int | None = None
    bloom_k: int | None = None


@dataclasses.dataclass
class Lowered:
    fn: Callable                 # blocks → (columns, mask)
    leaves: list[tuple[str, dict]]
    kernel: str
    tiling: roofline.KernelTiling | None = None


def _expr_cols(d: dict, out: set) -> None:
    if d.get("t") == "col":
        out.add(d["name"])
    for v in d.values():
        if isinstance(v, dict):
            _expr_cols(v, out)
        elif isinstance(v, list):
            for x in v:
                if isinstance(x, dict):
                    _expr_cols(x, out)


def _peel_filters(op: dict) -> tuple[list[dict], dict]:
    preds: list[dict] = []
    while op.get("t") == "filter":
        preds.append(op["pred"])
        op = op["child"]
    return preds, op


def _leaf_width(leaf: dict, fallback: int) -> int:
    """Column count feeding a kernel: exact for table scans, an estimate
    for exchange scans whose schema is only known at runtime."""
    if leaf.get("t") == "scan_table":
        return len(leaf["columns"])
    return max(fallback, 1)


def match_fragment_ex(op: dict) -> tuple[Match | None, str | None]:
    """Recognize a fragment op tree one of the fused kernels covers.

    Returns ``(match, None)`` on success or ``(None, miss_reason)`` — a
    short human-readable account of the first structural property that
    disqualified the tree, surfaced in EXPLAIN ANALYZE so erosion of
    match coverage is observable.
    """
    t = op.get("t")
    if t == "final":
        return _match_final(op)
    if t in ("partial_agg", "merge_agg"):
        return _match_agg(op)
    if t == "semijoin_probe":
        return _match_semijoin(op)
    return None, f"no fusible root (op={t})"


def _interpret_backend() -> bool:
    return jax.default_backend() != "tpu"


def _match_final(op: dict):
    sort_keys = [(k, bool(d)) for k, d in (op.get("sort_keys") or [])]
    limit = op.get("limit")
    if not sort_keys or limit is None:
        return None, "final lacks ORDER BY + LIMIT (no top-k)"
    if op.get("project"):
        return None, "final has a post-project"
    preds, child = _peel_filters(op["child"])
    if child.get("t") not in _LEAF_OPS:
        return None, f"unsupported op under final (op={child.get('t')})"
    needed: set[str] = {k for k, _ in sort_keys}
    for p in preds:
        _expr_cols(p, needed)
    if child["t"] == "scan_table" and not needed <= set(child["columns"]):
        missing = sorted(needed - set(child["columns"]))
        return None, f"columns {missing} absent from scan"
    tiling = roofline.resident_sort_tiling(
        "topk", n_arrays=_leaf_width(child, len(sort_keys) + 4) + 2)
    if _interpret_gate and _interpret_backend() \
            and roofline.interpret_prefers_jnp(tiling):
        return None, "interpret_cost"
    return Match("topk", child, preds, [], [], [], tiling,
                 sort_keys=sort_keys, limit=int(limit)), None


def _match_semijoin(op: dict):
    """``semijoin_probe`` wrapper (attached by the fragment driver when a
    probe-side spec carries a kernel-eligible Bloom filter): fuse the
    scan chain's predicate with the in-kernel Bloom membership test. The
    filter words arrive as the runtime ``__bloom`` pseudo-leaf — never
    baked into the trace, so the compiled program is shared across
    queries and across filter contents of the same capacity bucket."""
    key = op["key"]
    preds, child = _peel_filters(op["child"])
    if child.get("t") not in _LEAF_OPS:
        return None, (f"semijoin probe over non-scan chain "
                      f"(op={child.get('t')})")
    needed: set[str] = {key}
    for p in preds:
        _expr_cols(p, needed)
    if child["t"] == "scan_table" and not needed <= set(child["columns"]):
        missing = sorted(needed - set(child["columns"]))
        return None, f"columns {missing} absent from scan"
    tiling = roofline.bloom_probe_tiling(
        n_cols=_leaf_width(child, len(needed)), n_bits=int(op["bits"]))
    return Match("bloom_filter", child, preds, [], [], [], tiling,
                 probe_key=key, bloom_bits=int(op["bits"]),
                 bloom_k=int(op["k"])), None


def _match_agg(op: dict):
    strategy = op.get("strategy")
    group_cols = list(op["group_cols"])
    sizes = list(op["sizes"] or [])
    aggs = list(op["aggs"])
    fns = {fn for _, fn, _ in aggs}
    if not fns <= AGG_FNS:
        return None, f"aggregate fns {sorted(fns - AGG_FNS)} unsupported"
    needed: set[str] = set(group_cols)
    for _, _, arg in aggs:
        if arg is not None:
            _expr_cols(arg, needed)
    preds, child = _peel_filters(op["child"])
    for p in preds:
        _expr_cols(p, needed)

    if strategy == "sort":
        if child.get("t") not in _LEAF_OPS:
            return None, (f"sort-strategy aggregate over non-scan child "
                          f"(op={child.get('t')})")
        if child["t"] == "scan_table" and \
                not needed <= set(child["columns"]):
            missing = sorted(needed - set(child["columns"]))
            return None, f"columns {missing} absent from scan"
        tiling = roofline.resident_sort_tiling(
            "sort_agg", n_arrays=2 + len(group_cols) + len(aggs))
        if _interpret_gate and _interpret_backend() \
                and roofline.interpret_prefers_jnp(tiling):
            return None, "interpret_cost"
        return Match("sort_agg", child, preds, group_cols, sizes, aggs,
                     tiling), None
    if strategy != "direct":
        return None, f"aggregation strategy {strategy!r} unsupported"

    if group_cols:
        if len(sizes) != len(group_cols):
            return None, "group columns not dict-coded (no sizes)"
        K = int(np.prod(sizes))
        if K > MAX_KERNEL_GROUPS:
            return None, (f"group domain {K} exceeds the one-hot VMEM cap "
                          f"{MAX_KERNEL_GROUPS}")
    else:
        K = 0

    if child.get("t") == "join":
        return _match_join(op, child, preds, needed, group_cols, sizes,
                           aggs, K)
    if child.get("t") not in _LEAF_OPS:
        return None, f"unsupported child op {child.get('t')}"
    if child["t"] == "scan_table" and not needed <= set(child["columns"]):
        missing = sorted(needed - set(child["columns"]))
        return None, f"columns {missing} absent from scan"
    if not group_cols:
        kernel = "filter_agg"
        tiling = roofline.filter_agg_tiling(
            n_cols=_leaf_width(child, len(needed)), n_aggs=len(aggs))
    else:
        kernel = ("groupby_onehot" if fns <= ONEHOT_AGG_FNS
                  else "segmented_minmax")
        tiling = roofline.groupby_tiling(
            kernel, n_cols=_leaf_width(child, len(needed)),
            n_aggs=len(aggs), n_groups=K)
    return Match(kernel, child, preds, group_cols, sizes, aggs,
                 tiling), None


def _match_join(op, join, preds, needed, group_cols, sizes, aggs, K):
    probe_preds, probe_leaf = _peel_filters(join["probe"])
    if probe_leaf.get("t") not in _LEAF_OPS:
        return None, (f"join probe side is not a scan chain "
                      f"(op={probe_leaf.get('t')})")
    build_preds, build_leaf = _peel_filters(join["build"])
    if build_leaf.get("t") not in _LEAF_OPS:
        return None, (f"join build side is not a scan chain "
                      f"(op={build_leaf.get('t')})")
    payload = list(join["payload"])
    probe_key, build_key = join["probe_key"], join["build_key"]
    for p in probe_preds:
        _expr_cols(p, needed)
    needed.add(probe_key)
    if probe_leaf["t"] == "scan_table":
        avail = set(probe_leaf["columns"]) | set(payload)
        if not needed <= avail:
            missing = sorted(needed - avail)
            return None, f"columns {missing} absent from join inputs"
    if build_leaf["t"] == "scan_table":
        bneeded = {build_key} | set(payload)
        for p in build_preds:
            _expr_cols(p, bneeded)
        if not bneeded <= set(build_leaf["columns"]):
            missing = sorted(bneeded - set(build_leaf["columns"]))
            return None, f"columns {missing} absent from build scan"
    tiling = roofline.join_probe_tiling(
        n_cols=_leaf_width(probe_leaf, len(needed)),
        n_payload=len(payload), n_aggs=len(aggs), n_groups=K)
    return Match("join_probe_agg", probe_leaf, preds, group_cols, sizes,
                 aggs, tiling, build_leaf=build_leaf,
                 probe_preds=probe_preds, build_preds=build_preds,
                 probe_key=probe_key, build_key=build_key,
                 payload=payload), None


def match_fragment(op: dict) -> Match | None:
    """Recognize a fragment op tree one of the fused kernels covers."""
    m, _ = match_fragment_ex(op)
    return m


def kernel_miss_reason(op: dict) -> str | None:
    """Why ``op`` stays on the generic jnp path (None if it matched)."""
    _, miss = match_fragment_ex(op)
    return miss


def dispatch_signature(op: dict) -> tuple[str, tuple | None, dict]:
    """(kernel name or "", tiling cache key, effective op) for an op tree
    — matching only, no program construction, so the compiled-program
    cache can form its key cheaply. ``final`` ops whose top-k arm misses
    dispatch on their child (the coordinator's host sort still runs)."""
    m, _ = match_fragment_ex(op)
    if m is not None:
        return m.kernel, m.tiling.key, op
    if op.get("t") == "final":
        return dispatch_signature(op["child"])
    return "", None, op


def match_kernel(op: dict) -> str | None:
    """Name of the fused kernel ``op`` lowers to, or None (plan/explain)."""
    kernel, _, _ = dispatch_signature(op)
    return kernel or None


def kernel_info(op: dict) -> dict:
    """Dispatch summary for EXPLAIN: ``{"kernel", "miss", "tiling"}``.

    ``kernel`` is the fused kernel the pipeline will actually run with
    (for ``final`` ops, possibly on the child under the host sort) or
    None; ``miss`` is the miss reason for the op that executes; ``tiling``
    the roofline tiling estimates of the matched kernel.
    """
    m, miss = match_fragment_ex(op)
    if m is None and op.get("t") == "final":
        info = kernel_info(op["child"])
        if info["kernel"] is None:
            # neither arm matched: the final's own reason names the
            # blocker (the child's is just "no fusible root")
            info["miss"] = miss
        return info
    if m is None:
        return {"kernel": None, "miss": miss, "tiling": None}
    return {"kernel": m.kernel, "miss": None,
            "tiling": m.tiling.as_dict()}


def _compile_pred(preds: list[dict]):
    if not preds:
        return None
    fns = [compile_expr(expr_from_dict(p)) for p in preds]

    def pred(cols):
        out = fns[0](cols)
        for f in fns[1:]:
            out = out & f(cols)
        return out
    return pred


def _is_pow2(n: int) -> bool:
    return n > 0 and n & (n - 1) == 0


def lower_fragment(op: dict) -> Lowered | None:
    """Build the kernel-backed program for a matched fragment op tree.

    The returned function consumes the same leaf blocks as the generic
    chain and produces outputs identical in names, shapes, dtypes, and
    mask semantics to the generic operators — callers need no
    special-casing beyond swapping the function.
    """
    m = match_fragment(op)
    if m is None:
        return None
    if m.kernel == "topk":
        return _lower_topk(m)
    if m.kernel == "join_probe_agg":
        return _lower_join_probe(op, m)
    if m.kernel == "sort_agg":
        return _lower_sort_agg(op, m)
    if m.kernel == "bloom_filter":
        return _lower_bloom_filter(m)
    return _lower_direct_agg(m)


def _agg_closures(aggs):
    names = [name for name, _, _ in aggs]
    fns = [(fn, compile_expr(expr_from_dict(arg)) if arg is not None
            else None) for _, fn, arg in aggs]
    return names, fns


def _gid_fn(group_cols, sizes):
    strides = mixed_radix_strides(sizes)

    def gid(cols):
        g = jnp.zeros(cols[group_cols[0]].shape, jnp.int32)
        for c, s in zip(group_cols, strides):
            g = g + cols[c].astype(jnp.int32) * s
        return g
    return gid


def _lower_direct_agg(m: Match) -> Lowered:
    pred = _compile_pred(m.preds)
    agg_names, aggs = _agg_closures(m.aggs)
    leaf_id = "in0"
    leaves = [(leaf_id, m.leaf)]
    block = m.tiling.block_rows

    if m.kernel == "filter_agg":
        def fn(blocks):
            cols, mask = blocks[leaf_id]
            acc = kops.fused_filter_agg(cols, mask, pred=pred, aggs=aggs,
                                        block=block)
            out = {name: acc[j].reshape(1).astype(jnp.float64)
                   for j, name in enumerate(agg_names)}
            return out, jnp.ones((1,), bool)
        return Lowered(fn, leaves, m.kernel, m.tiling)

    # grouped: mixed-radix group id over dict-coded key columns, same
    # code assignment as operators.make_direct_agg
    K = int(np.prod(m.sizes))
    gid_fn = _gid_fn(list(m.group_cols), list(m.sizes))
    group_cols, sizes = list(m.group_cols), list(m.sizes)
    grouped = (kops.fused_groupby if m.kernel == "groupby_onehot"
               else kops.fused_groupby_minmax)

    def fn(blocks):
        cols, mask = blocks[leaf_id]
        tile = grouped(cols, mask, pred=pred, gid_fn=gid_fn, aggs=aggs,
                       n_groups=K, block=block)
        out = dict(decode_group_ids(group_cols, sizes, K))
        for j, name in enumerate(agg_names):
            out[name] = tile[:, j].astype(jnp.float64)
        return out, tile[:, -1] > 0
    return Lowered(fn, leaves, m.kernel, m.tiling)


def _lower_sort_agg(op: dict, m: Match) -> Lowered:
    pred = _compile_pred(m.preds)
    aggs3 = [(name, fn, compile_expr(expr_from_dict(arg))
              if arg is not None else None) for name, fn, arg in m.aggs]
    group_cols = list(m.group_cols)
    # identical-semantics XLA path for capacities the resident bitonic
    # network can't take (non-power-of-two or past the VMEM cap)
    generic = xops.make_sort_agg(
        group_cols, [(n, fn, expr_from_dict(a) if a else None)
                     for n, fn, a in m.aggs])
    cap = m.tiling.resident_rows
    leaf_id = "in0"

    def fn(blocks):
        cols, mask = blocks[leaf_id]
        n = int(mask.shape[0])
        if not _is_pow2(n) or n > cap:
            m2 = mask if pred is None else mask & pred(cols)
            return generic(cols, m2)
        out, om = kops.fused_sort_agg(cols, mask, group_cols=group_cols,
                                      pred=pred, aggs=aggs3)
        out = {c: (v.astype(jnp.int64) if c in group_cols
                   else v.astype(jnp.float64)) for c, v in out.items()}
        return out, om
    return Lowered(fn, [(leaf_id, m.leaf)], m.kernel, m.tiling)


def _lower_join_probe(op: dict, m: Match) -> Lowered:
    agg_pred = _compile_pred(m.preds)
    probe_pred = _compile_pred(m.probe_preds)
    build_pred = _compile_pred(m.build_preds)
    # inside the kernel the probe filters evaluate after the payload
    # gather; the conjunction with the hit mask is order-independent
    kernel_pred = _compile_pred(m.probe_preds + m.preds)
    agg_names, aggs = _agg_closures(m.aggs)
    K = int(np.prod(m.sizes)) if m.group_cols else 0
    gid_fn = (_gid_fn(list(m.group_cols), list(m.sizes))
              if m.group_cols else None)
    group_cols, sizes = list(m.group_cols), list(m.sizes)
    probe_key, build_key = m.probe_key, m.build_key
    payload = list(m.payload)
    block, cap = m.tiling.block_rows, m.tiling.resident_rows
    leaves = [("in0", m.leaf), ("in1", m.build_leaf)]
    # identical-semantics XLA path for build sides past the VMEM cap
    join_generic = xops.make_pk_join_probe(probe_key, build_key, payload)
    agg_generic, _ = xops.make_direct_agg(
        group_cols, sizes,
        [(n, fn, expr_from_dict(a) if a else None)
         for n, fn, a in m.aggs])

    def fn(blocks):
        pcols, pmask = blocks["in0"]
        bcols, bmask = blocks["in1"]
        if build_pred is not None:
            bmask = bmask & build_pred(bcols)
        if int(bmask.shape[0]) > cap:
            pm = pmask if probe_pred is None else pmask & probe_pred(pcols)
            jcols, jmask = join_generic(pcols, pm, bcols, bmask)
            if agg_pred is not None:
                jmask = jmask & agg_pred(jcols)
            return agg_generic(jcols, jmask)
        # XLA prepass: sort the build side by key once (masked rows to
        # the end under the sentinel), mirroring make_pk_join_probe
        kdt = kops.join_key_dtype()
        sentinel = jnp.asarray(jnp.iinfo(kdt).max, kdt)
        bk = jnp.where(bmask, bcols[build_key].astype(kdt), sentinel)
        order = jnp.argsort(bk)
        spay = {c: bcols[c][order] for c in payload if c not in pcols}
        res = kops.fused_join_probe_agg(
            pcols, pmask, bk[order], spay, probe_key=probe_key,
            pred=kernel_pred, gid_fn=gid_fn, aggs=aggs, n_groups=K,
            block=block)
        if not K:
            out = {name: res[j].reshape(1).astype(jnp.float64)
                   for j, name in enumerate(agg_names)}
            return out, jnp.ones((1,), bool)
        out = dict(decode_group_ids(group_cols, sizes, K))
        for j, name in enumerate(agg_names):
            out[name] = res[:, j].astype(jnp.float64)
        return out, res[:, -1] > 0
    return Lowered(fn, leaves, m.kernel, m.tiling)


def _lower_bloom_filter(m: Match) -> Lowered:
    """Probe-side scan chain with an in-kernel Bloom membership test.

    The program keeps the generic mask semantics (predicate-surviving
    rows stay valid) and emits the Bloom verdict as the reserved
    ``__bloom_pass`` column, so the fragment driver can count killed
    rows exactly and compact before partitioning. The jnp fallback
    (``exec.fragment._build``'s ``semijoin_probe`` arm) produces the
    same column bit-for-bit — both paths share one hash family."""
    from repro.kernels.bloom import bloom_probe_jnp
    pred = _compile_pred(m.preds)
    key, bits, k = m.probe_key, m.bloom_bits, m.bloom_k
    block = m.tiling.block_rows
    leaf_id = "in0"

    def fn(blocks):
        cols, mask = blocks[leaf_id]
        words = blocks["__bloom"][0]["words"]
        m2 = mask if pred is None else mask & pred(cols)
        if int(mask.shape[0]) == 0:
            hit = bloom_probe_jnp(cols[key], words, bits=bits, k=k) & m2
        else:
            hit = kops.fused_bloom_filter(
                {key: cols[key]}, m2, pred=None, key=key, words=words,
                bits=bits, k=k, block=block)
        out = dict(cols)
        out["__bloom_pass"] = hit.astype(jnp.int32)
        return out, m2
    return Lowered(fn, [(leaf_id, m.leaf),
                        ("__bloom", {"t": "bloom_words"})],
                   "bloom_filter", m.tiling)


def _lower_topk(m: Match) -> Lowered:
    pred = _compile_pred(m.preds)
    sort_keys, limit, cap = list(m.sort_keys), m.limit, m.tiling.resident_rows
    leaf_id = "in0"

    def fn(blocks):
        cols, mask = blocks[leaf_id]
        n = int(mask.shape[0])
        if not _is_pow2(n) or n > cap:
            # host sort handles it — pass the filtered batch through
            return cols, (mask if pred is None else mask & pred(cols))
        return kops.fused_topk(cols, mask, pred=pred,
                               sort_keys=sort_keys, limit=limit)
    return Lowered(fn, [(leaf_id, m.leaf)], m.kernel, m.tiling)
