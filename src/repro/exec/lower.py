"""Kernel dispatch: lower matched fragment op trees to fused Pallas kernels.

The physical→fragment compilation path runs every fragment as a generic
jit-compiled jnp operator chain (``repro.exec.fragment``). This module is
the dispatch layer on top: a pattern matcher over the serialized fragment
op tree recognizes supported hot-loop chains and emits a kernel-backed
program with the exact same ``blocks → (columns, mask)`` signature, so the
caller swaps it in transparently and falls back to the generic chain — bit
compatibly — for every unmatched shape.

Matched patterns (paper section 3.3's one-pass vectorized worker loop):

  ``scan → [filter…] → partial_agg`` (direct, no groups)
      → :func:`repro.kernels.ops.fused_filter_agg` — predicate and
        aggregate inputs evaluate inside the kernel over VMEM column
        tiles; one (1, A) accumulator tile crosses the row-block grid.
        TPC-H Q6 is the canonical instance.

  ``scan → [filter…] → partial_agg`` (direct, K = prod(sizes) groups)
      → :func:`repro.kernels.ops.fused_groupby` — group ids become a
        one-hot matrix against the aggregate inputs; grouped sums run on
        the MXU, scatter-free. TPC-H Q1 is the canonical instance.

Lowering is value-semantics-preserving: predicates/arguments are the same
compiled expressions the generic path uses, and in interpret mode (CPU CI)
the kernels accumulate in float64 like the jnp path. ``set_enabled`` /
``disabled()`` switch the layer off globally — used by the parity tests
and the fused-vs-generic benchmark rows.

Adding a new fused kernel: extend :func:`match_fragment` with the new op
shape, add the kernel factory under ``repro.kernels``, and emit its
lowered program in :func:`lower_fragment`; everything downstream (jit
caching, stats, explain output) picks it up from the returned
:class:`Lowered`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.exec.expr import compile_expr, expr_from_dict
from repro.exec.operators import decode_group_ids, mixed_radix_strides
from repro.kernels import ops as kops

# One-hot grouped aggregation materializes a (block, K) matrix in VMEM;
# cap K well below the direct-agg strategy bound so the tile stays small.
MAX_KERNEL_GROUPS = 4096
UNGROUPED_AGG_FNS = frozenset({"sum", "count", "min", "max"})
GROUPED_AGG_FNS = frozenset({"sum", "count"})   # one-hot matmul can't min/max

_enabled = os.environ.get("SKYRISE_DISABLE_FUSED", "") not in ("1", "true")


def enabled() -> bool:
    return _enabled


def set_enabled(flag: bool) -> bool:
    """Toggle kernel dispatch globally; returns the previous setting."""
    global _enabled
    prev, _enabled = _enabled, bool(flag)
    return prev


@contextlib.contextmanager
def disabled():
    """Run a scope on the generic jnp path (parity tests, benchmarks)."""
    prev = set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)


@dataclasses.dataclass
class Match:
    kernel: str                  # "filter_agg" | "groupby_onehot"
    leaf: dict                   # the scan_table op feeding the chain
    preds: list[dict]            # filter predicate expr dicts (conjoined)
    group_cols: list[str]
    sizes: list[int]
    aggs: list                   # [name, fn, arg expr dict | None]


@dataclasses.dataclass
class Lowered:
    fn: Callable                 # blocks → (columns, mask)
    leaves: list[tuple[str, dict]]
    kernel: str


def _expr_cols(d: dict, out: set) -> None:
    if d.get("t") == "col":
        out.add(d["name"])
    for v in d.values():
        if isinstance(v, dict):
            _expr_cols(v, out)
        elif isinstance(v, list):
            for x in v:
                if isinstance(x, dict):
                    _expr_cols(x, out)


def match_fragment(op: dict) -> Match | None:
    """Recognize a fragment op tree one of the fused kernels covers."""
    if op.get("t") != "partial_agg" or op.get("strategy") != "direct":
        return None
    preds: list[dict] = []
    child = op["child"]
    while child.get("t") == "filter":
        preds.append(child["pred"])
        child = child["child"]
    if child.get("t") != "scan_table":
        return None
    group_cols = list(op["group_cols"])
    sizes = list(op["sizes"] or [])
    fns = {fn for _, fn, _ in op["aggs"]}
    if group_cols:
        if len(sizes) != len(group_cols):
            return None
        if int(np.prod(sizes)) > MAX_KERNEL_GROUPS:
            return None
        if not fns <= GROUPED_AGG_FNS:
            return None
        kernel = "groupby_onehot"
    else:
        if not fns <= UNGROUPED_AGG_FNS:
            return None
        kernel = "filter_agg"
    needed: set[str] = set(group_cols)
    for p in preds:
        _expr_cols(p, needed)
    for _, _, arg in op["aggs"]:
        if arg is not None:
            _expr_cols(arg, needed)
    if not needed <= set(child["columns"]):
        return None
    return Match(kernel, child, preds, group_cols, sizes, list(op["aggs"]))


def match_kernel(op: dict) -> str | None:
    """Name of the fused kernel ``op`` lowers to, or None (plan/explain)."""
    m = match_fragment(op)
    return m.kernel if m is not None else None


def _compile_pred(preds: list[dict]):
    if not preds:
        return None
    fns = [compile_expr(expr_from_dict(p)) for p in preds]

    def pred(cols):
        out = fns[0](cols)
        for f in fns[1:]:
            out = out & f(cols)
        return out
    return pred


def lower_fragment(op: dict) -> Lowered | None:
    """Build the kernel-backed program for a matched fragment op tree.

    The returned function consumes the same leaf blocks as the generic
    chain and produces outputs identical in names, shapes, dtypes, and
    mask semantics to ``operators.make_direct_agg`` — callers need no
    special-casing beyond swapping the function.
    """
    m = match_fragment(op)
    if m is None:
        return None
    pred = _compile_pred(m.preds)
    agg_names = [name for name, _, _ in m.aggs]
    aggs = [(fn, compile_expr(expr_from_dict(arg)) if arg is not None
             else None) for _, fn, arg in m.aggs]
    leaf_id = "in0"
    leaves = [(leaf_id, m.leaf)]

    if m.kernel == "filter_agg":
        def fn(blocks):
            cols, mask = blocks[leaf_id]
            acc = kops.fused_filter_agg(cols, mask, pred=pred, aggs=aggs)
            out = {name: acc[j].reshape(1).astype(jnp.float64)
                   for j, name in enumerate(agg_names)}
            return out, jnp.ones((1,), bool)
        return Lowered(fn, leaves, m.kernel)

    # grouped: mixed-radix group id over dict-coded key columns, same
    # code assignment as operators.make_direct_agg
    K = int(np.prod(m.sizes))
    strides = mixed_radix_strides(m.sizes)
    group_cols, sizes = list(m.group_cols), list(m.sizes)

    def gid_fn(cols):
        gid = jnp.zeros(cols[group_cols[0]].shape, jnp.int32)
        for c, s in zip(group_cols, strides):
            gid = gid + cols[c].astype(jnp.int32) * s
        return gid

    def fn(blocks):
        cols, mask = blocks[leaf_id]
        tile = kops.fused_groupby(cols, mask, pred=pred, gid_fn=gid_fn,
                                  aggs=aggs, n_groups=K)
        out = dict(decode_group_ids(group_cols, sizes, K))
        for j, name in enumerate(agg_names):
            out[name] = tile[:, j].astype(jnp.float64)
        return out, tile[:, -1] > 0
    return Lowered(fn, leaves, m.kernel)
