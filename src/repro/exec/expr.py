"""AST expression → jnp evaluator compilation, plus expression (de)serialization
for shipping fragment plans to workers as JSON-able payloads (the paper
serializes PQP fragments into function invocation payloads, section 3.3)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.sql import ast


def compile_expr(e: ast.Expr):
    """Compile to a function of a column dict (values: jnp arrays)."""
    if isinstance(e, ast.Col):
        name = e.name
        return lambda cols: cols[name]
    if isinstance(e, ast.Lit):
        value = e.value
        return lambda cols: value
    if isinstance(e, ast.BinOp):
        lf, rf = compile_expr(e.left), compile_expr(e.right)
        op = e.op
        if op == "+":
            return lambda cols: lf(cols) + rf(cols)
        if op == "-":
            return lambda cols: lf(cols) - rf(cols)
        if op == "*":
            return lambda cols: lf(cols) * rf(cols)
        if op == "/":
            return lambda cols: lf(cols) / rf(cols)
        raise ValueError(op)
    if isinstance(e, ast.Cmp):
        lf, rf = compile_expr(e.left), compile_expr(e.right)
        op = e.op
        if op == "<":
            return lambda cols: lf(cols) < rf(cols)
        if op == "<=":
            return lambda cols: lf(cols) <= rf(cols)
        if op == ">":
            return lambda cols: lf(cols) > rf(cols)
        if op == ">=":
            return lambda cols: lf(cols) >= rf(cols)
        if op == "=":
            return lambda cols: lf(cols) == rf(cols)
        if op == "<>":
            return lambda cols: lf(cols) != rf(cols)
        raise ValueError(op)
    if isinstance(e, ast.And):
        fns = [compile_expr(t) for t in e.terms]

        def _and(cols):
            out = fns[0](cols)
            for f in fns[1:]:
                out = out & f(cols)
            return out
        return _and
    if isinstance(e, ast.Or):
        fns = [compile_expr(t) for t in e.terms]

        def _or(cols):
            out = fns[0](cols)
            for f in fns[1:]:
                out = out | f(cols)
            return out
        return _or
    if isinstance(e, ast.Not):
        f = compile_expr(e.term)
        return lambda cols: ~f(cols)
    if isinstance(e, ast.Case):
        cf, tf, of = (compile_expr(e.cond), compile_expr(e.then),
                      compile_expr(e.orelse))
        return lambda cols: jnp.where(cf(cols), tf(cols), of(cols))
    if isinstance(e, ast.InList):
        tf = compile_expr(e.term)
        vfs = [compile_expr(v) for v in e.values]

        def _in(cols):
            t = tf(cols)
            out = (t == vfs[0](cols))
            for v in vfs[1:]:
                out = out | (t == v(cols))
            return out
        return _in
    raise TypeError(f"cannot compile {e}")


# -- serialization ------------------------------------------------------------

def expr_to_dict(e: ast.Expr) -> dict:
    if isinstance(e, ast.Col):
        return {"t": "col", "name": e.name}
    if isinstance(e, ast.Lit):
        return {"t": "lit", "value": e.value, "kind": e.kind}
    if isinstance(e, ast.BinOp):
        return {"t": "bin", "op": e.op, "l": expr_to_dict(e.left),
                "r": expr_to_dict(e.right)}
    if isinstance(e, ast.Cmp):
        return {"t": "cmp", "op": e.op, "l": expr_to_dict(e.left),
                "r": expr_to_dict(e.right)}
    if isinstance(e, ast.And):
        return {"t": "and", "terms": [expr_to_dict(t) for t in e.terms]}
    if isinstance(e, ast.Or):
        return {"t": "or", "terms": [expr_to_dict(t) for t in e.terms]}
    if isinstance(e, ast.Not):
        return {"t": "not", "term": expr_to_dict(e.term)}
    if isinstance(e, ast.Case):
        return {"t": "case", "cond": expr_to_dict(e.cond),
                "then": expr_to_dict(e.then),
                "else": expr_to_dict(e.orelse)}
    if isinstance(e, ast.InList):
        return {"t": "in", "term": expr_to_dict(e.term),
                "values": [expr_to_dict(v) for v in e.values]}
    raise TypeError(f"cannot serialize {e}")


def expr_from_dict(d: dict) -> ast.Expr:
    t = d["t"]
    if t == "col":
        return ast.Col(d["name"])
    if t == "lit":
        return ast.Lit(d["value"], d["kind"])
    if t == "bin":
        return ast.BinOp(d["op"], expr_from_dict(d["l"]),
                         expr_from_dict(d["r"]))
    if t == "cmp":
        return ast.Cmp(d["op"], expr_from_dict(d["l"]),
                       expr_from_dict(d["r"]))
    if t == "and":
        return ast.And(tuple(expr_from_dict(x) for x in d["terms"]))
    if t == "or":
        return ast.Or(tuple(expr_from_dict(x) for x in d["terms"]))
    if t == "not":
        return ast.Not(expr_from_dict(d["term"]))
    if t == "case":
        return ast.Case(expr_from_dict(d["cond"]),
                        expr_from_dict(d["then"]),
                        expr_from_dict(d["else"]))
    if t == "in":
        return ast.InList(expr_from_dict(d["term"]),
                          tuple(expr_from_dict(v) for v in d["values"]))
    raise TypeError(t)
