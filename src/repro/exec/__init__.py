"""Vectorized JAX execution layer for query fragments.

SQL arithmetic (TPC-H decimals) needs float64/int64, so importing this
package enables jax_enable_x64. Model code elsewhere uses explicit dtypes
(bf16/f32) and is unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

from repro.exec.batch import Block, bucket_capacity, from_numpy, to_numpy
from repro.exec.expr import compile_expr, expr_from_dict, expr_to_dict

__all__ = ["Block", "bucket_capacity", "compile_expr", "expr_from_dict",
           "expr_to_dict", "from_numpy", "to_numpy"]
