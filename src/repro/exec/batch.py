"""Fixed-capacity columnar blocks with validity masks.

XLA programs need static shapes; SQL produces data-dependent cardinalities.
The execution layer therefore works on ``Block``s: equal-length column
arrays padded to a bucketed capacity plus a boolean validity mask. Filters
flip mask bits; joins and aggregations emit capacity-bounded outputs; rows
are compacted back to numpy only at fragment output boundaries.

Capacity bucketing (next power of two, floor 1024) bounds the number of
distinct shapes XLA compiles per operator.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


def bucket_capacity(n: int, floor: int = 1024) -> int:
    cap = floor
    while cap < n:
        cap *= 2
    return cap


@dataclasses.dataclass
class Block:
    columns: dict[str, jnp.ndarray]
    mask: jnp.ndarray                 # bool[capacity]

    @property
    def capacity(self) -> int:
        return int(self.mask.shape[0])

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]


def from_numpy(columns: dict[str, np.ndarray],
               capacity: int | None = None) -> Block:
    n = len(next(iter(columns.values()))) if columns else 0
    cap = capacity if capacity is not None else bucket_capacity(n)
    cols = {}
    for name, arr in columns.items():
        pad = np.zeros((cap - n,) + arr.shape[1:], dtype=arr.dtype)
        cols[name] = jnp.asarray(np.concatenate([arr, pad]))
    mask = np.zeros(cap, dtype=bool)
    mask[:n] = True
    return Block(cols, jnp.asarray(mask))


def to_numpy(block: Block) -> dict[str, np.ndarray]:
    """Compact valid rows back to numpy (host-side, at fragment edges)."""
    mask = np.asarray(block.mask)
    return {name: np.asarray(col)[mask]
            for name, col in block.columns.items()}
