"""Query-worker fragment execution (paper section 3.3).

A worker deserializes its invocation payload (the fragment spec), loads its
input partitions through the storage input handler, executes the fragment's
operator chain as one jit-compiled XLA program over fixed-capacity blocks,
and writes exactly one deterministic output object per destination — making
re-execution idempotent: racing duplicate workers overwrite identical bytes.
"""

from __future__ import annotations

import dataclasses
import json
import threading
import time

import jax
import numpy as np

from repro.exec import exchange
from repro.exec import lower
from repro.exec import operators as ops
from repro.kernels import bloom as bloomlib
from repro.exec.batch import bucket_capacity, from_numpy, to_numpy
from repro.exec.expr import expr_from_dict
from repro.storage import pax
from repro.storage.io_handlers import (FooterCache, InputHandler, IoStats,
                                       OutputHandler)
from repro.storage.object_store import ObjectStore


@dataclasses.dataclass
class FragmentStats:
    rows_in: int = 0
    rows_out: int = 0
    sim_io_s: float = 0.0
    compute_s: float = 0.0
    requests: int = 0
    retriggers: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    footer_cache_hits: int = 0
    kernel: str = ""       # fused Pallas kernel this fragment ran on ("" = jnp)
    # Pipelined consumption: this fragment read at least one input from a
    # partial manifest. ``first_input_s`` is the simulated makespan of the
    # first available batch (the fragment's exposed input latency);
    # ``topups`` counts later batches, whose read time double-buffers
    # against compute — the worker turns that into ``overlap_saved_s``
    # simulated seconds hidden from its runtime (CostModel overlap term).
    pipelined: bool = False
    first_input_s: float = 0.0
    topups: int = 0
    overlap_saved_s: float = 0.0
    # probe rows the semi-join Bloom filter killed before partitioning
    # (exact: counted against the predicate-surviving stream)
    semijoin_killed: int = 0
    # per-tier request/byte accounting for the cost model
    tier_ops: dict = dataclasses.field(default_factory=dict)

    def account(self, tier: str, st: IoStats, *, write: bool) -> None:
        t = self.tier_ops.setdefault(
            tier, {"get": 0, "put": 0, "bytes_read": 0, "bytes_written": 0})
        if write:
            t["put"] += st.requests
            t["bytes_written"] += st.bytes
        else:
            t["get"] += st.requests
            t["bytes_read"] += st.bytes
            self.retriggers += st.retriggers
            self.footer_cache_hits += st.footer_hits
        self.requests += st.requests
        self.bytes_read += 0 if write else st.bytes
        self.bytes_written += st.bytes if write else 0
        self.sim_io_s += st.sim_time_s


@dataclasses.dataclass
class FragmentResult:
    output_keys: list[str]
    stats: FragmentStats
    # Per-destination output statistics (rows, bytes, distinct-key KMV
    # sketch) — the worker's contribution to the exchange manifest that
    # the adaptive re-optimizer consumes at the next stage barrier.
    partition_stats: list[dict] = dataclasses.field(default_factory=list)
    # serialized Bloom filter words over this fragment's join-key column
    # (build side of an eligible repartition join; OR-merged and
    # published by the coordinator)
    bloom: bytes | None = None


# -- jit program construction ---------------------------------------------------

# Compiled-program cache, shared across fragments, pipelines, and queries
# of the process: the key is the *canonical* serialized op tree (fragment
# payloads of one pipeline share it verbatim) plus the dispatch mode, the
# value a jitted program. Capacities are bucketed (``bucket_capacity``)
# before blocks reach the program, so jax.jit retraces once per capacity
# bucket and every same-shaped fragment — of any query — reuses the trace.
_FN_CACHE: dict[tuple[str, bool], tuple] = {}
_FN_CACHE_LOCK = threading.Lock()
_FN_CACHE_STATS = {"hits": 0, "misses": 0}


def fn_cache_stats() -> dict:
    with _FN_CACHE_LOCK:
        return dict(_FN_CACHE_STATS, entries=len(_FN_CACHE))


def _plan_key(op: dict) -> str:
    return json.dumps(op, sort_keys=True, separators=(",", ":"))


def _build(op: dict, leaves: list[tuple[str, dict]]):
    """Recursively build a pure function over named leaf blocks."""
    t = op["t"]
    if t in ("scan_table", "scan_exchange"):
        leaf_id = f"in{len(leaves)}"
        leaves.append((leaf_id, op))
        return lambda blocks: blocks[leaf_id]
    if t == "filter":
        child = _build(op["child"], leaves)
        f = ops.make_filter(expr_from_dict(op["pred"]))

        def run_filter(blocks):
            cols, mask = child(blocks)
            return f(cols, mask)
        return run_filter
    if t == "project":
        child = _build(op["child"], leaves)
        f = ops.make_project([(n, expr_from_dict(e))
                              for n, e in op["exprs"]])

        def run_project(blocks):
            cols, mask = child(blocks)
            return f(cols, mask)
        return run_project
    if t in ("partial_agg", "merge_agg"):
        child = _build(op["child"], leaves)
        aggs = [(n, fn, expr_from_dict(a) if a else None)
                for n, fn, a in op["aggs"]]
        if op["strategy"] == "direct":
            f, _ = ops.make_direct_agg(op["group_cols"], op["sizes"], aggs)
        else:
            f = ops.make_sort_agg(op["group_cols"], aggs)

        def run_agg(blocks):
            cols, mask = child(blocks)
            return f(cols, mask)
        return run_agg
    if t == "semijoin_probe":
        # jnp fallback of the fused Bloom probe (exec.lower's
        # ``bloom_filter`` arm): same hash family, same reserved
        # ``__bloom_pass`` column, bit-for-bit. The filter words arrive
        # through the runtime ``__bloom`` pseudo-leaf so the jitted
        # program never closes over a query's filter contents.
        child = _build(op["child"], leaves)
        key, bits, k = op["key"], int(op["bits"]), int(op["k"])
        leaves.append(("__bloom", {"t": "bloom_words"}))

        def run_semijoin(blocks):
            cols, mask = child(blocks)
            words = blocks["__bloom"][0]["words"]
            hit = bloomlib.bloom_probe_jnp(cols[key], words, bits=bits,
                                           k=k) & mask
            out = dict(cols)
            out["__bloom_pass"] = hit.astype("int32")
            return out, mask
        return run_semijoin
    if t == "join":
        probe = _build(op["probe"], leaves)
        build = _build(op["build"], leaves)
        f = ops.make_pk_join_probe(op["probe_key"], op["build_key"],
                                   op["payload"])

        def run_join(blocks):
            pcols, pmask = probe(blocks)
            bcols, bmask = build(blocks)
            return f(pcols, pmask, bcols, bmask)
        return run_join
    raise TypeError(t)


def _compiled(op: dict):
    """(jitted fn, leaves, kernel name, cache key) for an op tree: kernel
    dispatch first (``repro.exec.lower``), generic jnp chain otherwise."""
    # read the dispatch switch once: key and lowering gate must agree, or
    # a concurrent toggle could park a generic program under a fused key
    dispatch = lower.enabled()
    if dispatch:
        # cheap match-only pass: the kernel's roofline tiling joins the
        # cache key (a tiling change must not reuse a stale trace), and
        # ``final`` ops whose top-k arm misses dispatch on their child —
        # the coordinator's host sort still runs either way
        _, tkey, op = lower.dispatch_signature(op)
    else:
        tkey = None
        if op.get("t") == "final":
            op = op["child"]
    key = (_plan_key(op), dispatch, tkey)
    with _FN_CACHE_LOCK:
        entry = _FN_CACHE.get(key)
        if entry is not None:
            _FN_CACHE_STATS["hits"] += 1
            return entry
        _FN_CACHE_STATS["misses"] += 1
    lowered = lower.lower_fragment(op) if dispatch else None
    if lowered is not None:
        entry = (jax.jit(lowered.fn), lowered.leaves, lowered.kernel, key)
    else:
        leaves: list[tuple[str, dict]] = []
        fn = _build(op, leaves)
        entry = (jax.jit(fn), leaves, "", key)
    with _FN_CACHE_LOCK:
        return _FN_CACHE.setdefault(key, entry)


# (cache key, leaf capacities) pairs whose XLA executable is already
# built: the first fragment hitting a new op×capacity-bucket combination
# pays trace+compile in an *untimed* warmup call, so ``compute_s`` — the
# simulated worker runtime — reflects steady-state kernel execution.
# Compile spikes otherwise masquerade as stragglers and draw spurious
# re-triggers on repeated runs.
_WARM_SHAPES: set = set()


def _warm(fn, key, blocks) -> None:
    sig = (key, tuple(sorted((lid, int(mask.shape[0]))
                             for lid, (_, mask) in blocks.items())))
    with _FN_CACHE_LOCK:
        if sig in _WARM_SHAPES:
            return
    jax.block_until_ready(fn(blocks)[1])
    with _FN_CACHE_LOCK:
        _WARM_SHAPES.add(sig)


# -- input loading ----------------------------------------------------------------

def _load_scan_table(handler: InputHandler, spec: dict, leaf_op: dict,
                     stats: FragmentStats) -> dict[str, np.ndarray]:
    preds = [pax.ZonePredicate(c, o, tuple(v) if isinstance(v, list) else v)
             for c, o, v in leaf_op["zone_preds"]]
    # one batched read: all scan units share the worker's request pool
    parts, st = handler.read_tables(spec["scan_units"],
                                    leaf_op["columns"], preds)
    stats.account("table", st, write=False)
    if not parts:
        return {c: np.empty((0,), np.int64) for c in leaf_op["columns"]}
    return {c: np.concatenate([p[c] for p in parts])
            for c in leaf_op["columns"]}


def _load_scan_exchange(handler_for, store: ObjectStore, spec: dict,
                        leaf_op: dict,
                        stats: FragmentStats) -> dict[str, np.ndarray]:
    src = spec["sources"][leaf_op["source"]]
    if src.get("pipelined"):
        return _load_exchange_pipelined(handler_for, store, spec, leaf_op,
                                        stats)
    part = src["partitioning"]
    tier = part.get("tier", "s3-standard")
    handler = handler_for(tier)
    me, F = spec["fragment"], spec["n_fragments"]
    # Adaptive re-optimization hooks (core.adaptive): ``read_partitions``
    # is this fragment's explicit upstream-partition assignment (fleet
    # re-sizing coarsens the 1:1 fragment↔partition map); per-source
    # ``source_partitions`` lists the provably non-empty partitions, so
    # empty ones are pruned from the read set entirely. The exchange
    # subsystem (repro.exec.exchange) resolves the object keys for the
    # *materialized* layout the registry entry records — a direct grid
    # or per-producer combined objects pruned via __dest zone maps.
    assigned = spec.get("read_partitions")
    nonempty = (spec.get("source_partitions") or {}).get(leaf_op["source"])
    keys, preds, local_filter = exchange.plan_exchange_read(
        part, src["prefix"], src["n_fragments"], leaf_op["mode"], me, F,
        assigned, nonempty)
    names = [c["name"] for c in src["schema"]]
    # One batched read over the whole producer × partition grid: the
    # shared footer cache still skips every chunk request of provably
    # empty partitions, and all objects' requests share one request-pool
    # makespan — a small (cost-optimally shrunk) fleet fetches many
    # partitions concurrently instead of paying per-object first-byte
    # latency serially.
    parts, st = handler.read_tables(keys, names, preds)
    stats.account(tier, st, write=False)
    out = {c: np.concatenate([p[c] for p in parts]) if parts
           else np.empty((0,), np.dtype(s["dtype"]))
           for c, s in zip(names, src["schema"])}
    if local_filter:
        dest = ops.np_hash_dest(out, list(part["keys"]), F)
        sel = dest == me
        out = {c: v[sel] for c, v in out.items()}
    return out


def _read_cost(info) -> int:
    """Estimated read cost of one upstream partition, from its manifest
    entry (0 for retired streams whose entries carry no stats)."""
    return int(info.get("bytes") or 0) if isinstance(info, dict) else 0


def _load_exchange_pipelined(handler_for, store: ObjectStore, spec: dict,
                             leaf_op: dict, stats: FragmentStats,
                             ) -> dict[str, np.ndarray]:
    """Consume an exchange from its *partial* manifest (barrier-free).

    The fragment was admitted once a fraction of its producers had
    published. It drains what exists, then tops up batch-by-batch as
    further manifest entries land — each batch prefetched on a
    background thread while the previous one is collected (double
    buffering), waiting on manifest *versions* between batches. Rows are
    assembled in sorted producer-id order regardless of completion
    order, so the concatenated input — and every byte derived from it —
    is identical to the barrier run's.
    """
    from repro.core.registry import read_manifest
    src = spec["sources"][leaf_op["source"]]
    part = src["partitioning"]
    tier = part.get("tier", "s3-standard")
    handler = handler_for(tier)
    me, F = spec["fragment"], spec["n_fragments"]
    assigned = spec.get("read_partitions")
    nonempty = (spec.get("source_partitions") or {}).get(leaf_op["source"])
    names = [c["name"] for c in src["schema"]]
    kv = store.with_tier("dynamodb")
    mkey = src["manifest_key"]
    deadline = time.time() + float(src.get("wait_timeout_s") or 600.0)
    stats.pipelined = True
    tables: dict[int, list] = {}        # producer id → its tables
    local_filter = False
    pending: tuple | None = None        # (Prefetch, gids, n_keys)

    def collect(pref, gids, n_keys) -> None:
        parts, st = pref.result()
        if tables:
            stats.topups += 1
        else:
            stats.first_input_s = st.sim_time_s
        stats.account(tier, st, write=False)
        per = n_keys // len(gids) if gids else 0
        for i, g in enumerate(gids):
            tables[g] = parts[i * per:(i + 1) * per]

    while True:
        token = kv.version(mkey)
        man = read_manifest(kv, mkey)
        if man is None:
            # stream already retired with its result entry — the entry's
            # producer count is final and every object exists
            man = {"done": {str(g): None
                            for g in range(src["n_fragments"])},
                   "complete": True}
        if man.get("aborted"):
            raise RuntimeError("upstream producer pipeline aborted")
        known = set(tables) | (set(pending[1]) if pending else set())
        done = man.get("done") or {}
        # top-up order: most expensive reads first (per-partition bytes
        # from the partial manifest), so the largest transfers overlap
        # compute the longest; arrival order carries no such signal
        fresh = sorted((g for g in map(int, done) if g not in known),
                       key=lambda g: (-_read_cost(done.get(str(g))), g))
        if fresh:
            keys, preds, lf = exchange.plan_exchange_read(
                part, src["prefix"], fresh, leaf_op["mode"], me, F,
                assigned, nonempty)
            local_filter = local_filter or lf
            nxt = (handler.prefetch_tables(keys, names, preds), fresh,
                   len(keys))
            if pending is not None:
                collect(*pending)   # overlap: next batch is in flight
            pending = nxt
        if man.get("complete"):
            break
        if not fresh:
            if time.time() >= deadline:
                raise TimeoutError("exchange manifest never sealed: "
                                   "producer pipeline lost without abort")
            kv.watch(mkey, token, timeout_s=1.0)
    if pending is not None:
        collect(*pending)

    ordered: list[dict] = []
    for g in sorted(tables):
        ordered.extend(tables[g])
    out = {c: np.concatenate([p[c] for p in ordered]) if ordered
           else np.empty((0,), np.dtype(s["dtype"]))
           for c, s in zip(names, src["schema"])}
    if local_filter:
        dest = ops.np_hash_dest(out, list(part["keys"]), F)
        sel = dest == me
        out = {c: v[sel] for c, v in out.items()}
    return out


# -- driver ------------------------------------------------------------------------

def execute_fragment(store: ObjectStore, spec: dict,
                     footer_cache: FooterCache | None = None,
                     cost_model=None,
                     ) -> FragmentResult:
    cache = footer_cache if footer_cache is not None else FooterCache()
    # Merge-wave fragments of a multi-level exchange are pure host-side
    # re-bucketing (plus partial-state combining): no XLA program.
    if spec["op"]["t"] == "merge_exchange":
        return exchange.execute_merge(store, spec, footer_cache=cache,
                                      cost_model=cost_model)
    stats = FragmentStats()
    # One input handler per storage tier, all sharing the (session-scoped)
    # footer cache — every leaf of this fragment reuses them instead of
    # constructing fresh handlers per source.
    handlers: dict[str | None, InputHandler] = {}

    def handler_for(tier: str | None) -> InputHandler:
        if tier not in handlers:
            view = store if tier is None else store.with_tier(tier)
            handlers[tier] = InputHandler(view, footer_cache=cache,
                                          cost_model=cost_model)
        return handlers[tier]

    # Semi-join filter pushdown: a probe-side spec may carry the build
    # side's published Bloom filter. Kernel-eligible filters (single
    # truncated-integer key) wrap the op tree for dispatch only — the
    # wrapper joins the compiled-program cache key, never the semantic
    # hash — so the membership test fuses into the scan program (Pallas
    # kernel or jnp fallback); other key shapes kill on the host below.
    op = spec["op"]
    sj = spec.get("semijoin")
    if sj is not None and sj.get("mode") == "u32" and len(sj["key"]) == 1 \
            and op.get("t") in ("scan_table", "filter", "project"):
        op = {"t": "semijoin_probe", "key": sj["key"][0],
              "bits": int(sj["bits"]), "k": int(sj["k"]), "child": op}
    fn, leaves, kernel, fn_key = _compiled(op)
    stats.kernel = kernel

    # 1. Load leaf inputs (host side, ranged + pruned + re-triggered reads).
    blocks = {}
    for leaf_id, leaf_op in leaves:
        if leaf_op["t"] == "bloom_words":
            words = np.frombuffer(sj["words"], dtype=np.uint32)
            blocks[leaf_id] = ({"words": words}, np.ones((1,), bool))
            continue
        if leaf_op["t"] == "scan_table":
            cols = _load_scan_table(handler_for(None), spec, leaf_op,
                                    stats)
        else:
            cols = _load_scan_exchange(handler_for, store, spec, leaf_op,
                                       stats)
        n = len(next(iter(cols.values()))) if cols else 0
        stats.rows_in += n
        blk = from_numpy(cols, bucket_capacity(n))
        blocks[leaf_id] = (blk.columns, blk.mask)

    # 2. Execute the fused XLA program (trace/compile paid untimed, once
    # per op×capacity bucket — simulated runtime is steady-state compute).
    _warm(fn, fn_key, blocks)
    t0 = time.perf_counter()
    out_cols, out_mask = fn(blocks)
    jax.block_until_ready(out_mask)
    stats.compute_s += time.perf_counter() - t0
    from repro.exec.batch import Block
    result = to_numpy(Block(dict(out_cols), out_mask))

    # 2b. Semi-join kill before partitioning: the fused program emitted a
    # per-row Bloom verdict (``__bloom_pass``), or — for multi-column /
    # non-integer keys — the host probes the filter directly. Either way
    # the count is exact against the predicate-surviving stream, and the
    # killed rows never reach the exchange write.
    if "__bloom_pass" in result:
        hit = result.pop("__bloom_pass") != 0
        stats.semijoin_killed = int(hit.size - hit.sum())
        if stats.semijoin_killed:
            result = {c: v[hit] for c, v in result.items()}
    elif sj is not None and all(c in result for c in sj["key"]):
        filt = bloomlib.bloom_from_wire(sj)
        ku = bloomlib.keys_u32(result, list(sj["key"]), filt["mode"])
        hit = bloomlib.bloom_probe_np(ku, filt["words"], filt["bits"],
                                      filt["k"])
        stats.semijoin_killed = int(hit.size - hit.sum())
        if stats.semijoin_killed:
            result = {c: v[hit] for c, v in result.items()}

    # 3. Final-stage host ops (global sort / limit on the compacted result).
    if spec["op"]["t"] == "final":
        fop = spec["op"]
        if fop["sort_keys"]:
            cols_for_sort = []
            for name, desc in reversed(fop["sort_keys"]):
                k = result[name]
                cols_for_sort.append(-k if desc else k)
            order = np.lexsort(cols_for_sort)
            result = {c: v[order] for c, v in result.items()}
        if fop.get("limit") is not None:
            result = {c: v[:fop["limit"]] for c, v in result.items()}

    # 4. Write deterministic output object(s).
    schema = [pax.ColumnSpec(s["name"], s["kind"], s["dtype"])
              for s in spec["output"]["schema"]]
    names = [s.name for s in schema]
    result = {c: result[c].astype(np.dtype(s.dtype))
              for c, s in zip(names, schema)}
    part = spec["output"]["partitioning"]
    prefix = spec["output"]["prefix"]
    me = spec["fragment"]
    out_keys = []
    part_stats: list[dict] = []
    n_out = len(next(iter(result.values()))) if result else 0
    stats.rows_out = n_out
    if part["kind"] == "hash":
        # the exchange strategy owns the materialized layout: a direct
        # producer×partition grid, or one combined per-producer object
        # (combining / multi-level level 0)
        strat = exchange.get_strategy(part.get("strategy", "direct"))
        keys, part_stats = strat.write(store, result, schema, part,
                                       prefix, me, stats)
        out_keys.extend(keys)
    else:
        out = OutputHandler(store)
        out.append(result)
        key = f"{prefix}/f{me:04d}/out.spax"
        st = out.finish(key, schema)
        stats.account("table", st, write=True)
        out_keys.append(key)
        part_stats.append({"rows": n_out, "bytes": st.bytes, "kmv": [],
                           "write_s": st.sim_time_s})

    # 5. Build-side Bloom filter: fold this fragment's join-key column
    # into fleet-uniform filter words (size fixed by the coordinator so
    # per-fragment filters OR-merge). Emitted whenever the spec asks —
    # even when the planner's cost gate said no — so the Reoptimizer can
    # still adopt the filter at pilot-K time from observed cardinality.
    bloom_payload = None
    bl = spec.get("bloom")
    if bl is not None and part["kind"] == "hash" \
            and all(c in result for c in part["keys"]):
        ku = bloomlib.keys_u32(result, list(part["keys"]), bl["mode"])
        words = bloomlib.bloom_build(ku, int(bl["bits"]), int(bl["k"]))
        bloom_payload = words.tobytes()
    return FragmentResult(out_keys, stats, part_stats,
                          bloom=bloom_payload)
