"""First-class exchange subsystem: pluggable shuffle strategies.

Skyrise shuffles run entirely through serverless storage, so exchange
cost is dominated by *request counts*: the naive layout writes one
object per producer × partition pair and reads the whole grid — O(n·m)
requests that explode at wide fan-out (the pattern Lambada's multi-level
exchange collapses). This module owns how a pipeline's hash-partitioned
output is materialized and read back; everything else (planner, cost
model, adaptive layer, engine) only names a strategy.

Strategies (registry below, ``register_strategy`` to add one):

  * ``direct`` — the producer × partition grid
    (``f{g}/d{d}.spax``): bit-compatible with the historical layout.
    Requests: n·m PUTs, n·m GETs.
  * ``combining`` — each producer *combines* its whole destination grid
    into ONE object (``f{g}/all.spax``) whose rows are sorted by a
    stored ``__dest`` column and whose row groups split at partition
    boundaries, so consumers prune to their partition via zone maps and
    fetch it with one coalesced ranged GET per producer. Requests:
    n PUTs, ≤ n·m (smaller, ranged) GETs.
  * ``multilevel`` — Lambada-style two-phase tree shuffle: producers
    write combined intermediates (under ``l0/``), a merge wave of
    G = ⌈√n⌉ workers re-partitions them — re-combining mergeable
    partial-aggregate states when the KMV sketches say the key
    cardinality is well below the row count — and writes a G×m grid;
    consumers read O(√n·m) objects instead of O(n·m).

The *materialized* layout is recorded in the registry entry
(``partitioning["layout"]``: "grid" | "combined"), which is what
consumers dispatch on — so cached results produced under any strategy
stay readable by any plan, and the semantic hash (caching/dedup) is
untouched by strategy choice.
"""

from __future__ import annotations

import math
import time
from typing import Sequence

import numpy as np

from repro.exec import operators as ops
from repro.storage import pax
from repro.storage.io_handlers import InputHandler, OutputHandler
from repro.storage.pax import ColumnSpec, ZonePredicate

# Stored destination-partition column of combined exchange objects.
DEST_COL = "__dest"
_DEST_SPEC = ColumnSpec(DEST_COL, "num", "<i4")


def merge_group_count(producers: int) -> int:
    """Merge-wave width of the multi-level exchange: ⌈√producers⌉."""
    return max(1, math.isqrt(max(producers - 1, 0)) + 1)


# -- strategy objects -----------------------------------------------------------

class ExchangeStrategy:
    """How one hash exchange is partitioned, materialized, and read."""

    name = ""
    layout = "grid"          # materialized layout consumers dispatch on
    # Whether this strategy's layout supports *incremental* consumption:
    # each producer's contribution is a self-contained object (or object
    # row-group) readable the moment that producer publishes its partial
    # manifest entry, so consumers can start on a subset of producers and
    # top up. All built-in layouts qualify — direct and combining write
    # per-producer objects; multilevel's consumer-facing grid is written
    # per merge *group*, each itself an incremental reader of the l0
    # stream. A strategy that interleaves producers inside shared objects
    # would set this False and consumers would fall back to the barrier.
    incremental = True

    # -- request-count math (the cost model's per-strategy estimates) ----
    def written_objects(self, producers: int, n_dest: int) -> int:
        raise NotImplementedError

    def merge_workers(self, producers: int) -> int:
        return 0

    def producer_puts(self, n_dest: int) -> int:
        """Exchange PUTs issued by one producer fragment."""
        return 1

    def producer_requests(self, producers: int, n_dest: int) -> int:
        """Estimated storage requests on the producer side of the
        barrier: exchange PUTs plus, for multi-level, the merge wave's
        reads (footers included) and writes. This is the figure EXPLAIN
        ANALYZE compares against the observed count."""
        raise NotImplementedError

    def consumer_requests(self, producers: int, n_dest: int) -> int:
        """Estimated data GETs for all consumers to read the exchange
        once (footer fetches excluded: the shared cache pays them once
        per object)."""
        raise NotImplementedError

    # -- producer write path ---------------------------------------------
    def write(self, store, result: dict[str, np.ndarray],
              schema: Sequence[ColumnSpec], part: dict, prefix: str,
              me: int, stats) -> tuple[list[str], list[dict]]:
        """Materialize one producer fragment's hash-partitioned output;
        returns (object keys, per-destination stats for the exchange
        manifest). ``stats`` is the fragment's ``FragmentStats``."""
        raise NotImplementedError


class DirectStrategy(ExchangeStrategy):
    name = "direct"
    layout = "grid"

    def written_objects(self, producers, n_dest):
        return producers * n_dest

    def producer_puts(self, n_dest):
        return n_dest

    def producer_requests(self, producers, n_dest):
        return producers * n_dest

    def consumer_requests(self, producers, n_dest):
        return producers * n_dest

    def write(self, store, result, schema, part, prefix, me, stats):
        tier = part.get("tier", "s3-standard")
        out = OutputHandler(store.with_tier(tier))
        h = ops.np_key_hash(result, list(part["keys"]))
        dest = (h % np.uint64(part["n_dest"])).astype(np.int32)
        out_keys, part_stats = [], []
        for d in range(part["n_dest"]):
            sel = dest == d
            out.append({c: v[sel] for c, v in result.items()})
            key = f"{prefix}/f{me:04d}/d{d:04d}.spax"
            st = out.finish(key, schema)
            stats.account(tier, st, write=True)
            out_keys.append(key)
            part_stats.append({"rows": int(sel.sum()), "bytes": st.bytes,
                               "kmv": ops.kmv_sketch(h[sel]),
                               "write_s": st.sim_time_s})
        return out_keys, part_stats


def _write_combined(store, result, schema, part, prefix, me, stats,
                    subdir: str = "", tier_override: str | None = None):
    """One combined object per producer: rows stably sorted by
    destination, row groups split at partition boundaries, ``__dest``
    stored so both zone maps and the merge wave can route by it."""
    tier = tier_override or part.get("tier", "s3-standard")
    n_dest = part["n_dest"]
    h = ops.np_key_hash(result, list(part["keys"]))
    dest = (h % np.uint64(n_dest)).astype(np.int32)
    # stable: rows keep their original order within each destination, so
    # per-partition row sequences are identical to the direct grid's
    order = np.argsort(dest, kind="stable")
    counts = np.bincount(dest, minlength=n_dest)
    splits = [int(s) for s in np.cumsum(counts)[:-1]]
    combined = {c: v[order] for c, v in result.items()}
    combined[DEST_COL] = dest[order]
    out = OutputHandler(store.with_tier(tier))
    out.append(combined)
    key = f"{prefix}/{subdir}f{me:04d}/all.spax"
    st = out.finish(key, list(schema) + [_DEST_SPEC], splits=splits)
    stats.account(tier, st, write=True)
    n = max(int(counts.sum()), 1)
    part_stats = [{"rows": int(counts[d]),
                   "bytes": int(st.bytes * counts[d] / n),
                   "kmv": ops.kmv_sketch(h[dest == d]),
                   "write_s": st.sim_time_s * counts[d] / n}
                  for d in range(n_dest)]
    return [key], part_stats


class CombiningStrategy(ExchangeStrategy):
    name = "combining"
    layout = "combined"

    def written_objects(self, producers, n_dest):
        return producers

    def producer_requests(self, producers, n_dest):
        return producers

    def consumer_requests(self, producers, n_dest):
        return producers * n_dest

    def write(self, store, result, schema, part, prefix, me, stats):
        return _write_combined(store, result, schema, part, prefix, me,
                               stats)


class MultiLevelStrategy(ExchangeStrategy):
    name = "multilevel"
    layout = "grid"          # the merge wave materializes a G×m grid

    def written_objects(self, producers, n_dest):
        return producers + merge_group_count(producers) * n_dest

    def merge_workers(self, producers):
        return merge_group_count(producers)

    def producer_requests(self, producers, n_dest):
        # l0 PUTs + merge reads (1 data + 2 footer GETs per l0 object)
        # + merge-wave grid PUTs
        g = merge_group_count(producers)
        return producers + 3 * producers + g * n_dest

    def consumer_requests(self, producers, n_dest):
        return merge_group_count(producers) * n_dest

    def write(self, store, result, schema, part, prefix, me, stats):
        # l0 intermediates are short-lived (read once by the merge wave,
        # then deleted) — the cost model may route them to a hotter tier
        # than the grid the consumers read
        return _write_combined(store, result, schema, part, prefix, me,
                               stats, subdir="l0/",
                               tier_override=part.get("l0_tier"))


STRATEGIES: dict[str, ExchangeStrategy] = {}


def register_strategy(strategy: ExchangeStrategy) -> None:
    STRATEGIES[strategy.name] = strategy


for _s in (DirectStrategy(), CombiningStrategy(), MultiLevelStrategy()):
    register_strategy(_s)


def get_strategy(name: str) -> ExchangeStrategy:
    return STRATEGIES[name or "direct"]


# -- consumer read planning -----------------------------------------------------

def plan_exchange_read(part: dict, prefix: str,
                       n_producers: int | Sequence[int],
                       mode: str, me: int, n_fragments: int,
                       assigned: list[int] | None,
                       nonempty: list[int] | None,
                       ) -> tuple[list[str], list[ZonePredicate], bool]:
    """Object keys (+ zone predicates, + local-repartition flag) one
    consumer fragment must read, for any materialized layout.

    ``part`` is the *registry entry's* partitioning dict — the layout of
    what was actually written, which may differ from the reader's plan
    (cached results, adapted strategies). ``assigned`` is the adaptive
    partition assignment, ``nonempty`` the provably non-empty partition
    ids of this source. ``n_producers`` may be an explicit producer-id
    subset instead of a count: pipelined consumers plan one top-up batch
    at a time over exactly the ids newly present in the partial manifest.
    """
    producers: Sequence[int] = range(n_producers) \
        if isinstance(n_producers, int) else n_producers
    if part["kind"] != "hash":
        return ([f"{prefix}/f{g:04d}/out.spax" for g in producers],
                [], False)
    layout = part.get("layout", "grid")
    ds: list[int] | None
    local_filter = False
    if mode == "partition":
        if assigned is not None:
            ds = [d for d in assigned
                  if nonempty is None or d in nonempty]
        elif part["n_dest"] == n_fragments:
            ds = [me]
        else:
            # Cached result with a different fan-out: read everything
            # and re-partition locally (correct under any layout).
            local_filter = True
            ds = None
    else:  # mode == all
        ds = [d for d in range(part["n_dest"])
              if nonempty is None or d in nonempty]
    if layout == "combined":
        if ds is not None and not ds:
            return [], [], False
        keys = [f"{prefix}/f{g:04d}/all.spax" for g in producers]
        preds = [] if ds is None or len(ds) == part["n_dest"] else \
            [ZonePredicate(DEST_COL, "in", tuple(ds))]
        return keys, preds, local_filter
    if ds is None:
        ds = list(range(part["n_dest"]))
    keys = [f"{prefix}/f{g:04d}/d{d:04d}.spax"
            for g in producers for d in ds]
    return keys, [], local_filter


# -- multi-level merge wave -----------------------------------------------------

def combine_spec(op: dict) -> dict | None:
    """Merge-wave combine spec when the exchanged payload is mergeable
    partial-aggregate state (the pipeline ends in ``partial_agg``), else
    None (join exchanges re-bucket raw rows untouched)."""
    if op.get("t") != "partial_agg":
        return None
    return {"group_cols": list(op["group_cols"]),
            "aggs": [[name, ops.MERGE_FN[fn]]
                     for name, fn, _ in op["aggs"]]}


def execute_merge(store, spec: dict, footer_cache=None, cost_model=None):
    """Run one merge-wave fragment of a multi-level exchange.

    Reads its producer group's combined l0 intermediates, optionally
    re-combines partial-aggregate states (per-worker partial aggregation
    before the final exchange write), and writes its slice of the final
    G×m grid — the layout consumers read as a plain direct grid.

    When the spec carries an l0 ``manifest_key`` (pipelined execution),
    the merge fragment starts on the *partial* l0 stream: it drains the
    objects already published, then tops up batch-by-batch as further
    producers land — watching manifest versions, never polling — until
    the stream is sealed. Assembly order is sorted producer id either
    way, so the merged grid is bit-identical to the barrier run's.
    """
    from repro.exec.fragment import FragmentResult, FragmentStats
    op = spec["op"]
    tier = op.get("tier", "s3-standard")
    l0_tier = op.get("l0_tier") or tier
    stats = FragmentStats()
    view = store.with_tier(tier)
    handler = InputHandler(store.with_tier(l0_tier),
                           footer_cache=footer_cache,
                           cost_model=cost_model)
    schema = [ColumnSpec(s["name"], s["kind"], s["dtype"])
              for s in op["schema"]]
    names = [c.name for c in schema] + [DEST_COL]

    def in_group(g: int) -> bool:
        return g % op["n_groups"] == op["group"]

    parts_by_g: dict[int, dict] = {}

    def drain(gids: list[int]) -> None:
        keys = [f"{op['l0_prefix']}/f{g:04d}/all.spax" for g in gids]
        parts, st = handler.read_tables(keys, names)
        if parts_by_g:
            stats.topups += 1
        else:
            stats.first_input_s = st.sim_time_s
        stats.account(l0_tier, st, write=False)
        parts_by_g.update(zip(gids, parts))

    manifest_key = op.get("manifest_key")
    if manifest_key is None:
        drain([g for g in range(op["producers"]) if in_group(g)])
    else:
        from repro.core.registry import read_manifest
        stats.pipelined = True
        kv = store.with_tier("dynamodb")
        deadline = time.time() + float(op.get("wait_timeout_s") or 600.0)
        while True:
            token = kv.version(manifest_key)
            man = read_manifest(kv, manifest_key)
            if man is None:
                # stream retired with its entry: planned count is final
                man = {"done": {str(g): None
                                for g in range(op["producers"])},
                       "complete": True}
            if man.get("aborted"):
                raise RuntimeError("upstream producer pipeline aborted")
            fresh = sorted(g for g in map(int, man.get("done") or {})
                           if in_group(g) and g not in parts_by_g)
            if fresh:
                drain(fresh)
            if man.get("complete"):
                break
            if time.time() >= deadline:
                raise TimeoutError("l0 stream never sealed: producer "
                                   "pipeline lost without abort")
            kv.watch(manifest_key, token, timeout_s=1.0)

    ordered = [parts_by_g[g] for g in sorted(parts_by_g)]
    cols = {c.name: np.concatenate([p[c.name] for p in ordered])
            if ordered else np.empty((0,), np.dtype(c.dtype))
            for c in schema}
    dest = np.concatenate([p[DEST_COL] for p in ordered]) if ordered \
        else np.empty((0,), np.int32)
    stats.rows_in = int(dest.shape[0])

    t0 = time.perf_counter()
    combine = op.get("combine")
    out = OutputHandler(view)
    prefix = spec["output"]["prefix"]
    out_keys, part_stats = [], []
    rows_out = 0
    for d in range(op["n_dest"]):
        sel = dest == d
        dcols = {c: v[sel] for c, v in cols.items()}
        if combine is not None and sel.any():
            dcols = ops.np_combine_partials(
                dcols, list(combine["group_cols"]),
                [(name, fn) for name, fn in combine["aggs"]])
        dcols = {c.name: dcols[c.name].astype(np.dtype(c.dtype))
                 for c in schema}
        n_rows = len(next(iter(dcols.values()))) if dcols else 0
        rows_out += n_rows
        out.append(dcols)
        key = f"{prefix}/f{op['group']:04d}/d{d:04d}.spax"
        wst = out.finish(key, schema)
        stats.account(tier, wst, write=True)
        out_keys.append(key)
        h = ops.np_key_hash(dcols, list(op["keys"])) if n_rows else \
            np.empty((0,), np.uint64)
        part_stats.append({"rows": n_rows, "bytes": wst.bytes,
                           "kmv": ops.kmv_sketch(h),
                           "write_s": wst.sim_time_s})
    stats.rows_out = rows_out
    stats.compute_s += time.perf_counter() - t0
    return FragmentResult(out_keys, stats, part_stats)
