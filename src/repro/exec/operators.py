"""Vectorized physical operator implementations (jnp, static shapes).

Each factory takes plan-time parameters and returns a pure function over
(columns: dict[str, array], mask: bool array) pairs, so a fragment's whole
operator chain composes into one jit-compiled XLA program. Data-dependent
cardinalities are carried in the mask; outputs are capacity-bounded.

Push-based vectorized execution per the paper (section 3.3), adapted to the
TPU's static-shape world: a "batch" is the fragment's full block and
operators push columns through fused element-wise/segment computations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.exec.expr import compile_expr
from repro.kernels.common import NEUTRAL
from repro.sql import ast

INT64_SENTINEL = np.iinfo(np.int64).max

Cols = dict[str, jnp.ndarray]


# -- hashing (numpy/jnp twins; must agree bit-for-bit) -------------------------

_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB


def hash64_jnp(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint64)
    x = (x ^ (x >> 30)) * jnp.uint64(_M1)
    x = (x ^ (x >> 27)) * jnp.uint64(_M2)
    return x ^ (x >> 31)


def hash64_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * np.uint64(_M1)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(_M2)
    return x ^ (x >> np.uint64(31))


def combine_hash_jnp(cols: list[jnp.ndarray]) -> jnp.ndarray:
    h = hash64_jnp(cols[0])
    for c in cols[1:]:
        h = hash64_jnp(h ^ hash64_jnp(c))
    return h


def combine_hash_np(cols: list[np.ndarray]) -> np.ndarray:
    h = hash64_np(cols[0])
    for c in cols[1:]:
        h = hash64_np(h ^ hash64_np(c))
    return h


# -- row-wise operators --------------------------------------------------------

def make_filter(pred: ast.Expr):
    fn = compile_expr(pred)

    def op(cols: Cols, mask):
        return cols, mask & fn(cols)
    return op


def make_project(exprs: list[tuple[str, ast.Expr]]):
    fns = [(name, compile_expr(e)) for name, e in exprs]

    def op(cols: Cols, mask):
        out = {}
        for name, f in fns:
            v = f(cols)
            if not hasattr(v, "shape") or v.shape != mask.shape:
                v = jnp.broadcast_to(jnp.asarray(v), mask.shape)
            out[name] = v
        return out, mask
    return op


# -- aggregation ----------------------------------------------------------------

def _neutral(fn: str):
    return NEUTRAL[fn]


def mixed_radix_strides(sizes: list[int]) -> list[int]:
    """Strides assigning each group-key combination a unique id in
    [0, prod(sizes)) — shared by the jnp direct aggregation and the
    fused one-hot kernel path so group codes agree bit-for-bit."""
    strides = []
    acc = 1
    for s in reversed(sizes):
        strides.append(acc)
        acc *= s
    return list(reversed(strides))


def decode_group_ids(group_cols: list[str], sizes: list[int],
                     K: int) -> Cols:
    """Reconstruct the group-key columns from the mixed-radix ids."""
    ids = jnp.arange(K)
    return {c: ((ids // s) % size).astype(jnp.int64)
            for c, s, size in zip(group_cols,
                                  mixed_radix_strides(sizes), sizes)}


def make_direct_agg(group_cols: list[str], sizes: list[int],
                    aggs: list[tuple[str, str, ast.Expr | None]]):
    """Group keys with a small known domain: group id = mixed-radix code.

    Output has exactly K = prod(sizes) rows (one per potential group),
    masked to groups with at least one input row. MXU-friendly: the
    segment sums lower to one-hot matmuls / scatter-adds.
    """
    K = int(np.prod(sizes)) if group_cols else 1
    strides = mixed_radix_strides(sizes)
    agg_fns = [(name, fn, compile_expr(arg) if arg is not None else None)
               for name, fn, arg in aggs]

    def op(cols: Cols, mask):
        if group_cols:
            gid = jnp.zeros(mask.shape, jnp.int32)
            for c, s in zip(group_cols, strides):
                gid = gid + cols[c].astype(jnp.int32) * s
            gid = jnp.where(mask, gid, 0)
        else:
            gid = jnp.zeros(mask.shape, jnp.int32)
        maskf = mask.astype(jnp.float64)
        out: Cols = dict(decode_group_ids(group_cols, sizes, K))
        present = jax.ops.segment_sum(maskf, gid, num_segments=K)
        for name, fn, argf in agg_fns:
            if fn == "count":
                out[name] = jax.ops.segment_sum(maskf, gid, num_segments=K)
            else:
                v = argf(cols).astype(jnp.float64)
                if v.shape != mask.shape:
                    v = jnp.broadcast_to(v, mask.shape)
                if fn == "sum":
                    out[name] = jax.ops.segment_sum(
                        v * maskf, gid, num_segments=K)
                elif fn == "min":
                    out[name] = jax.ops.segment_min(
                        jnp.where(mask, v, jnp.inf), gid, num_segments=K)
                elif fn == "max":
                    out[name] = jax.ops.segment_max(
                        jnp.where(mask, v, -jnp.inf), gid, num_segments=K)
        if not group_cols:
            out_mask = jnp.ones((1,), bool) if K == 1 else None
        else:
            out_mask = present > 0
        return out, out_mask
    return op, K


def make_sort_agg(group_cols: list[str],
                  aggs: list[tuple[str, str, ast.Expr | None]]):
    """General grouped aggregation: lexicographic sort + segment reduce.

    Output capacity equals input capacity (#groups ≤ #rows); invalid rows
    sort last via a leading invalid flag and produce masked-out segments.
    """
    agg_fns = [(name, fn, compile_expr(arg) if arg is not None else None)
               for name, fn, arg in aggs]

    def op(cols: Cols, mask):
        n = mask.shape[0]
        inv = (~mask).astype(jnp.int32)
        keys = [cols[c].astype(jnp.int64) for c in group_cols]
        vals = []
        for name, fn, argf in agg_fns:
            if fn == "count":
                vals.append(mask.astype(jnp.float64))
            else:
                v = argf(cols).astype(jnp.float64)
                if v.shape != mask.shape:
                    v = jnp.broadcast_to(v, mask.shape)
                vals.append(v)
        operands = [inv] + keys + vals + [mask]
        res = jax.lax.sort(operands, num_keys=1 + len(keys),
                           is_stable=False)
        s_inv = res[0]
        s_keys = res[1:1 + len(keys)]
        s_vals = res[1 + len(keys):-1]
        s_mask = res[-1]
        diff = s_inv[1:] != s_inv[:-1]
        for k in s_keys:
            diff = diff | (k[1:] != k[:-1])
        flags = jnp.concatenate([jnp.ones((1,), bool), diff])
        seg = jnp.cumsum(flags) - 1
        out: Cols = {}
        for c, k in zip(group_cols, s_keys):
            out[c] = jax.ops.segment_min(
                jnp.where(s_mask, k, INT64_SENTINEL), seg, num_segments=n)
        maskf = s_mask.astype(jnp.float64)
        for (name, fn, _), v in zip(agg_fns, s_vals):
            if fn in ("sum", "count"):
                out[name] = jax.ops.segment_sum(v * maskf, seg,
                                                num_segments=n)
            elif fn == "min":
                out[name] = jax.ops.segment_min(
                    jnp.where(s_mask, v, jnp.inf), seg, num_segments=n)
            elif fn == "max":
                out[name] = jax.ops.segment_max(
                    jnp.where(s_mask, v, -jnp.inf), seg, num_segments=n)
        out_mask = jax.ops.segment_max(s_mask, seg, num_segments=n)
        return out, out_mask
    return op


MERGE_FN = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


def merge_aggs_spec(aggs: list[tuple[str, str, ast.Expr | None]]):
    """Aggregation spec for merging partial states: re-aggregate the partial
    accumulator columns with the merge function (avg was already decomposed
    into sum/count by the binder)."""
    return [(name, MERGE_FN[fn], ast.Col(name)) for name, fn, _ in aggs]


# -- join -----------------------------------------------------------------------

def make_pk_join_probe(probe_key: str, build_key: str,
                       payload_cols: list[str]):
    """FK→PK equi-join: binary-search probe against the sorted build side.

    Build keys are unique (PK) so each probe row matches ≤ 1 build row.
    Output occupies the probe block; misses/invalid rows are masked out.
    """

    def op(probe_cols: Cols, probe_mask, build_cols: Cols, build_mask):
        bk = jnp.where(build_mask, build_cols[build_key].astype(jnp.int64),
                       INT64_SENTINEL)
        order = jnp.argsort(bk)
        sk = bk[order]
        pk = probe_cols[probe_key].astype(jnp.int64)
        pos = jnp.searchsorted(sk, pk)
        pos_c = jnp.clip(pos, 0, sk.shape[0] - 1)
        hit = (sk[pos_c] == pk) & probe_mask & (pk != INT64_SENTINEL)
        sel = order[pos_c]
        out = dict(probe_cols)
        for c in payload_cols:
            if c not in out:
                out[c] = build_cols[c][sel]
        return out, hit
    return op


# -- exchange partitioning -------------------------------------------------------

def make_hash_partitioner(key_cols: list[str], n_dest: int):
    """Appends a __dest column (hash of the key columns mod n_dest)."""

    def op(cols: Cols, mask):
        h = combine_hash_jnp([cols[c] for c in key_cols])
        dest = (h % jnp.uint64(n_dest)).astype(jnp.int32)
        out = dict(cols)
        out["__dest"] = jnp.where(mask, dest, -1)
        return out, mask
    return op


def np_key_hash(columns: dict[str, np.ndarray],
                key_cols: list[str]) -> np.ndarray:
    """Combined uint64 key hash — shared by destination routing and the
    per-partition distinct-key sketches workers emit for the adaptive
    re-optimizer."""
    return combine_hash_np([columns[c] for c in key_cols])


def np_hash_dest(columns: dict[str, np.ndarray], key_cols: list[str],
                 n_dest: int) -> np.ndarray:
    h = np_key_hash(columns, key_cols)
    return (h % np.uint64(n_dest)).astype(np.int32)


_NP_MERGE = {"sum": np.add, "min": np.minimum, "max": np.maximum}


def np_combine_partials(cols: dict[str, np.ndarray], group_cols: list[str],
                        aggs: list[tuple[str, str]]) -> dict[str, np.ndarray]:
    """Re-combine mergeable partial-aggregate states on the host.

    The multi-level exchange's merge wave collapses the partial states of
    its producer group before re-partitioning: rows sharing a group key
    are folded with the merge function of each aggregate column (sums
    add, counts were already decomposed to sums, min/max reduce). Order-
    independent up to float rounding, like the downstream merge_agg.
    """
    n = len(next(iter(cols.values()))) if cols else 0
    if n == 0:
        return cols
    if not group_cols:
        return {name: _NP_MERGE[fn].reduce(cols[name], keepdims=True)
                for name, fn in aggs}
    keys = [cols[c] for c in group_cols]
    order = np.lexsort(keys[::-1])
    skeys = [k[order] for k in keys]
    diff = np.zeros(n, bool)
    for k in skeys:
        diff[1:] |= k[1:] != k[:-1]
    diff[0] = True
    starts = np.flatnonzero(diff)
    out = {c: k[starts] for c, k in zip(group_cols, skeys)}
    for name, fn in aggs:
        out[name] = _NP_MERGE[fn].reduceat(cols[name][order], starts)
    return out


# -- distinct-key sketches (KMV) -------------------------------------------------

KMV_K = 32


def kmv_sketch(hashes: np.ndarray, k: int = KMV_K) -> list[int]:
    """K-minimum-values sketch of a uint64 hash column: the ``k``
    smallest *distinct* hash values. Tiny, mergeable, and order-free —
    workers attach one per output partition so the coordinator can
    estimate distinct join/group keys without a second pass."""
    if hashes.size == 0:
        return []
    u = np.unique(hashes)
    return [int(x) for x in u[:k]]


def kmv_merge(sketches: list[list[int]], k: int = KMV_K) -> list[int]:
    """Union of per-worker sketches (min-k of the combined value set)."""
    all_vals = [v for s in sketches for v in s]
    if not all_vals:
        return []
    u = np.unique(np.array(all_vals, dtype=np.uint64))
    return [int(x) for x in u[:k]]


def kmv_estimate(sketch: list[int], k: int = KMV_K) -> int:
    """Distinct-count estimate: exact below ``k`` values, else the
    classic (k-1) / kth-minimum fraction of the uint64 hash space."""
    if len(sketch) < k:
        return len(sketch)
    kth = max(sketch[k - 1], 1)
    return int((k - 1) * (2.0 ** 64) / kth)
