"""Multi-query DAG validation and topological ordering (service tier).

``QueryService.submit_dag`` takes a list of statements plus a
``depends_on`` edge map (statement index → indices it waits for). The
service only *admits* a node once every dependency SUCCEEDED, so edges
order execution; data sharing needs no edges at all — any two nodes
containing the same subplan (semantic hash) share one materialization
through the result registry automatically, whichever runs first.
"""

from __future__ import annotations


def validate_dag(n: int, depends_on: dict[int, list[int]]) -> None:
    """Reject out-of-range, self-referential, or cyclic edge maps."""
    for node, deps in depends_on.items():
        if not 0 <= node < n:
            raise ValueError(f"DAG node {node} out of range (n={n})")
        for d in deps:
            if not 0 <= d < n:
                raise ValueError(
                    f"DAG dependency {d} of node {node} out of range")
            if d == node:
                raise ValueError(f"DAG node {node} depends on itself")
    if topological_order(n, depends_on) is None:
        raise ValueError("DAG contains a dependency cycle")


def topological_order(n: int,
                      depends_on: dict[int, list[int]]) -> list[int] | None:
    """Kahn's algorithm over ``depends_on``; None if cyclic. Ties keep
    submission (index) order, so the schedule is deterministic."""
    deps = {i: set(depends_on.get(i, ())) for i in range(n)}
    order: list[int] = []
    ready = sorted(i for i in range(n) if not deps[i])
    while ready:
        node = ready.pop(0)
        order.append(node)
        newly = sorted(
            i for i in range(n)
            if node in deps[i] and not (deps[i] - set(order)))
        for i in newly:
            if i not in ready and i not in order:
                ready.append(i)
        ready.sort()
    return order if len(order) == n else None
