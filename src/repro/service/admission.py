"""Multi-tenant admission over the shared platform quota (service tier).

Three enforcement layers:

  * **fair share** — each tenant's weight is registered with the
    platform's ``AdmissionController`` (``set_share``); every fragment a
    tenant's queries invoke charges its group, and freed slots go to the
    weighted group with the largest deficit (normalized admitted work).
    This is slot-granular, so the invocation split converges to the
    weight ratio under sustained contention no matter how queries are
    shaped — extending the priority+aging scheduler, which still orders
    waiters *within* a group.

  * **cost budgets** — cents per tenant per sliding window, charged from
    each finished query's actual cost breakdown. A tenant over
    ``degrade_fraction`` of its budget is *degraded* (its queries run at
    the tenant's minimum fleet: cheapest dollars, slowest latency); a
    tenant at/over budget is *throttled* — its queued requests simply
    wait for the window to roll over, which always happens, so
    throttling is bounded, never starvation.

  * **deadline ordering** — the dispatcher admits queued requests in
    ``deadline_order``: tightest *feasible* deadline first (earliest
    deadline first, gated on the tenant's observed-runtime EMA fitting
    the deadline), then deadline-free requests FIFO, then
    infeasible-deadline requests last — a request whose SLO is already
    lost never displaces one whose SLO can still be met.

This module is pure policy on in-process state plus the platform's
admission ledger; durable request state lives in the ledger
(``repro.service.ledger``).
"""

from __future__ import annotations

import dataclasses
import threading
import time

from repro.core.platform import AdmissionController


@dataclasses.dataclass
class TenantConfig:
    """Per-tenant service policy."""

    name: str
    weight: float = 1.0                 # fair-share weight (> 0)
    priority: int = 0                   # default query priority
    budget_cents: float | None = None   # None → unmetered
    budget_window_s: float = 60.0       # wall-clock budget window
    deadline_s: float | None = None     # default SLO deadline (sim s)
    min_fleet: int = 1                  # degraded-dispatch fleet clamp
    # fraction of the budget past which dispatch degrades to min_fleet
    degrade_fraction: float = 0.8


@dataclasses.dataclass
class _TenantState:
    config: TenantConfig
    window_start: float
    spent_cents: float = 0.0
    lifetime_cents: float = 0.0
    throttled_admissions: int = 0       # admissions deferred on budget
    degraded_dispatches: int = 0
    runtime_ema_s: float | None = None  # observed sim latency (EMA)


#: EMA weight for per-tenant runtime observations — recent queries
#: dominate, so a tenant that switches workload shape re-converges in a
#: handful of queries.
RUNTIME_EMA_ALPHA = 0.3


def deadline_order(entries, runtime_estimate):
    """Admission order for QUEUED ledger entries (EDF with a
    feasibility gate):

      1. requests with a *feasible* deadline, tightest deadline first —
         feasible means the tenant's observed runtime estimate fits
         inside the deadline (no estimate yet → optimistically
         feasible);
      2. requests with no deadline, oldest submission first (plain
         FIFO — the pre-deadline behavior);
      3. requests whose deadline is *infeasible* (estimate already
         exceeds it), oldest first. They would likely miss anyway, so
         they must not displace requests whose SLO can still be met —
         but they stay in the queue and run, they are never dropped.

    ``runtime_estimate`` maps a tenant name (or None) to an estimated
    sim latency in seconds, or None when unknown."""
    def rank(e):
        if e.deadline_s is None:
            return (1, 0.0, e.submitted_at, e.request_id)
        est = runtime_estimate(e.tenant)
        if est is not None and est > e.deadline_s:
            return (2, 0.0, e.submitted_at, e.request_id)
        return (0, e.deadline_s, e.submitted_at, e.request_id)
    return sorted(entries, key=rank)


class FairShareAdmission:
    """Tenant registry + budget meter in front of the platform quota."""

    def __init__(self, admission: AdmissionController,
                 tenants: tuple[TenantConfig, ...] = ()):
        self.admission = admission
        self._lock = threading.Lock()
        self._tenants: dict[str, _TenantState] = {}
        for cfg in tenants:
            self.register(cfg)

    def register(self, config: TenantConfig) -> None:
        with self._lock:
            self._tenants[config.name] = _TenantState(
                config, window_start=time.monotonic())
        self.admission.set_share(config.name, config.weight)

    def config(self, tenant: str | None) -> TenantConfig | None:
        with self._lock:
            st = self._tenants.get(tenant) if tenant else None
        return st.config if st else None

    # -- budget metering -----------------------------------------------------
    def _roll_window_locked(self, st: _TenantState) -> None:
        now = time.monotonic()
        if now - st.window_start >= st.config.budget_window_s:
            st.window_start = now
            st.spent_cents = 0.0

    def charge(self, tenant: str | None, cents: float) -> None:
        """Charge a finished query's actual cost to its tenant."""
        if tenant is None or cents <= 0:
            return
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None:
                return
            self._roll_window_locked(st)
            st.spent_cents += cents
            st.lifetime_cents += cents

    # -- runtime estimation (deadline feasibility) ----------------------------
    def observe_runtime(self, tenant: str | None, sim_s: float) -> None:
        """Fold a finished query's simulated latency into the tenant's
        runtime EMA — the feasibility estimate ``deadline_order``
        consults for its queue ordering."""
        if tenant is None or sim_s <= 0:
            return
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None:
                return
            if st.runtime_ema_s is None:
                st.runtime_ema_s = sim_s
            else:
                st.runtime_ema_s += RUNTIME_EMA_ALPHA * (
                    sim_s - st.runtime_ema_s)

    def runtime_estimate(self, tenant: str | None) -> float | None:
        with self._lock:
            st = self._tenants.get(tenant) if tenant else None
        return st.runtime_ema_s if st else None

    def admissible(self, tenant: str | None) -> bool:
        """May this tenant's next request be admitted *now*? False only
        while the tenant is at/over budget inside the current window —
        the window rolls over, so a throttled tenant is never starved."""
        if tenant is None:
            return True
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None or st.config.budget_cents is None:
                return True
            self._roll_window_locked(st)
            if st.spent_cents >= st.config.budget_cents:
                st.throttled_admissions += 1
                return False
            return True

    def degraded(self, tenant: str | None) -> bool:
        """Past ``degrade_fraction`` of the window budget: still
        admitted, but dispatched at the tenant's minimum fleet."""
        if tenant is None:
            return False
        with self._lock:
            st = self._tenants.get(tenant)
            if st is None or st.config.budget_cents is None:
                return False
            self._roll_window_locked(st)
            if st.spent_cents >= \
                    st.config.degrade_fraction * st.config.budget_cents:
                st.degraded_dispatches += 1
                return True
            return False

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            tenants = {
                name: {
                    "weight": st.config.weight,
                    "budget_cents": st.config.budget_cents,
                    "window_spent_cents": st.spent_cents,
                    "lifetime_cents": st.lifetime_cents,
                    "throttled_admissions": st.throttled_admissions,
                    "degraded_dispatches": st.degraded_dispatches,
                    "runtime_ema_s": st.runtime_ema_s,
                } for name, st in self._tenants.items()}
        admitted = self.admission.admitted_by_group
        for name, t in tenants.items():
            t["admitted_slots"] = admitted.get(name, 0)
        return tenants
