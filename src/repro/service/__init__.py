"""Skyrise query *service* tier (ISSUE 6).

Layers a durable, multi-tenant, SLO-aware front end over the session /
engine stack: request ledger on the KV tier (``ledger``), weighted
fair-share admission with cost budgets (``admission``), multi-query DAG
scheduling (``dag``), and the orchestrating ``QueryService``
(``service``).
"""

from repro.service.admission import FairShareAdmission, TenantConfig
from repro.service.dag import topological_order, validate_dag
from repro.service.ledger import (LedgerConflict, LedgerEntry,
                                  RequestLedger, RequestStatus)
from repro.service.service import (QueryService, RequestFailed,
                                   ServiceHandle, ServiceResult)

__all__ = [
    "FairShareAdmission", "TenantConfig",
    "topological_order", "validate_dag",
    "LedgerConflict", "LedgerEntry", "RequestLedger", "RequestStatus",
    "QueryService", "RequestFailed", "ServiceHandle", "ServiceResult",
]
