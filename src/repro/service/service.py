"""The query service: durable, multi-tenant front end of a session.

``QueryService`` wraps a ``SkyriseSession`` with the pieces a *shared*
serverless SQL endpoint needs (ISSUE 6 / ROADMAP "query service tier"):

  * every request is persisted in the KV-tier request ledger before
    anything runs — the service process is stateless and restartable;
  * a dispatcher thread admits QUEUED requests when their tenant is
    within budget and their DAG dependencies SUCCEEDED, claims them
    under this instance's ownership lease, and hands them to the
    session scheduler (fair share is enforced per *fragment slot* by
    the platform's admission ledger, so it holds across queries of any
    shape);
  * SLO deadlines ride the request into the engine: the remaining
    deadline becomes per-stage latency budgets for cost-optimal fleet
    sizing, escalating at barriers when the query runs behind — and
    they order the queue itself (``deadline_order``): tightest
    *feasible* deadline first, judged against the tenant's
    observed-runtime EMA, so an already-lost SLO never displaces a
    winnable one;
  * on completion the result pointer (object locations + cost) is
    written back to the ledger and the tenant's budget is charged;
    over-budget tenants degrade to their minimum fleet, then throttle
    until the window rolls over;
  * a second (or restarted) instance recovers: expired leases re-queue
    orphaned ADMITTED/RUNNING entries, and re-execution is deduped
    against already-published pipeline results by the semantic-hash
    registry — the fleet runs at most once per pipeline.

Service handles resolve through the ledger's *watch* primitive (the
same store-level notification seam the registry waiters use), so a
client can await a request submitted by a different process.
"""

from __future__ import annotations

import threading
import time
import uuid

import numpy as np

from repro.api.session import SkyriseSession
from repro.core.engine import QueryCancelled
from repro.service.admission import (FairShareAdmission, TenantConfig,
                                     deadline_order)
from repro.service.dag import validate_dag
from repro.service.ledger import (LedgerConflict, LedgerEntry,
                                  RequestLedger, RequestStatus)
from repro.storage.io_handlers import InputHandler
from repro.storage.object_store import ObjectStore


class RequestFailed(RuntimeError):
    """The service recorded the request as FAILED."""


class ServiceResult:
    """Client-side view of a SUCCEEDED ledger entry's result pointer."""

    def __init__(self, entry: LedgerEntry):
        self.entry = entry
        pointer = entry.result or {}
        self.locations: list[str] = list(pointer.get("locations", ()))
        self.output_names: list[str] = list(
            pointer.get("output_names", ()))
        self.cost_cents: float = pointer.get("cost_cents", 0.0)
        self.sim_latency_s: float = pointer.get("sim_latency_s", 0.0)
        self.cache_hits: int = pointer.get("cache_hits", 0)
        self.deadline_missed: bool = pointer.get("deadline_missed", False)
        self.pipelined_pipelines: int = pointer.get(
            "pipelined_pipelines", 0)
        self.overlap_saved_s: float = pointer.get("overlap_saved_s", 0.0)

    def fetch(self, store: ObjectStore) -> dict[str, np.ndarray]:
        ih = InputHandler(store)
        parts = [ih.read_table(loc)[0] for loc in self.locations]
        if len(parts) == 1:
            return parts[0]
        return {k: np.concatenate([p[k] for p in parts])
                for k in parts[0]}


class ServiceHandle:
    """Durable request handle: resolves through the ledger, so it works
    across service restarts and even from a different process."""

    def __init__(self, request_id: str, service: "QueryService"):
        self.request_id = request_id
        self._service = service

    def __repr__(self) -> str:
        return f"<ServiceHandle {self.request_id} {self.status().value}>"

    def entry(self) -> LedgerEntry:
        entry = self._service.ledger.get(self.request_id)
        if entry is None:
            raise KeyError(f"request {self.request_id} not in ledger")
        return entry

    def status(self) -> RequestStatus:
        return self.entry().status

    def wait(self, timeout: float | None = None) -> LedgerEntry:
        """Block (via ledger watch) until the request is terminal."""
        ledger = self._service.ledger
        deadline = None if timeout is None \
            else time.monotonic() + timeout
        while True:
            token = ledger.version_token(self.request_id)
            entry = self.entry()
            if entry.status.terminal:
                return entry
            left = None if deadline is None \
                else deadline - time.monotonic()
            if left is not None and left <= 0:
                raise TimeoutError(
                    f"request {self.request_id} still "
                    f"{entry.status.value} after {timeout}s")
            # bounded watch: lease expiry / re-queue also changes the
            # record, so progress (or recovery) always wakes us
            ledger.watch(self.request_id, token,
                         timeout_s=1.0 if left is None
                         else min(left, 1.0))

    def result(self, timeout: float | None = None) -> ServiceResult:
        entry = self.wait(timeout)
        if entry.status is RequestStatus.SUCCEEDED:
            return ServiceResult(entry)
        if entry.status is RequestStatus.CANCELLED:
            raise QueryCancelled(f"request {self.request_id} cancelled")
        raise RequestFailed(
            f"request {self.request_id} failed: {entry.error}")

    def fetch(self, timeout: float | None = None):
        return self.result(timeout).fetch(self._service.session.store)

    def cancel(self) -> bool:
        return self._service.cancel(self.request_id)


class QueryService:
    """Durable multi-tenant query endpoint over one session."""

    def __init__(self, session: SkyriseSession, *,
                 tenants: tuple[TenantConfig, ...] = (),
                 ledger: RequestLedger | None = None,
                 lease_ttl_s: float = 30.0,
                 service_id: str | None = None,
                 poll_interval_s: float = 0.02,
                 start: bool = True):
        self.session = session
        self.ledger = ledger if ledger is not None else RequestLedger(
            session.store, lease_ttl_s=lease_ttl_s)
        self.admission = FairShareAdmission(session.platform.admission,
                                            tuple(tenants))
        self.service_id = service_id or f"svc-{uuid.uuid4().hex[:8]}"
        self._lock = threading.Lock()
        self._running: dict[str, object] = {}   # rid → session handle
        self._closing = threading.Event()
        self._thread: threading.Thread | None = None
        self.deadline_misses = 0
        self.recovered_requests = 0
        if start:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Recover orphaned ledger entries, then start dispatching."""
        if self._thread is not None:
            return
        self.recovered_requests += len(self.ledger.recover_expired())
        self._closing.clear()
        self._thread = threading.Thread(
            target=self._dispatch_loop,
            name=f"skyrise-{self.service_id}", daemon=True)
        self._thread.start()

    def close(self, *, drain: bool = True) -> None:
        """Graceful shutdown: optionally wait for owned requests to
        finish (and write their terminal records) before stopping."""
        if drain:
            self.drain()
        self.kill()

    def kill(self) -> None:
        """Abrupt stop — the process-death analog used by the recovery
        tests: the dispatcher halts, owned ADMITTED/RUNNING ledger
        entries are left to expire their leases. Queries already handed
        to the session keep running (their published pipeline results
        are what makes recovery duplicate-free)."""
        self._closing.set()
        thread, self._thread = self._thread, None
        if thread is not None:
            thread.join()

    def drain(self, timeout: float | None = None) -> None:
        """Block until every request this instance owns is terminal."""
        deadline = None if timeout is None \
            else time.monotonic() + timeout
        while True:
            with self._lock:
                busy = bool(self._running)
            if not busy:
                # QUEUED entries this instance could still admit
                queued = self.ledger.entries(
                    status=RequestStatus.QUEUED)
                if not queued or self._thread is None:
                    return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("service drain timed out")
            time.sleep(0.01)

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- client API ----------------------------------------------------------
    def submit(self, sql: str, *, tenant: str | None = None,
               priority: int | None = None,
               deadline_s: float | None = None,
               request_id: str | None = None,
               dag_id: str | None = None,
               depends_on: list[str] | None = None) -> ServiceHandle:
        """Persist a request and return its durable handle. Tenant
        defaults (priority, deadline) fill unspecified fields."""
        cfg = self.admission.config(tenant)
        if priority is None:
            priority = cfg.priority if cfg else 0
        if deadline_s is None and cfg is not None:
            deadline_s = cfg.deadline_s
        entry = self.ledger.submit(
            sql, tenant=tenant, priority=priority, deadline_s=deadline_s,
            request_id=request_id, dag_id=dag_id, depends_on=depends_on)
        return ServiceHandle(entry.request_id, self)

    def submit_dag(self, statements: list[str],
                   depends_on: dict[int, list[int]] | None = None, *,
                   tenant: str | None = None,
                   priority: int | None = None,
                   deadline_s: float | None = None) -> list[ServiceHandle]:
        """Submit a DAG of queries; node i waits for ``depends_on[i]``.

        Ordering is all an edge buys — *data* sharing is automatic:
        nodes containing the same subplan share one materialization
        through the semantic-hash registry, edges or not.
        """
        depends_on = depends_on or {}
        validate_dag(len(statements), depends_on)
        dag_id = f"dag-{uuid.uuid4().hex[:8]}"
        rids = [f"{dag_id}-n{i}" for i in range(len(statements))]
        return [self.submit(sql, tenant=tenant, priority=priority,
                            deadline_s=deadline_s, request_id=rids[i],
                            dag_id=dag_id,
                            depends_on=[rids[d] for d in
                                        depends_on.get(i, ())])
                for i, sql in enumerate(statements)]

    def cancel(self, request_id: str) -> bool:
        """Cancel a request: QUEUED entries terminate immediately;
        RUNNING ones owned here are cancelled at the next boundary."""
        entry = self.ledger.get(request_id)
        if entry is None or entry.status.terminal:
            return entry is not None \
                and entry.status is RequestStatus.CANCELLED
        if entry.status is RequestStatus.QUEUED:
            try:
                self.ledger.transition(request_id,
                                       RequestStatus.CANCELLED,
                                       expected_version=entry.version)
                return True
            except LedgerConflict:
                return self.cancel(request_id)    # raced: re-read
        with self._lock:
            handle = self._running.get(request_id)
        if handle is not None:
            handle.cancel()
            return True
        return False    # owned by another instance: its lease decides

    def stats(self) -> dict:
        by_status: dict[str, int] = {}
        for entry in self.ledger.entries():
            by_status[entry.status.value] = \
                by_status.get(entry.status.value, 0) + 1
        return {
            "service_id": self.service_id,
            "requests_by_status": by_status,
            "tenants": self.admission.stats(),
            "deadline_misses": self.deadline_misses,
            "recovered_requests": self.recovered_requests,
        }

    # -- dispatcher ----------------------------------------------------------
    def _dispatch_loop(self) -> None:
        from repro.core.chaos import ChaosKill
        poll = 0.02
        last_recover = time.monotonic()
        while not self._closing.is_set():
            try:
                self._harvest_finished()
                self._renew_leases()
                now = time.monotonic()
                if now - last_recover >= self.ledger.lease_ttl_s / 3:
                    self.recovered_requests += len(
                        self.ledger.recover_expired())
                    last_recover = now
                self._admit_queued()
            except ChaosKill:
                # instance death: the dispatcher stops cold, leaving
                # ledger entries to lease expiry — a peer (or a restart)
                # recovers them via recover_expired
                self._closing.set()
                return
            self._closing.wait(poll)

    def _admit_queued(self) -> None:
        queued = deadline_order(
            self.ledger.entries(status=RequestStatus.QUEUED),
            self.admission.runtime_estimate)
        for entry in queued:
            if self._closing.is_set():
                return
            ready, failed_dep = self._deps_state(entry)
            if failed_dep is not None:
                try:
                    self.ledger.transition(
                        entry.request_id, RequestStatus.FAILED,
                        expected_version=entry.version,
                        error=f"dependency {failed_dep} did not succeed")
                except LedgerConflict:
                    pass
                continue
            if not ready or not self.admission.admissible(entry.tenant):
                continue
            claimed = self.ledger.claim(entry.request_id,
                                        self.service_id)
            if claimed is None:
                continue    # another instance admitted it
            self._dispatch(claimed)

    def _deps_state(self, entry: LedgerEntry):
        """(all dependencies SUCCEEDED?, first dead dependency id)."""
        for rid in entry.depends_on:
            dep = self.ledger.get(rid)
            if dep is None:
                return False, rid
            if dep.status in (RequestStatus.FAILED,
                              RequestStatus.CANCELLED):
                return False, rid
            if dep.status is not RequestStatus.SUCCEEDED:
                return False, None
        return True, None

    def _dispatch(self, entry: LedgerEntry) -> None:
        cfg = self.admission.config(entry.tenant)
        fleet_cap = None
        if cfg is not None and self.admission.degraded(entry.tenant):
            fleet_cap = cfg.min_fleet
        try:
            handle = self.session.submit(
                entry.sql, priority=entry.priority, tenant=entry.tenant,
                deadline_s=entry.deadline_s, fleet_cap=fleet_cap)
        except BaseException as e:  # noqa: BLE001 - recorded, not raised
            try:
                self.ledger.transition(
                    entry.request_id, RequestStatus.FAILED,
                    if_owner=self.service_id, error=str(e))
            except LedgerConflict:
                pass
            return
        try:
            self.ledger.transition(entry.request_id,
                                   RequestStatus.RUNNING,
                                   if_owner=self.service_id)
        except LedgerConflict:
            # lease was stolen between claim and dispatch (pathological
            # TTL); the duplicate run is absorbed by the result cache
            pass
        with self._lock:
            self._running[entry.request_id] = handle

    def _renew_leases(self) -> None:
        with self._lock:
            rids = list(self._running)
        for rid in rids:
            self.ledger.renew_lease(rid, self.service_id)

    def _harvest_finished(self) -> None:
        with self._lock:
            items = list(self._running.items())
        for rid, handle in items:
            if not handle.done():
                continue
            self._record_terminal(rid, handle)
            with self._lock:
                self._running.pop(rid, None)

    def _record_terminal(self, rid: str, handle) -> None:
        entry = self.ledger.get(rid)
        if entry is None:
            return
        try:
            result = handle.result(timeout=0)
        except QueryCancelled:
            self._transition_safe(rid, RequestStatus.CANCELLED)
            return
        except BaseException as e:  # noqa: BLE001 - recorded in ledger
            self._transition_safe(rid, RequestStatus.FAILED,
                                  error=str(e))
            return
        stats = result.stats
        missed = (entry.deadline_s is not None
                  and stats.sim_latency_s > entry.deadline_s)
        if missed:
            self.deadline_misses += 1
        self.admission.charge(entry.tenant, stats.cost.total_cents)
        self.admission.observe_runtime(entry.tenant, stats.sim_latency_s)
        self._transition_safe(rid, RequestStatus.SUCCEEDED, result={
            "locations": result.locations,
            "output_names": result.output_names,
            "cost_cents": stats.cost.total_cents,
            "sim_latency_s": stats.sim_latency_s,
            "cache_hits": stats.cache_hits,
            "deduped": sum(1 for p in stats.pipelines if p.deduped),
            "deadline_missed": missed,
            # pipelined execution telemetry (barrier-free PR): how many
            # pipelines started on partial input, and the overlap they
            # reclaimed from the simulated critical path
            "pipelined_pipelines": sum(
                1 for p in stats.pipelines if p.pipelined),
            "overlap_saved_s": sum(
                p.overlap_saved_s for p in stats.pipelines),
        })

    def _transition_safe(self, rid: str, to: RequestStatus,
                         **fields) -> None:
        try:
            self.ledger.transition(rid, to, if_owner=self.service_id,
                                   **fields)
        except LedgerConflict:
            # entry was re-queued/stolen while the query ran: the other
            # instance's execution will write the terminal record; ours
            # only duplicated cached pipelines
            pass
