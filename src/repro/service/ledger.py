"""Durable request ledger on the KV tier (service subsystem).

Every query submitted to the service gets a persistent record in the
shared low-latency KV tier (DynamoDB analog) — *not* in any service
process — tracking it through an explicit lifecycle::

    QUEUED → ADMITTED → RUNNING → SUCCEEDED | FAILED | CANCELLED
                 ↑__________________________________|
                 (lease expiry re-queues orphans)

Coordination state living in serverless storage is what lets the service
itself be serverless: a restarted (or second) service process reads the
ledger and picks up exactly where the dead one stopped. The concurrency
protocol is the same ownership-token pattern as the result registry's
claims:

  * every write is a *versioned put* — a compare-and-swap analog: the
    writer re-reads the entry, checks the version (and, for owned
    entries, its ownership token) inside a critical section, and writes
    version+1; a stale writer loses and raises ``LedgerConflict``;
  * ADMITTED/RUNNING entries carry the owning service's token and a
    TTL lease; ``recover_expired`` re-queues entries whose lease ran
    out (owner died mid-flight), bumping ``attempt`` — workers are
    idempotent single-object writers, so a re-run after a *published*
    result is absorbed by the semantic result cache instead of
    re-executing the fleet.

Like the registry, in-process mutual exclusion (one module lock) stands
in for the KV store's conditional-put primitive; cross-process safety
comes from the versioned read-check-write being the only write path.
"""

from __future__ import annotations

import dataclasses
import enum
import threading
import time
import uuid

import msgpack

from repro.storage.object_store import ObjectStore

_LEDGER_LOCK = threading.Lock()


class RequestStatus(str, enum.Enum):
    QUEUED = "QUEUED"
    ADMITTED = "ADMITTED"
    RUNNING = "RUNNING"
    SUCCEEDED = "SUCCEEDED"
    FAILED = "FAILED"
    CANCELLED = "CANCELLED"

    @property
    def terminal(self) -> bool:
        return self in (RequestStatus.SUCCEEDED, RequestStatus.FAILED,
                        RequestStatus.CANCELLED)


# Legal status transitions; ADMITTED/RUNNING → QUEUED is the lease-expiry
# re-queue path (orphaned owner), nothing leaves a terminal state.
_ALLOWED: dict[RequestStatus, set[RequestStatus]] = {
    RequestStatus.QUEUED: {RequestStatus.ADMITTED, RequestStatus.CANCELLED,
                           RequestStatus.FAILED},
    RequestStatus.ADMITTED: {RequestStatus.RUNNING, RequestStatus.QUEUED,
                             RequestStatus.CANCELLED, RequestStatus.FAILED},
    RequestStatus.RUNNING: {RequestStatus.SUCCEEDED, RequestStatus.FAILED,
                            RequestStatus.CANCELLED, RequestStatus.QUEUED},
    RequestStatus.SUCCEEDED: set(),
    RequestStatus.FAILED: set(),
    RequestStatus.CANCELLED: set(),
}


class LedgerConflict(RuntimeError):
    """A versioned put lost its compare-and-swap (stale version, foreign
    owner, or illegal transition)."""


@dataclasses.dataclass
class LedgerEntry:
    """One persistent query record (the KV tier's unit of truth)."""

    request_id: str
    sql: str
    tenant: str | None = None
    priority: int = 0
    deadline_s: float | None = None
    status: RequestStatus = RequestStatus.QUEUED
    version: int = 1
    owner: str | None = None        # service token while ADMITTED/RUNNING
    lease_expires: float = 0.0      # wall-clock lease deadline
    submitted_at: float = 0.0
    started_at: float | None = None
    finished_at: float | None = None
    attempt: int = 0                # lease-expiry re-queues bump this
    result: dict | None = None      # result pointer once SUCCEEDED
    error: str | None = None
    dag_id: str | None = None
    depends_on: list[str] = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["status"] = self.status.value
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LedgerEntry":
        d = dict(d)
        d["status"] = RequestStatus(d["status"])
        d["depends_on"] = list(d.get("depends_on") or [])
        return cls(**d)


class RequestLedger:
    """Versioned-put request records on the shared KV tier."""

    def __init__(self, store: ObjectStore, namespace: str = "ledger",
                 lease_ttl_s: float = 30.0):
        self.store = store.with_tier("dynamodb")
        self.namespace = namespace
        self.lease_ttl_s = lease_ttl_s

    def _key(self, request_id: str) -> str:
        return f"{self.namespace}/{request_id}"

    def _read(self, request_id: str) -> LedgerEntry | None:
        key = self._key(request_id)
        if not self.store.exists(key):
            return None
        return LedgerEntry.from_dict(
            msgpack.unpackb(self.store.get(key).data))

    def _write(self, entry: LedgerEntry) -> None:
        self.store.put(self._key(entry.request_id),
                       msgpack.packb(entry.to_dict()))

    # -- submission ----------------------------------------------------------
    def submit(self, sql: str, *, tenant: str | None = None,
               priority: int = 0, deadline_s: float | None = None,
               request_id: str | None = None,
               dag_id: str | None = None,
               depends_on: list[str] | None = None) -> LedgerEntry:
        """Persist a new QUEUED record; the id is the durable handle."""
        entry = LedgerEntry(
            request_id=request_id or uuid.uuid4().hex,
            sql=sql, tenant=tenant, priority=priority,
            deadline_s=deadline_s, submitted_at=time.time(),
            dag_id=dag_id, depends_on=list(depends_on or []))
        with _LEDGER_LOCK:
            if self.store.exists(self._key(entry.request_id)):
                raise LedgerConflict(
                    f"request {entry.request_id} already exists")
            self._write(entry)
        return entry

    # -- reads ---------------------------------------------------------------
    def get(self, request_id: str) -> LedgerEntry | None:
        return self._read(request_id)

    def entries(self, *, tenant: str | None = None,
                status: RequestStatus | None = None) -> list[LedgerEntry]:
        """All records (optionally filtered), oldest submission first."""
        out = []
        for key in self.store.list(f"{self.namespace}/"):
            entry = self._read(key[len(self.namespace) + 1:])
            if entry is None:
                continue
            if tenant is not None and entry.tenant != tenant:
                continue
            if status is not None and entry.status is not status:
                continue
            out.append(entry)
        out.sort(key=lambda e: (e.submitted_at, e.request_id))
        return out

    # -- versioned-put transitions ------------------------------------------
    _ANY_OWNER = object()       # sentinel: skip the ownership guard

    def transition(self, request_id: str, to: RequestStatus, *,
                   expected_version: int | None = None,
                   if_owner=_ANY_OWNER,
                   **fields) -> LedgerEntry:
        """Compare-and-swap the record to status ``to``.

        ``expected_version`` (when given) must match the stored version;
        ``if_owner`` (when given — ``None`` means *must be unowned*)
        must match the stored ownership token. Extra ``fields``
        overwrite entry attributes in the same put. Raises
        ``LedgerConflict`` when the swap loses.
        """
        with _LEDGER_LOCK:
            entry = self._read(request_id)
            if entry is None:
                raise LedgerConflict(f"request {request_id} not found")
            if expected_version is not None \
                    and entry.version != expected_version:
                raise LedgerConflict(
                    f"request {request_id}: version {entry.version} != "
                    f"expected {expected_version}")
            if if_owner is not RequestLedger._ANY_OWNER \
                    and entry.owner != if_owner:
                raise LedgerConflict(
                    f"request {request_id}: owned by {entry.owner}, "
                    f"not {if_owner}")
            if to not in _ALLOWED[entry.status]:
                raise LedgerConflict(
                    f"request {request_id}: illegal transition "
                    f"{entry.status.value} → {to.value}")
            entry.status = to
            entry.version += 1
            for k, v in fields.items():
                setattr(entry, k, v)
            if to is RequestStatus.RUNNING and entry.started_at is None:
                entry.started_at = time.time()
            if to.terminal:
                entry.finished_at = time.time()
                entry.owner = None
                entry.lease_expires = 0.0
            if to is RequestStatus.QUEUED:     # re-queue: drop ownership
                entry.owner = None
                entry.lease_expires = 0.0
            self._write(entry)
            # chaos: the service instance dies right after the CAS
            # landed this transition — the entry is consistent but the
            # owner is gone; lease expiry must hand it to a peer
            chaos = getattr(self.store, "chaos", None)
            if chaos is not None:
                chaos.kill_once(f"ledger.{to.value}")
            return entry

    # -- ownership / leases --------------------------------------------------
    def claim(self, request_id: str, owner: str) -> LedgerEntry | None:
        """QUEUED → ADMITTED under ``owner``'s lease; None if the swap
        lost (someone else admitted it, or it is no longer QUEUED —
        only QUEUED → ADMITTED is a legal transition, so the status
        check rides on the transition table)."""
        try:
            return self.transition(
                request_id, RequestStatus.ADMITTED,
                if_owner=None,  # guard: only unowned entries claimable
                owner=owner,
                lease_expires=time.time() + self.lease_ttl_s)
        except LedgerConflict:
            return None

    def renew_lease(self, request_id: str, owner: str) -> bool:
        """Extend the owner's lease on a live entry; False if lost.

        An *expired* lease cannot be renewed even by its original owner:
        once the deadline passed, ``recover_expired`` may already have
        handed the request to a peer (or is about to) — a slow-but-alive
        owner renewing after expiry would resurrect ownership it no
        longer holds and run the query twice. The owner must treat the
        False as a fencing signal and drop the request."""
        with _LEDGER_LOCK:
            entry = self._read(request_id)
            if entry is None or entry.owner != owner \
                    or entry.status.terminal \
                    or entry.lease_expires < time.time():
                return False
            entry.version += 1
            entry.lease_expires = time.time() + self.lease_ttl_s
            self._write(entry)
            return True

    def recover_expired(self) -> list[LedgerEntry]:
        """Re-queue every ADMITTED/RUNNING entry whose lease expired
        (owner died mid-flight); returns the re-queued entries."""
        now = time.time()
        recovered = []
        for entry in self.entries():
            if entry.status in (RequestStatus.ADMITTED,
                                RequestStatus.RUNNING) \
                    and entry.lease_expires < now:
                try:
                    recovered.append(self.transition(
                        entry.request_id, RequestStatus.QUEUED,
                        expected_version=entry.version,
                        attempt=entry.attempt + 1))
                except LedgerConflict:
                    pass    # someone else recovered (or finished) it
        return recovered

    # -- notifications -------------------------------------------------------
    def version_token(self, request_id: str) -> str | None:
        return self.store.version(self._key(request_id))

    def watch(self, request_id: str, token: str | None = None, *,
              timeout_s: float | None = None,
              cancel_check=None) -> str | None:
        """Block until the record changes (store watch primitive)."""
        return self.store.watch(self._key(request_id), token,
                                timeout_s=timeout_s,
                                cancel_check=cancel_check)
