"""Serving path: cache construction, prefill, and single-token decode.

Cache layouts (leading axis = layers, so layer-scan threads cache slices):

  * attention archs: k/v (L, B, Hkv, C, hd); C = seq_len for full
    attention, C = sliding window for SWA archs (ring buffer, slot =
    pos % W — keys are RoPE-rotated at write time so ring order is
    irrelevant to softmax attention).
  * SSM/hybrid archs: ssm_state (L, B, H, N, P) + conv_state
    (L, B, K-1, conv_dim) — constant-size state, the reason ``long_500k``
    runs for these families.
  * enc-dec (whisper): self-attention cache + cross-attention K/V
    computed once from the encoder output.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.model import (_heads, _unheads, attention_sublayer,
                                ffn_sublayer, make_block_fn, ssm_sublayer)


def _scan_or_loop(layer, x, xs, n_layers: int, scan_layers: bool):
    """lax.scan over per-layer (params, cache) slices, or Python unroll
    (roofline harness mode — see model._layer_loop)."""
    if scan_layers:
        return jax.lax.scan(layer, x, xs)
    outs_acc = []
    for i in range(n_layers):
        xs_i = jax.tree.map(lambda a: a[i], xs)
        x, outs = layer(x, xs_i)
        outs_acc.append(outs)
    stacked = jax.tree.map(lambda *vs: jnp.stack(vs), *outs_acc)
    return x, stacked


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> dict:
    nl, hd = cfg.n_layers, cfg.head_dim_
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    C = cache_len(cfg, seq_len)
    if cfg.family != "ssm":
        cache["k"] = jnp.zeros((nl, batch, cfg.n_kv_heads, C, hd), dtype)
        cache["v"] = jnp.zeros((nl, batch, cfg.n_kv_heads, C, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        H, N, P = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        conv_dim = cfg.d_inner + 2 * N
        cache["ssm_state"] = jnp.zeros((nl, batch, H, N, P), jnp.float32)
        cache["conv_state"] = jnp.zeros(
            (nl, batch, cfg.conv_width - 1, conv_dim), jnp.float32)
    if cfg.enc_dec:
        cache["cross_k"] = jnp.zeros(
            (nl, batch, cfg.n_heads, cfg.enc_frames, hd), dtype)
        cache["cross_v"] = jnp.zeros(
            (nl, batch, cfg.n_heads, cfg.enc_frames, hd), dtype)
    return cache


# -- decode ------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, cache: dict, tokens,
                *, mesh=None, compute_dtype=jnp.bfloat16,
                scan_layers: bool = True):
    """tokens (B,) → (logits (B, V), new cache)."""
    pos = cache["pos"]
    x = params["embed"].astype(compute_dtype)[tokens][:, None, :]
    if mesh is not None:
        from repro.parallel.sharding import constrain, dp_axes_of
        x = constrain(mesh, x, (dp_axes_of(mesh), None, None))
    if cfg.enc_dec:
        x = x + params["dec_pos"].astype(compute_dtype)[None, pos][:, None]
    positions = pos[None]
    C = cache["k"].shape[3] if "k" in cache else 0
    slot = pos % C if (cfg.sliding_window and C) else pos
    hd = cfg.head_dim_

    def attn_decode(p, h, k_l, v_l):
        cd = h.dtype
        q = _heads(jnp.dot(h, p["wq"].astype(cd)), cfg.n_heads, hd)
        k = _heads(jnp.dot(h, p["wk"].astype(cd)), cfg.n_kv_heads, hd)
        v = _heads(jnp.dot(h, p["wv"].astype(cd)), cfg.n_kv_heads, hd)
        if cfg.qk_norm:
            q = L.rms_norm(q, p["q_norm"].astype(cd), cfg.norm_eps)
            k = L.rms_norm(k, p["k_norm"].astype(cd), cfg.norm_eps)
        if cfg.rope_fraction > 0:
            q = L.apply_rope(q, positions, fraction=cfg.rope_fraction,
                             theta=cfg.rope_theta)
            k = L.apply_rope(k, positions, fraction=cfg.rope_fraction,
                             theta=cfg.rope_theta)
        # Cache write as iota-select instead of dynamic-update-slice: DUS
        # with a dynamic start on the sequence dim forces GSPMD to gather
        # the (sequence-sharded) cache every step (observed: tens of GB of
        # all-gathers per decode step). The select partitions cleanly —
        # each shard compares its local position range.
        write = jnp.arange(C)[None, None, :, None] == slot
        k_l = jnp.where(write, k.astype(k_l.dtype), k_l)
        v_l = jnp.where(write, v.astype(v_l.dtype), v_l)
        o = L.decode_attention(q, k_l, v_l,
                               jnp.minimum(pos, C - 1)
                               if cfg.sliding_window else pos, mesh)
        return jnp.dot(_unheads(o), p["wo"].astype(cd)), k_l, v_l

    def layer(carry, xs):
        h_in = carry
        p = xs["p"]
        outs = {}
        hn = L.rms_norm(h_in, p["attn_norm"].astype(h_in.dtype),
                        cfg.norm_eps)
        if cfg.family == "ssm":
            y, conv, state = ssm_sublayer(
                cfg, p, hn, xs["conv_state"], xs["ssm_state"], decode=True)
            h = h_in + y
            outs.update(conv_state=conv, ssm_state=state)
        elif cfg.hybrid_parallel:
            a, k_l, v_l = attn_decode(p, hn, xs["k"], xs["v"])
            s, conv, state = ssm_sublayer(
                cfg, p, hn, xs["conv_state"], xs["ssm_state"], decode=True)
            h = h_in + 0.5 * (a + s)
            outs.update(k=k_l, v=v_l, conv_state=conv, ssm_state=state)
            hn2 = L.rms_norm(h, p["mlp_norm"].astype(h.dtype), cfg.norm_eps)
            h = h + ffn_sublayer(cfg, p, hn2, mesh)
        else:
            a, k_l, v_l = attn_decode(p, hn, xs["k"], xs["v"])
            h = h_in + a
            outs.update(k=k_l, v=v_l)
            if cfg.enc_dec:
                pc = xs["pc"]
                hn2 = L.rms_norm(h, pc["norm"].astype(h.dtype),
                                 cfg.norm_eps)
                q = _heads(jnp.dot(hn2, pc["wq"].astype(h.dtype)),
                           cfg.n_heads, hd)
                o = L.decode_attention(q, xs["cross_k"], xs["cross_v"],
                                       cfg.enc_frames, mesh)
                h = h + jnp.dot(_unheads(o), pc["wo"].astype(h.dtype))
                outs.update(cross_k=xs["cross_k"],
                            cross_v=xs["cross_v"])
            hn2 = L.rms_norm(h, p["mlp_norm"].astype(h.dtype), cfg.norm_eps)
            h = h + ffn_sublayer(cfg, p, hn2, mesh)
        return h, outs

    xs: dict = {"p": params["blocks"]}
    for key in ("k", "v", "ssm_state", "conv_state", "cross_k", "cross_v"):
        if key in cache:
            xs[key] = cache[key]
    if cfg.enc_dec:
        xs["pc"] = params["cross"]
    x, outs = _scan_or_loop(layer, x, xs, cfg.n_layers, scan_layers)
    new_cache = dict(cache)
    new_cache["pos"] = pos + 1
    for key, val in outs.items():
        if val is not None:
            new_cache[key] = val
    x = L.rms_norm(x, params["final_norm"].astype(compute_dtype),
                   cfg.norm_eps)
    from repro.models.model import lm_logits
    return lm_logits(cfg, params, x[:, 0], compute_dtype, mesh), new_cache


# -- prefill -----------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, tokens, *, mesh=None,
            compute_dtype=jnp.bfloat16, frames=None, remat: bool = True,
            max_len: int | None = None, scan_layers: bool = True):
    """Full-sequence forward building the cache; returns (last-token
    logits (B, V), cache). ``max_len`` reserves cache slots beyond S for
    subsequent decode steps."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len or S, compute_dtype)
    x = params["embed"].astype(compute_dtype)[tokens]
    if mesh is not None:
        from repro.parallel.sharding import constrain, dp_axes_of
        x = constrain(mesh, x, (dp_axes_of(mesh), None, None))
    if cfg.enc_dec:
        # encoder + cross K/V
        enc = frames.astype(compute_dtype) + \
            params["enc_pos"].astype(compute_dtype)[None]
        enc_block = make_block_fn(
            dataclasses.replace(cfg, family="dense", enc_dec=False,
                                n_kv_heads=cfg.n_heads), causal=False)

        def enc_scan(c, p):
            y, _ = enc_block(c, p)
            return y, None
        enc, _ = _scan_or_loop(enc_scan, enc, params["enc_blocks"],
                               cfg.enc_layers, scan_layers)
        enc = L.rms_norm(enc, params["enc_norm"].astype(compute_dtype),
                         cfg.norm_eps)
        x = x + params["dec_pos"].astype(compute_dtype)[None, :S]

    hd = cfg.head_dim_
    W = cache_len(cfg, max_len or S)

    def layer(carry, xs):
        h_in = carry
        p = xs["p"]
        positions = jnp.arange(S)
        outs = {}
        hn = L.rms_norm(h_in, p["attn_norm"].astype(h_in.dtype),
                        cfg.norm_eps)
        if cfg.family == "ssm":
            y, _, _ = ssm_sublayer(cfg, p, hn)
            # rebuild final state by running the chunked scan is wasteful;
            # prefill for SSM recomputes state via one extra decode-style
            # pass over the last token only is incorrect — so we recompute
            # the exact final state from the full recurrence below.
            h = h_in + y
            outs["ssm_state"], outs["conv_state"] = _ssm_final_state(
                cfg, p, hn)
        elif cfg.hybrid_parallel:
            a, (k, v) = attention_sublayer(cfg, p, hn, causal=True,
                                           positions=positions)
            s, _, _ = ssm_sublayer(cfg, p, hn)
            h = h_in + 0.5 * (a + s)
            outs["ssm_state"], outs["conv_state"] = _ssm_final_state(
                cfg, p, hn)
            outs["k"], outs["v"] = _ring(k, W), _ring(v, W)
            hn2 = L.rms_norm(h, p["mlp_norm"].astype(h.dtype), cfg.norm_eps)
            h = h + ffn_sublayer(cfg, p, hn2, mesh)
        else:
            a, (k, v) = attention_sublayer(cfg, p, hn, causal=True,
                                           positions=positions)
            h = h_in + a
            outs["k"], outs["v"] = _ring(k, W), _ring(v, W)
            if cfg.enc_dec:
                pc = xs["pc"]
                hn2 = L.rms_norm(h, pc["norm"].astype(h.dtype),
                                 cfg.norm_eps)
                q = _heads(jnp.dot(hn2, pc["wq"].astype(h.dtype)),
                           cfg.n_heads, hd)
                ck = _heads(jnp.dot(enc, pc["wk"].astype(h.dtype)),
                            cfg.n_heads, hd)
                cv = _heads(jnp.dot(enc, pc["wv"].astype(h.dtype)),
                            cfg.n_heads, hd)
                o = L.blockwise_attention(q, ck, cv, causal=False)
                h = h + jnp.dot(_unheads(o), pc["wo"].astype(h.dtype))
                outs["cross_k"], outs["cross_v"] = ck, cv
            hn2 = L.rms_norm(h, p["mlp_norm"].astype(h.dtype), cfg.norm_eps)
            h = h + ffn_sublayer(cfg, p, hn2, mesh)
        return h, outs

    xs: dict = {"p": params["blocks"]}
    if cfg.enc_dec:
        xs["pc"] = params["cross"]
    layer_fn = jax.checkpoint(layer) if remat else layer
    x, outs = _scan_or_loop(layer_fn, x, xs, cfg.n_layers, scan_layers)
    for key, val in outs.items():
        cache[key] = val
    cache["pos"] = jnp.asarray(S, jnp.int32)
    x = L.rms_norm(x, params["final_norm"].astype(compute_dtype),
                   cfg.norm_eps)
    from repro.models.model import lm_logits
    return lm_logits(cfg, params, x[:, -1], compute_dtype, mesh), cache


def _ring(k, W):
    """Store the last W positions at ring slots (pos % W)."""
    S = k.shape[2]
    if W == S:
        return k
    if W > S:
        return jnp.pad(k, ((0, 0), (0, 0), (0, W - S), (0, 0)))
    tail = k[:, :, S - W:]                       # positions S-W..S-1
    if S % W == 0:
        # position S-W+j lands on slot (S-W+j) % W = j: the identity
        # slice IS the ring layout. The scatter below permutes a
        # sequence-sharded cache dim and caused 100+ collective-permutes
        # per prefill in the multi-pod dry-run (§Perf it10).
        return tail
    slots = (jnp.arange(S - W, S)) % W
    out = jnp.zeros_like(tail)
    return out.at[:, :, slots].set(tail)


def _ssm_final_state(cfg: ModelConfig, p, hn):
    """Exact final (ssm_state, conv_state) after a full-sequence prefill."""
    cd = hn.dtype
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, \
        cfg.ssm_head_dim
    zxbcdt = jnp.dot(hn, p["ssm_in"].astype(cd))
    _, xin0, Bc0, Cc0, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    xbc = jnp.concatenate([xin0, Bc0, Cc0], axis=-1)
    conv_state = xbc[:, -(cfg.conv_width - 1):].astype(jnp.float32)
    xbc_c, _ = ssm_lib.causal_conv(xbc, p["conv_w"].astype(cd))
    xbc_c = jax.nn.silu(xbc_c)
    xin, Bc, Cc = jnp.split(xbc_c, [di, di + N], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    b, s = hn.shape[:2]
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = dtv * a                                  # (b,s,H)
    # final state = Σ_t exp(Σ_{u>t} da_u) dt_t B_t ⊗ x_t
    rev_cum = jnp.cumsum(da[:, ::-1], axis=1)[:, ::-1] - da
    w = jnp.exp(rev_cum) * dtv                    # (b,s,H)
    xh = xin.reshape(b, s, H, P).astype(jnp.float32)
    state = jnp.einsum("bsn,bshp,bsh->bhnp", Bc.astype(jnp.float32),
                       xh, w)
    return state, conv_state
