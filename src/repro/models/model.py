"""Unified LM assembly for the assigned architecture pool.

One parameter layout + three entry points per architecture:

  * ``forward``       — full-sequence logits (training / evaluation)
  * ``prefill``       — full-sequence forward that also builds the decode
                        cache and returns last-token logits
  * ``decode_step``   — one new token against the cache (serving)

Layers are stacked along a leading L axis and executed with
``jax.lax.scan`` (+ optional remat), so compile time and HLO size are
O(1) in depth — required for the 126-layer dry-run cells.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig


def _split(key, n):
    return list(jax.random.split(key, n))


def _layer_loop(scan_fn, x, stacked, n_layers: int, scan_layers: bool):
    """lax.scan over stacked layer params, or a Python unroll (used by the
    roofline harness so per-layer costs are counted per layer)."""
    if scan_layers:
        x, _ = jax.lax.scan(scan_fn, x, stacked)
        return x
    for i in range(n_layers):
        p_i = jax.tree.map(lambda a: a[i], stacked)
        x, _ = scan_fn(x, p_i)
    return x


# -- initialization ---------------------------------------------------------------

def init_block_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    """Stacked per-layer parameters (leading axis = n_layers)."""
    d, hd = cfg.d_model, cfg.head_dim_
    nl = cfg.n_layers
    ks = iter(_split(key, 40))

    def w(shape, scale=None):
        s = scale if scale is not None else (shape[-2] ** -0.5)
        return (jax.random.normal(next(ks), (nl,) + shape, jnp.float32)
                * s).astype(dtype)

    p: dict = {"attn_norm": jnp.ones((nl, d), dtype),
               "mlp_norm": jnp.ones((nl, d), dtype)}
    if cfg.family != "ssm":
        p["wq"] = w((d, cfg.n_heads * hd))
        p["wk"] = w((d, cfg.n_kv_heads * hd))
        p["wv"] = w((d, cfg.n_kv_heads * hd))
        p["wo"] = w((cfg.n_heads * hd, d))
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((nl, hd), dtype)
            p["k_norm"] = jnp.ones((nl, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        conv_dim = di + 2 * N
        p["ssm_in"] = w((d, 2 * di + 2 * N + H))
        p["conv_w"] = (jax.random.normal(next(ks),
                                         (nl, cfg.conv_width, conv_dim),
                                         jnp.float32) * 0.2).astype(dtype)
        p["dt_bias"] = jnp.zeros((nl, H), dtype)
        p["A_log"] = jnp.zeros((nl, H), dtype)
        p["ssm_norm"] = jnp.ones((nl, di), dtype)
        p["ssm_out"] = w((di, d))
    if cfg.n_experts:
        e, f = cfg.n_experts, cfg.d_ff
        p["router"] = w((d, e), scale=0.02)
        p["w1"] = w((e, d, f))
        p["w2"] = w((e, f, d), scale=f ** -0.5)
        if cfg.activation == "swiglu":
            p["w3"] = w((e, d, f))
    elif cfg.d_ff:
        p["w1"] = w((d, cfg.d_ff))
        p["w2"] = w((cfg.d_ff, d), scale=cfg.d_ff ** -0.5)
        if cfg.activation == "swiglu":
            p["w3"] = w((d, cfg.d_ff))
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5 = _split(key, 5)
    d = cfg.d_model
    params = {
        "embed": (jax.random.normal(k1, (cfg.padded_vocab, d), jnp.float32)
                  * 0.02).astype(dtype),
        "blocks": init_block_params(cfg, k2, dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k3, (d, cfg.padded_vocab),
                                               jnp.float32)
                             * d ** -0.5).astype(dtype)
    if cfg.enc_dec:
        enc_cfg = dataclasses.replace(
            cfg, family="dense", n_layers=cfg.enc_layers, enc_dec=False,
            n_kv_heads=cfg.n_heads)
        params["enc_blocks"] = init_block_params(enc_cfg, k4, dtype)
        params["enc_norm"] = jnp.ones((d,), dtype)
        params["enc_pos"] = (jax.random.normal(
            k5, (cfg.enc_frames, d), jnp.float32) * 0.02).astype(dtype)
        nl, hd = cfg.n_layers, cfg.head_dim_
        kc = iter(_split(k5, 8))

        def wx(shape):
            return (jax.random.normal(next(kc), (nl,) + shape, jnp.float32)
                    * shape[-2] ** -0.5).astype(dtype)
        params["cross"] = {
            "norm": jnp.ones((nl, d), dtype),
            "wq": wx((d, cfg.n_heads * hd)),
            "wk": wx((d, cfg.n_heads * hd)),
            "wv": wx((d, cfg.n_heads * hd)),
            "wo": wx((cfg.n_heads * hd, d)),
        }
        params["dec_pos"] = (jax.random.normal(
            k5, (cfg.dec_positions, d), jnp.float32) * 0.02).astype(dtype)
    return params


# -- attention sublayer --------------------------------------------------------------

def use_weight(mesh, w, cd, axes=None):
    """FSDP all-gather-at-use: cast a weight for compute and pin its
    at-use layout (FSDP axis gathered, TP axis kept). Without this the
    SPMD partitioner sometimes resolves the FSDP(data)×batch(data) clash
    by replicating *activations* over data — multi-GB per-layer
    all-reduces — instead of gathering the (much smaller) weight shard."""
    w = w.astype(cd)
    if mesh is None:
        return w
    from repro.parallel.sharding import constrain
    if axes is None:
        axes = (None,) * w.ndim
    return constrain(mesh, w, axes)


def _heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)   # (B,n,S,hd)


def _unheads(x):
    b, n, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, n * hd)


def attention_sublayer(cfg: ModelConfig, p, x, *, causal: bool,
                       positions, mesh=None):
    """Returns (out, (k, v)) — k/v in (B, Hkv, S, hd) post-RoPE layout."""
    from repro.parallel.sharding import (constrain, dp_axes_of,
                                         head_constraint)
    hd = cfg.head_dim_
    cd = x.dtype

    def proj(w, n):
        # All-gather the FSDP weight shard at use, then constrain the flat
        # (B, S, n·hd) projection before the head reshape — GQA kv widths
        # (Hkv < TP degree) otherwise make GSPMD batch-replicate the
        # output (multi-GB per-layer all-reduces in the baseline dry-run).
        y = jnp.dot(x, use_weight(mesh, w, cd, (None, "model")))
        if mesh is not None:
            y = constrain(mesh, y, (dp_axes_of(mesh), None, "model"))
        return _heads(y, n, hd)

    q = head_constraint(mesh, proj(p["wq"], cfg.n_heads))
    k = head_constraint(mesh, proj(p["wk"], cfg.n_kv_heads))
    v = head_constraint(mesh, proj(p["wv"], cfg.n_kv_heads))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"].astype(cd), cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"].astype(cd), cfg.norm_eps)
    if cfg.rope_fraction > 0:
        q = L.apply_rope(q, positions, fraction=cfg.rope_fraction,
                         theta=cfg.rope_theta)
        k = L.apply_rope(k, positions, fraction=cfg.rope_fraction,
                         theta=cfg.rope_theta)
    if cfg.sliding_window and causal:
        o = L.sliding_window_attention(q, k, v, window=cfg.sliding_window)
    else:
        o = L.blockwise_attention(q, k, v, causal=causal)
    out = jnp.dot(_unheads(o), use_weight(mesh, p["wo"], cd,
                                          ("model", None)))
    return out, (k, v)


def ssm_sublayer(cfg: ModelConfig, p, x, conv_state=None, ssm_state=None,
                 *, decode: bool = False, mesh=None):
    """Mamba2 mixer. x: (B, S, D) (S=1 when decoding)."""
    cd = x.dtype
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, \
        cfg.ssm_head_dim
    zxbcdt = jnp.dot(x, use_weight(mesh, p["ssm_in"], cd))
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    xbc = jnp.concatenate([xin, Bc, Cc], axis=-1)
    if decode:
        new_conv = jnp.concatenate([conv_state[:, 1:],
                                    xbc.astype(jnp.float32)], axis=1)
        xbc_tap = jnp.concatenate([conv_state.astype(cd), xbc], axis=1)
        y = jnp.zeros_like(xbc)
        for i in range(cfg.conv_width):
            y = y + xbc_tap[:, i:i + 1] * p["conv_w"][i].astype(cd)
        xbc = jax.nn.silu(y)
        xin, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)
        dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))
        xh = xin[:, 0].reshape(-1, H, P)
        yh, new_state = ssm_lib.ssd_decode_step(
            ssm_state, xh, dtv, p["A_log"], Bc[:, 0], Cc[:, 0])
        y = yh.reshape(xh.shape[0], 1, di).astype(cd)
        y = L.rms_norm(y * jax.nn.silu(z), p["ssm_norm"].astype(cd),
                       cfg.norm_eps)
        return jnp.dot(y, use_weight(mesh, p["ssm_out"], cd)), \
            new_conv, new_state
    xbc, _ = ssm_lib.causal_conv(xbc, p["conv_w"].astype(cd))
    xbc = jax.nn.silu(xbc)
    xin, Bc, Cc = jnp.split(xbc, [di, di + N], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    b, s = x.shape[:2]
    yh = ssm_lib.ssd_chunked(xin.reshape(b, s, H, P), dtv, p["A_log"],
                             Bc, Cc, chunk=cfg.ssm_chunk)
    y = yh.reshape(b, s, di)
    y = L.rms_norm(y * jax.nn.silu(z), p["ssm_norm"].astype(cd),
                   cfg.norm_eps)
    return jnp.dot(y, use_weight(mesh, p["ssm_out"], cd)), None, None


def ffn_sublayer(cfg: ModelConfig, p, x, mesh=None):
    if cfg.n_experts:
        b, s, d = x.shape
        y, _ = moe_lib.moe_ffn(
            x.reshape(b * s, d),
            {k: p[k] for k in ("router", "w1", "w2", "w3") if k in p},
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            activation=cfg.activation, mesh=mesh)
        return y.reshape(b, s, d)
    cd = x.dtype
    ax = {"w1": (None, "model"), "w3": (None, "model"),
          "w2": ("model", None)}
    return L.mlp(x, {k: use_weight(mesh, p[k], cd, ax[k])
                     for k in ("w1", "w2", "w3") if k in p},
                 cfg.activation)


# -- full-sequence block ------------------------------------------------------------

def make_block_fn(cfg: ModelConfig, *, causal: bool, mesh=None,
                  collect_kv: bool = False):
    def seq_shard(h):
        # Pin the residual stream's layout: without this the embed
        # lookup's D-sharding propagates (batch-replicated!) through the
        # whole stack and every projection contraction-splits (§Perf it7).
        if mesh is None:
            return h
        from repro.parallel.sharding import constrain, dp_axes_of
        if cfg.seq_parallel:
            return constrain(mesh, h, (dp_axes_of(mesh), "model", None))
        return constrain(mesh, h, (dp_axes_of(mesh), None, None))

    def block(x, p):
        x = seq_shard(x)
        positions = jnp.arange(x.shape[1])
        kv = None
        if cfg.family == "ssm":
            h = L.rms_norm(x, p["attn_norm"].astype(x.dtype), cfg.norm_eps)
            y, _, _ = ssm_sublayer(cfg, p, h, mesh=mesh)
            x = x + y
        elif cfg.hybrid_parallel:
            h = L.rms_norm(x, p["attn_norm"].astype(x.dtype), cfg.norm_eps)
            a, kv = attention_sublayer(cfg, p, h, causal=causal,
                                       positions=positions, mesh=mesh)
            s, _, _ = ssm_sublayer(cfg, p, h, mesh=mesh)
            x = x + 0.5 * (a + s)
            h = L.rms_norm(x, p["mlp_norm"].astype(x.dtype), cfg.norm_eps)
            x = x + ffn_sublayer(cfg, p, h, mesh)
        else:
            h = L.rms_norm(x, p["attn_norm"].astype(x.dtype), cfg.norm_eps)
            a, kv = attention_sublayer(cfg, p, h, causal=causal,
                                       positions=positions, mesh=mesh)
            x = x + a
            h = L.rms_norm(x, p["mlp_norm"].astype(x.dtype), cfg.norm_eps)
            x = x + ffn_sublayer(cfg, p, h, mesh)
        return seq_shard(x), (kv if collect_kv else None)
    return block


def forward(cfg: ModelConfig, params, tokens, *, mesh=None,
            compute_dtype=jnp.bfloat16, remat: bool = True,
            frames=None, scan_layers: bool = True):
    """Token ids (B, S) → logits (B, S, V). For enc-dec models ``frames``
    (B, enc_frames, D) are the stubbed modality-frontend embeddings.

    ``scan_layers=False`` unrolls the stack in Python — used by the
    roofline harness, because XLA's cost analysis counts while-loop bodies
    once regardless of trip count."""
    x = params["embed"].astype(compute_dtype)[tokens]
    if mesh is not None:
        from repro.parallel.sharding import constrain, dp_axes_of
        x = constrain(mesh, x, (dp_axes_of(mesh), None, None))
    if cfg.enc_dec:
        return _whisper_forward(cfg, params, tokens, frames,
                                compute_dtype, remat, scan_layers, mesh)
    block = make_block_fn(cfg, causal=True, mesh=mesh)
    if remat:
        block = jax.checkpoint(block)

    def scan_fn(carry, p):
        y, _ = block(carry, p)
        return y, None

    x = _layer_loop(scan_fn, x, params["blocks"], cfg.n_layers,
                    scan_layers)
    x = L.rms_norm(x, params["final_norm"].astype(compute_dtype),
                   cfg.norm_eps)
    return lm_logits(cfg, params, x, compute_dtype, mesh)


def lm_logits(cfg, params, x, compute_dtype, mesh=None):
    """Final projection with an explicit (replicated-D, vocab-TP) weight
    layout: without the constraint GSPMD resolves the tied-embedding
    matmul by batch-replicating the (B, S, V) logits (observed as 12.9 GB
    all-gathers in the baseline dry-run)."""
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    if mesh is not None:
        from repro.parallel.sharding import constrain, dp_axes_of
        head = constrain(mesh, head, (None, "model"))
        logits = jnp.dot(x, head)
        logits = constrain(mesh, logits,
                           (dp_axes_of(mesh),) + (None,) * (x.ndim - 2)
                           + ("model",))
    else:
        logits = jnp.dot(x, head)
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype),
                           logits)
    return logits


# -- whisper (enc-dec) ---------------------------------------------------------------

def _whisper_forward(cfg, params, tokens, frames, compute_dtype, remat,
                     scan_layers=True, mesh=None):
    def pin(h):
        # residual-stream layout pinning (§Perf it7) for both stacks
        if mesh is None:
            return h
        from repro.parallel.sharding import constrain, dp_axes_of
        return constrain(mesh, h, (dp_axes_of(mesh), None, None))

    enc = pin(frames.astype(compute_dtype)
              + params["enc_pos"].astype(compute_dtype)[None])
    enc_block = make_block_fn(
        dataclasses.replace(cfg, family="dense", enc_dec=False,
                            n_kv_heads=cfg.n_heads),
        causal=False, mesh=mesh)
    if remat:
        enc_block = jax.checkpoint(enc_block)

    def enc_scan(carry, p):
        y, _ = enc_block(carry, p)
        return y, None
    enc = _layer_loop(enc_scan, enc, params["enc_blocks"], cfg.enc_layers,
                      scan_layers)
    enc = pin(L.rms_norm(enc, params["enc_norm"].astype(compute_dtype),
                         cfg.norm_eps))

    S = tokens.shape[1]
    x = pin(params["embed"].astype(compute_dtype)[tokens]
            + params["dec_pos"].astype(compute_dtype)[None, :S])
    dec_cfg = dataclasses.replace(cfg, enc_dec=False,
                                  n_kv_heads=cfg.n_heads)
    hd = cfg.head_dim_

    def dec_block(x, ps):
        p, pc = ps
        x = pin(x)
        positions = jnp.arange(x.shape[1])
        h = L.rms_norm(x, p["attn_norm"].astype(x.dtype), cfg.norm_eps)
        a, _ = attention_sublayer(dec_cfg, p, h, causal=True,
                                  positions=positions, mesh=mesh)
        x = x + a
        h = L.rms_norm(x, pc["norm"].astype(x.dtype), cfg.norm_eps)
        cd = x.dtype
        q = _heads(jnp.dot(h, use_weight(mesh, pc["wq"], cd,
                                         (None, "model"))),
                   cfg.n_heads, hd)
        k = _heads(jnp.dot(enc, use_weight(mesh, pc["wk"], cd,
                                           (None, "model"))),
                   cfg.n_heads, hd)
        v = _heads(jnp.dot(enc, use_weight(mesh, pc["wv"], cd,
                                           (None, "model"))),
                   cfg.n_heads, hd)
        o = L.blockwise_attention(q, k, v, causal=False)
        x = x + jnp.dot(_unheads(o), use_weight(mesh, pc["wo"], cd,
                                                ("model", None)))
        h = L.rms_norm(x, p["mlp_norm"].astype(x.dtype), cfg.norm_eps)
        x = x + ffn_sublayer(dec_cfg, p, h, mesh)
        return pin(x), None
    if remat:
        dec_block = jax.checkpoint(dec_block)

    def dec_scan(carry, ps):
        y, _ = dec_block(carry, ps)
        return y, None
    x = _layer_loop(dec_scan, x, (params["blocks"], params["cross"]),
                    cfg.n_layers, scan_layers)
    x = L.rms_norm(x, params["final_norm"].astype(compute_dtype),
                   cfg.norm_eps)
    return lm_logits(cfg, params, x, compute_dtype, mesh)
