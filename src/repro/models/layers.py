"""Core neural layers: RMSNorm, RoPE, GQA attention (full / causal /
sliding-window / decode), and the MLP variants of the assigned archs.

Attention is implemented blockwise (online softmax over KV chunks) so the
compiled HLO never materializes an S×S score matrix — the memory roofline
term stays honest at 32k/500k sequence lengths; the Pallas flash kernel
(kernels/flash_attention.py) is the TPU hot path with identical semantics.

Layouts: activations (B, S, D); attention heads (B, H, S, hd).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * w


def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, fraction: float,
               theta: float) -> jnp.ndarray:
    """x: (..., S, hd); positions: (S,) or broadcastable."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# -- blockwise attention ---------------------------------------------------------
#
# GQA is expressed by broadcasting KV heads up to the full query-head count
# BEFORE the score einsums ("repeat-KV"). This keeps a single head axis H
# that shards cleanly over the TP mesh axis (Hkv < TP-degree would otherwise
# force GSPMD to replicate activations — observed as multi-GB per-layer
# all-reduces in the baseline dry-run; see EXPERIMENTS.md §Perf). XLA fuses
# the broadcast, so no HBM copy materializes.

def repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    B, Hkv, T, hd = k.shape
    k = jnp.broadcast_to(k[:, :, None], (B, Hkv, groups, T, hd))
    return k.reshape(B, Hkv * groups, T, hd)


def blockwise_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                        chunk: int = 1024, window: int = 0) -> jnp.ndarray:
    """Online-softmax attention over KV chunks (flash-style: no S×T score
    matrix in HBM).

    q: (B, H, S, hd); k/v: (B, Hkv, T, hd). ``q_offset``: absolute position
    of q[0]. ``window`` > 0 bounds lookback (sliding-window semantics).
    """
    B, H, S, hd = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    k = repeat_kv(k, H // Hkv)
    v = repeat_kv(v, H // Hkv)
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    pad_t = n_chunks * chunk - T
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    kc = k.reshape(B, H, n_chunks, chunk, hd)
    vc = v.reshape(B, H, n_chunks, chunk, hd)
    q_pos = q_offset + jnp.arange(S)

    def step(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs
        s = jnp.einsum("bhsd,bhtd->bhst", q, kb,
                       preferred_element_type=jnp.float32) * scale
        t_pos = ci * chunk + jnp.arange(chunk)
        mask = t_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((S, chunk), bool)
        if window:
            mask = mask & (t_pos[None, :] > q_pos[:, None] - window)
        mask = mask & (t_pos < T)[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bhtd->bhsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd), jnp.float32)
    kc_t = jnp.moveaxis(kc, 2, 0)
    vc_t = jnp.moveaxis(vc, 2, 0)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc_t, vc_t))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.astype(q.dtype)


def sliding_window_attention(q, k, v, *, window: int) -> jnp.ndarray:
    """Banded causal attention: O(S·2W) via per-block two-chunk lookback.

    Exact for self-attention where q and kv cover the same positions.
    q: (B, H, S, hd); k/v: (B, Hkv, S, hd). Requires S % window == 0 or
    S < window (falls back to windowed blockwise).
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    W = window
    if S <= W or S % W != 0:
        return blockwise_attention(q, k, v, causal=True, chunk=min(S, W),
                                   window=W)
    k = repeat_kv(k, H // Hkv)
    v = repeat_kv(v, H // Hkv)
    nb = S // W
    scale = 1.0 / math.sqrt(hd)
    qb = q.reshape(B, H, nb, W, hd)
    kb = k.reshape(B, H, nb, W, hd)
    vb = v.reshape(B, H, nb, W, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :, :1]),
                              kb[:, :, :-1]], axis=2)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]),
                              vb[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([k_prev, kb], axis=3)       # (B,H,nb,2W,hd)
    v2 = jnp.concatenate([v_prev, vb], axis=3)
    s = jnp.einsum("bhnsd,bhntd->bhnst", qb, k2,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(W)[:, None]
    tpos = jnp.arange(2 * W)[None, :] - W
    mask = (tpos <= qpos) & (tpos > qpos - W)
    first = jnp.arange(nb) == 0
    tvalid = (tpos >= 0) | (~first[:, None, None])
    s = jnp.where(mask[None, None, None] & tvalid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhnst,bhntd->bhnsd", p.astype(v2.dtype), v2,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, S, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, mesh=None) -> jnp.ndarray:
    """Single-token decode: q (B, H, 1, hd) against cache (B, Hkv, S, hd),
    masked to positions ≤ pos.

    Flash-decode partitioning: the cache is sequence-sharded over the
    model axis, scores stay S-sharded (constraint below), and the softmax
    + weighted sum decompose into per-shard partials merged by tiny
    (B, H[, hd]) all-reduces. Query heads are replicated — resharding the
    cache from S- to H-sharded layout would all-gather hundreds of MB per
    layer per step (observed in the baseline)."""
    B, H, _, hd = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    if mesh is not None:
        from repro.parallel.sharding import constrain, dp_axes_of
        dp = dp_axes_of(mesh)
        q = constrain(mesh, q, (dp, None, None, None))
    k_cache = repeat_kv(k_cache, H // Hkv)
    v_cache = repeat_kv(v_cache, H // Hkv)
    qs = q[:, :, 0]
    s = jnp.einsum("bhd,bhtd->bht", qs, k_cache,
                   preferred_element_type=jnp.float32)
    if mesh is not None:
        s = constrain(mesh, s, (dp, None, "model"))
    s = s / math.sqrt(hd)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bht,bhtd->bhd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    if mesh is not None:
        out = constrain(mesh, out, (dp, None, None))
    return out[:, :, None].astype(q.dtype)


# -- MLPs -------------------------------------------------------------------------

def mlp(x, params, activation: str):
    if activation == "swiglu":
        g = jnp.dot(x, params["w1"])
        u = jnp.dot(x, params["w3"])
        h = jax.nn.silu(g) * u
    elif activation == "squared_relu":
        h = jax.nn.relu(jnp.dot(x, params["w1"]))
        h = h * h
    elif activation == "gelu":
        h = jax.nn.gelu(jnp.dot(x, params["w1"]))
    else:
        raise ValueError(activation)
    return jnp.dot(h, params["w2"])
