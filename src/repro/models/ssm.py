"""Mamba2 state-space duality (SSD) layer — chunked scan formulation
(arXiv:2405.21060), plus the constant-state single-token decode step.

The chunked algorithm splits the sequence into chunks of Q tokens:
intra-chunk terms form a small attention-like quadratic within each chunk
(MXU-friendly — the Pallas `ssd_scan` kernel tiles exactly this), and
inter-chunk terms propagate a (heads, head_dim, state) running state with
a `lax.scan` over chunks. Work is O(S·Q + S·N·P) instead of O(S²).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked(x, dt, A_log, B, C, *, chunk: int) -> jnp.ndarray:
    """SSD forward.

    x:  (batch, S, H, P)    inputs per head
    dt: (batch, S, H)       softplus-activated step sizes (>0)
    A_log: (H,)             log of -A (per-head decay rate)
    B:  (batch, S, N)       input projection (ngroups=1, shared over heads)
    C:  (batch, S, N)       output projection
    returns y: (batch, S, H, P)
    """
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    orig_S = S
    if S % Q:
        # pad with dt=0 tokens: zero step size contributes nothing to
        # states and padded outputs are sliced off below
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    a = -jnp.exp(A_log.astype(jnp.float32))            # (H,) negative

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = B.reshape(Bsz, nc, Q, N)
    Cc = C.reshape(Bsz, nc, Q, N)

    # log-decay within each chunk
    da = dtc * a                                       # (b,c,Q,H)
    cum = jnp.cumsum(da, axis=2)                       # inclusive
    seg_total = cum[:, :, -1, :]                       # (b,c,H)

    # intra-chunk (masked quadratic): y_s += Σ_{t<=s} C_s·B_t · decay · dt_t·x_t
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcsn,bctn->bcst", Cc, Bc,
                    preferred_element_type=jnp.float32)
    w = cb[..., None] * decay * dtc[:, :, None, :, :]  # (b,c,s,t,H)
    y_intra = jnp.einsum("bcsth,bcthp->bcshp", w,
                         xc.astype(jnp.float32))

    # chunk states: S_c = Σ_t exp(cum_last - cum_t) dt_t B_t ⊗ x_t
    sdecay = jnp.exp(seg_total[:, :, None, :] - cum)   # (b,c,Q,H)
    wB = Bc[:, :, :, None, :] * (sdecay * dtc)[..., None]  # (b,c,Q,H,N)
    chunk_state = jnp.einsum("bcqhn,bcqhp->bchnp", wB,
                             xc.astype(jnp.float32))

    # inter-chunk linear recurrence S_k = a_k·S_{k-1} + b_k as an
    # associative scan — parallel over chunks, so sequence-sharded inputs
    # (long-context cells) turn into a parallel prefix with collectives
    # instead of a serial loop.
    a = jnp.exp(seg_total)                             # (b,c,H)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2[:, :, :, None, None] * b1 + b2

    a_inc, s_inc = jax.lax.associative_scan(
        combine, (a, chunk_state), axis=1)
    # prev_states[k] = state entering chunk k (exclusive scan)
    prev_states = jnp.concatenate(
        [jnp.zeros_like(s_inc[:, :1]), s_inc[:, :-1]], axis=1)

    # inter-chunk output: y_s += exp(cum_s) · C_s · S_prev
    out_decay = jnp.exp(cum)                           # (b,c,Q,H)
    y_inter = jnp.einsum("bcqn,bchnp->bcqhp", Cc,
                         prev_states) * out_decay[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)[:, :orig_S]
    return y.astype(x.dtype)


def ssd_decode_step(state, x, dt, A_log, B, C):
    """Single-token recurrence.

    state: (batch, H, N, P); x: (batch, H, P); dt: (batch, H);
    B/C: (batch, N). Returns (y (batch, H, P), new_state).
    """
    a = -jnp.exp(A_log.astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a)                           # (b,H)
    upd = jnp.einsum("bn,bhp->bhnp", B.astype(jnp.float32),
                     x.astype(jnp.float32)) * dtf[:, :, None, None]
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), new_state)
    return y.astype(x.dtype), new_state


def causal_conv(x, w, conv_state=None):
    """Depthwise causal conv1d, width K. x: (B, S, D); w: (K, D).

    With ``conv_state`` (B, K-1, D) performs the streaming update and
    returns (y (B, S, D), new_state)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(x[:, :K - 1])
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(K):
        y = y + xp[:, i:i + x.shape[1]] * w[i]
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return y, new_state
