from repro.models.config import SHAPES, ModelConfig, ShapeConfig

__all__ = ["ModelConfig", "SHAPES", "ShapeConfig"]
