"""Model configuration for the assigned architecture pool.

One dataclass covers all ten families (dense / MoE / SSM / hybrid / VLM /
audio enc-dec); family-specific fields are ignored where inapplicable.
All dtypes are explicit (bf16 params / f32 master) — SQL-side x64 does not
leak in here.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 → d_model // n_heads
    activation: str = "swiglu"      # swiglu | squared_relu | gelu
    rope_fraction: float = 1.0      # chatglm-style 2d rope uses 0.5
    rope_theta: float = 10000.0
    qk_norm: bool = False           # chameleon-style query/key norm
    max_seq_len: int = 1 << 20
    # attention window; 0 → full attention (quadratic)
    sliding_window: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # hybrid: parallel attention + SSM heads in every block (Hymba)
    hybrid_parallel: bool = False
    # encoder-decoder (Whisper): encoder frames are stubbed embeddings
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500
    # learned decoder position table length (whisper-large-v3 is 448 in the
    # real model; sized to the assigned decode/prefill shapes here)
    dec_positions: int = 32768
    # norm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # -- beyond-paper performance knobs (EXPERIMENTS.md §Perf) --
    # pad embedding/vocab rows to a multiple (0/1 = off): keeps the vocab
    # dim divisible by the TP degree so logits shard without padding
    # pathologies (Megatron-style vocab padding)
    vocab_pad: int = 1
    # Megatron sequence parallelism: shard the residual stream's sequence
    # dim over the model axis between blocks (reduce-scatter/all-gather
    # instead of all-reduce; remat stash divided by the TP degree)
    seq_parallel: bool = False

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad and self.vocab_pad > 1:
            return -(-self.vocab // self.vocab_pad) * self.vocab_pad
        return self.vocab

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family == "ssm" or self.sliding_window > 0

    def param_count(self) -> int:
        """Approximate parameter count N for MODEL_FLOPS = 6·N·D."""
        d, hd = self.d_model, self.head_dim_
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family != "ssm":
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            per_layer += q + kv + o
        if self.family in ("ssm", "hybrid"):
            di, ns = self.d_inner, self.ssm_state
            # in_proj (x, z, B, C, dt), out_proj
            per_layer += d * (2 * di + 2 * ns + self.n_ssm_heads) + di * d
        if self.n_experts:
            per_layer += self.n_experts * 3 * d * self.d_ff \
                + d * self.n_experts
        elif self.d_ff:
            mult = 3 if self.activation == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        total = emb + self.n_layers * per_layer
        if self.enc_dec:
            enc_layer = (4 * d * d + (3 if self.activation == "swiglu"
                                      else 2) * d * self.d_ff)
            # decoder cross-attention
            total += self.enc_layers * enc_layer + \
                self.n_layers * 4 * d * d
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE uses top_k experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense_like = self.param_count() - self.n_layers * (
            self.n_experts * 3 * d * self.d_ff)
        return dense_like + self.n_layers * self.top_k * 3 * d * self.d_ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                       # train_4k | prefill_32k | ...
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
