"""Training / serving step builders shared by the drivers and the dry-run."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode as decode_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW


def softmax_xent(logits, labels):
    """Cross entropy in f32 over a (possibly vocab-sharded) logits tensor.

    The gold logit is extracted with an iota-compare masked sum instead of
    take_along_axis: under a vocab-sharded layout the gather would make
    GSPMD materialize/permute full-vocab tensors, while compare+sum
    partitions cleanly (only a tiny (B, S) all-reduce crosses shards)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    eq = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                  logits.ndim - 1) == labels[..., None]
    gold = jnp.sum(jnp.where(eq, logits, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def make_loss_fn(cfg: ModelConfig, *, mesh=None, remat=True,
                 compute_dtype=jnp.bfloat16, scan_layers=True):
    def loss_fn(params, batch):
        logits = model_lib.forward(
            cfg, params, batch["tokens"], mesh=mesh, remat=remat,
            compute_dtype=compute_dtype, frames=batch.get("frames"),
            scan_layers=scan_layers)
        if mesh is not None:
            from repro.parallel.sharding import constrain, dp_axes_of
            logits = constrain(mesh, logits,
                               (dp_axes_of(mesh), None, "model"))
        return softmax_xent(logits, batch["labels"])
    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: AdamW, *, mesh=None,
                    remat=True, compute_dtype=jnp.bfloat16,
                    scan_layers=True, accum_steps: int = 1):
    """``accum_steps`` > 1 splits the global batch into microbatches and
    accumulates gradients under a lax.scan (gradient accumulation): the
    activation working set shrinks by the accumulation factor at the cost
    of one extra f32 gradient buffer."""
    loss_fn = make_loss_fn(cfg, mesh=mesh, remat=remat,
                           compute_dtype=compute_dtype,
                           scan_layers=scan_layers)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps,
                                     x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                loss_sum, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_sum + l, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss_sum / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        params, opt_state, gnorm = optimizer.update(grads, opt_state,
                                                    params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(cfg: ModelConfig, *, mesh=None,
                      compute_dtype=jnp.bfloat16, scan_layers=True):
    def prefill_step(params, batch):
        return decode_lib.prefill(cfg, params, batch["tokens"], mesh=mesh,
                                  compute_dtype=compute_dtype,
                                  frames=batch.get("frames"),
                                  scan_layers=scan_layers)
    return prefill_step


def make_serve_step(cfg: ModelConfig, *, mesh=None,
                    compute_dtype=jnp.bfloat16, scan_layers=True):
    def serve_step(params, cache, tokens):
        logits, cache = decode_lib.decode_step(
            cfg, params, cache, tokens, mesh=mesh,
            compute_dtype=compute_dtype, scan_layers=scan_layers)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, cache
    return serve_step
