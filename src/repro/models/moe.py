"""Mixture-of-Experts FFN with sort-based token dispatch (expert-parallel).

Top-k routing with bounded expert capacity: tokens are ranked within their
chosen expert (stable sort over expert ids), tokens past capacity are
dropped (GShard-style), features are scattered into a dense
(experts, capacity, d_model) buffer, experts run as batched einsums with
the expert axis sharded over the ``model`` mesh axis (GSPMD inserts the
token all-to-alls), and outputs are combined back weighted by router
probabilities.

FLOPs scale with tokens·top_k (active experts), not n_experts — keeping
the compute roofline term equal to the 6·N_active·D model estimate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def moe_capacity(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(n_tokens * top_k * capacity_factor / n_experts)
    return max(8, -(-cap // 8) * 8)


def moe_ffn(x, params, *, top_k: int, capacity_factor: float,
            activation: str = "swiglu", mesh=None):
    """x: (T, D) token-major. params: router (D, E), w1/w3 (E, D, F),
    w2 (E, F, D). Returns ((T, D), router probs).

    Dispatch: capacity-bounded sort-based ranking, scatter into a dense
    (E, C, D) buffer whose expert axis is sharded over the model axis
    (expert parallelism); GSPMD inserts the token exchange. §Perf it8
    (EXPERIMENTS.md) documents why a *hierarchical* per-data-shard
    dispatch regressed 15× under pjit — the partitioner cannot prove
    scatter locality without shard_map — and the planned shard_map
    all-to-all formulation with its expected ~5× collective win.
    """
    T, D = x.shape
    E = params["router"].shape[1]
    C = moe_capacity(T, E, top_k, capacity_factor)

    logits = jnp.dot(x, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)         # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)                          # (T*k,)
    Tk = flat_e.shape[0]
    # rank of each (token, slot) within its expert via stable sort
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    onehot_starts = jnp.zeros((E,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.cumsum(onehot_starts) - onehot_starts  # exclusive prefix
    rank_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((Tk,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < C
    slot = jnp.where(keep, rank, 0)

    tok_idx = jnp.repeat(jnp.arange(T), top_k)
    feats = x[tok_idx] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[flat_e, slot].add(feats, mode="drop")
    if mesh is not None:
        buf = jax.lax.with_sharding_constraint(
            buf, jax.sharding.NamedSharding(mesh, P("model", None, None)))

    w1 = params["w1"].astype(x.dtype)
    w2 = params["w2"].astype(x.dtype)
    if activation == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, w1)
        u = jnp.einsum("ecd,edf->ecf", buf,
                       params["w3"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.relu(jnp.einsum("ecd,edf->ecf", buf, w1)) ** 2
    out_buf = jnp.einsum("ecf,efd->ecd", h, w2)
    if mesh is not None:
        out_buf = jax.lax.with_sharding_constraint(
            out_buf,
            jax.sharding.NamedSharding(mesh, P("model", None, None)))

    gathered = out_buf[flat_e, slot]                    # (T*k, D)
    weights = (top_p.reshape(-1) * keep).astype(x.dtype)
    y = (gathered * weights[:, None]).reshape(T, top_k, D).sum(axis=1)
    return y, probs


def load_balance_loss(probs: jnp.ndarray, top_i: jnp.ndarray | None = None
                      ) -> jnp.ndarray:
    """Switch-style auxiliary loss: E · Σ_e f_e · p̄_e."""
    E = probs.shape[-1]
    pbar = probs.mean(axis=0)
    if top_i is None:
        f = pbar
    else:
        f = jnp.zeros((E,)).at[top_i.reshape(-1)].add(
            1.0 / top_i.size)
    return E * jnp.sum(f * pbar)
