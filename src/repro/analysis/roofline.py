"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in seconds-per-step on TPU v5e:

    compute    = per-device HLO FLOPs / 197e12
    memory     = per-device HLO bytes accessed / 819e9
    collective = per-device collective operand bytes / 50e9

``cost_analysis()`` supplies FLOPs/bytes of the *partitioned per-device*
program. Collective bytes are not in cost_analysis — we parse the compiled
HLO text and sum the output-tensor sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (output size ≈ bytes
an operand moves through a device's links; multi-link utilization and
bidirectional rings make this a conservative upper bound).
"""

from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,2048,128]{2,1,0:T(8,128)(2,1)}
_TYPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\)|\S+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        out_type, kind = m.group(1), m.group(2)
        # avoid double counting async -start/-done pairs
        if f"{kind}-done" in line:
            continue
        bytes_by[kind] += _tensor_bytes(out_type)
        count_by[kind] += 1
    del seen_done
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    peak_memory_bytes: int
    collectives: CollectiveStats

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device":
                self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "peak_memory_bytes": self.peak_memory_bytes,
            "collective_counts": self.collectives.count_by_kind,
            "collective_bytes": self.collectives.bytes_by_kind,
        }


def analyze(compiled, hlo_text: str | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)

    compute_s = flops / TPU_V5E["peak_bf16_flops"]
    memory_s = nbytes / TPU_V5E["hbm_bandwidth"]
    collective_s = coll.total_bytes / TPU_V5E["ici_link_bandwidth"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    ma = compiled.memory_analysis()
    peak = int(getattr(ma, "argument_size_in_bytes", 0)
               + getattr(ma, "output_size_in_bytes", 0)
               + getattr(ma, "temp_size_in_bytes", 0)
               - getattr(ma, "alias_size_in_bytes", 0))
    return Roofline(flops, nbytes, float(coll.total_bytes), compute_s,
                    memory_s, collective_s, dominant, peak, coll)


def model_flops(cfg, shape, *, train: bool) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) global step FLOPs; 2·N·D for
    forward-only kinds."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if train else 2
    return mult * n * tokens
