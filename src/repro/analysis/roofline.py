"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in seconds-per-step on TPU v5e:

    compute    = per-device HLO FLOPs / 197e12
    memory     = per-device HLO bytes accessed / 819e9
    collective = per-device collective operand bytes / 50e9

``cost_analysis()`` supplies FLOPs/bytes of the *partitioned per-device*
program. Collective bytes are not in cost_analysis — we parse the compiled
HLO text and sum the output-tensor sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (output size ≈ bytes
an operand moves through a device's links; multi-link utilization and
bidirectional rings make this a conservative upper bound).
"""

from __future__ import annotations

import dataclasses
import math
import re

from repro.launch.mesh import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,2048,128]{2,1,0:T(8,128)(2,1)}
_TYPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^=]*?\)|\S+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        out_type, kind = m.group(1), m.group(2)
        # avoid double counting async -start/-done pairs
        if f"{kind}-done" in line:
            continue
        bytes_by[kind] += _tensor_bytes(out_type)
        count_by[kind] += 1
    del seen_done
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    peak_memory_bytes: int
    collectives: CollectiveStats

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device":
                self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "peak_memory_bytes": self.peak_memory_bytes,
            "collective_counts": self.collectives.count_by_kind,
            "collective_bytes": self.collectives.bytes_by_kind,
        }


def analyze(compiled, hlo_text: str | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)

    compute_s = flops / TPU_V5E["peak_bf16_flops"]
    memory_s = nbytes / TPU_V5E["hbm_bandwidth"]
    collective_s = coll.total_bytes / TPU_V5E["ici_link_bandwidth"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    ma = compiled.memory_analysis()
    peak = int(getattr(ma, "argument_size_in_bytes", 0)
               + getattr(ma, "output_size_in_bytes", 0)
               + getattr(ma, "temp_size_in_bytes", 0)
               - getattr(ma, "alias_size_in_bytes", 0))
    return Roofline(flops, nbytes, float(coll.total_bytes), compute_s,
                    memory_s, collective_s, dominant, peak, coll)


def model_flops(cfg, shape, *, train: bool) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) global step FLOPs; 2·N·D for
    forward-only kinds."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if train else 2
    return mult * n * tokens


# -- kernel tiling autotune ------------------------------------------------------
#
# The analytic half of the roofline: pick Pallas block sizes and resident
# capacities for the fused query kernels (repro.exec.lower) from the same
# machine constants the post-hoc analyzer divides by, instead of
# hand-picked constants. The model is deliberately static — a pure
# function of the op shape (column counts, group domain, aggregate
# count) — so the chosen tiling can join the compiled-program cache key
# and be unit-tested without tracing anything.

# Per-element VMEM cost of a kernel operand lane. Interpret mode runs
# f64/i64, but the tiling models the TPU execution (f32/i32 lanes) —
# the cast happens at kernel entry either way.
_ELEM_BYTES = 4

# Fraction of VMEM a kernel's working set may claim; the rest is head
# room for Mosaic's double buffering of grid inputs and spills.
_VMEM_FRACTION = 0.25

_MIN_BLOCK = 128        # ≥ the f32 min tile's lane count (8, 128)
_MAX_BLOCK = 8192


def machine_balance() -> float:
    """Machine balance point in FLOPs/byte: arithmetic intensities above
    it are compute-bound on the MXU, below it HBM-bandwidth-bound."""
    return TPU_V5E["peak_bf16_flops"] / TPU_V5E["hbm_bandwidth"]


def vmem_budget_bytes() -> int:
    return int(TPU_V5E["vmem_bytes"] * _VMEM_FRACTION)


def _pow2_floor(n: int) -> int:
    return 1 << max(int(n).bit_length() - 1, 0)


@dataclasses.dataclass(frozen=True)
class KernelTiling:
    """Roofline-chosen tiling for one fused kernel instance.

    ``block_rows`` is the per-grid-step row block; ``resident_rows`` the
    largest input capacity a fully-VMEM-resident kernel (sort / top-k /
    join build side) accepts before the dispatch falls back to the XLA
    path. ``arithmetic_intensity`` and ``dominant`` record which side of
    the machine balance the kernel lands on at the chosen block.
    """
    kernel: str
    block_rows: int
    resident_rows: int
    vmem_bytes: int              # estimated working set at block_rows
    flops_per_row: float
    bytes_per_row: float
    arithmetic_intensity: float
    dominant: str                # "compute" | "memory"

    @property
    def key(self) -> tuple:
        return (self.kernel, self.block_rows, self.resident_rows)

    def as_dict(self) -> dict:
        return {
            "kernel": self.kernel,
            "block_rows": self.block_rows,
            "resident_rows": self.resident_rows,
            "vmem_bytes": self.vmem_bytes,
            "flops_per_row": round(self.flops_per_row, 3),
            "bytes_per_row": round(self.bytes_per_row, 3),
            "arithmetic_intensity": round(self.arithmetic_intensity, 4),
            "dominant": self.dominant,
        }


def _finish(kernel: str, block: int, resident: int, ws_bytes: float,
            flops_per_row: float, bytes_per_row: float) -> KernelTiling:
    ai = flops_per_row / max(bytes_per_row, 1e-9)
    dominant = "compute" if ai >= machine_balance() else "memory"
    return KernelTiling(kernel, block, resident, int(ws_bytes),
                        flops_per_row, bytes_per_row, ai, dominant)


def _grid_block(ws_at, flops_per_row: float, bytes_per_row: float) -> int:
    """Largest power-of-two block whose working set fits the budget; a
    compute-bound kernel (AI past the machine balance) halves once —
    the MXU is the bottleneck anyway and the smaller tile deepens the
    grid pipeline instead of hogging VMEM."""
    budget = vmem_budget_bytes()
    block = _MIN_BLOCK
    while block * 2 <= _MAX_BLOCK and ws_at(block * 2) <= budget:
        block *= 2
    ai = flops_per_row / max(bytes_per_row, 1e-9)
    if ai >= machine_balance() and block > _MIN_BLOCK:
        block //= 2
    return block


def filter_agg_tiling(*, n_cols: int, n_aggs: int) -> KernelTiling:
    """scan→filter→agg: streaming VPU kernel, one (1, A) accumulator."""
    def ws(b):
        return (n_cols + 1) * b * _ELEM_BYTES + n_aggs * _ELEM_BYTES
    flops = 4.0 * n_aggs + 2.0 * n_cols          # pred eval + accumulate
    bpr = float((n_cols + 1) * _ELEM_BYTES)
    block = _grid_block(ws, flops, bpr)
    return _finish("filter_agg", block, _MAX_BLOCK * 16, ws(block),
                   flops, bpr)


def groupby_tiling(kernel: str, *, n_cols: int, n_aggs: int,
                   n_groups: int) -> KernelTiling:
    """One-hot grouped aggregation (sum/count matmul on the MXU, plus
    masked broadcast min/max reductions for ``segmented_minmax``): the
    (block, K) one-hot tile dominates the working set."""
    K, A = max(n_groups, 1), n_aggs

    def ws(b):
        return ((n_cols + 1) * b * _ELEM_BYTES      # input columns + mask
                + b * K * _ELEM_BYTES               # one-hot matrix
                + K * (A + 1) * _ELEM_BYTES)        # accumulator tile
    flops = 2.0 * K * (A + 1)                       # one-hot matmul row
    bpr = float((n_cols + 1) * _ELEM_BYTES)
    block = _grid_block(ws, flops, bpr)
    return _finish(kernel, block, _MAX_BLOCK * 16, ws(block), flops, bpr)


def join_probe_tiling(*, n_cols: int, n_payload: int, n_aggs: int,
                      n_groups: int) -> KernelTiling:
    """Fused join probe + aggregation: the sorted build side stays
    resident across every grid step, so the budget splits between the
    build arrays and the per-step probe block."""
    budget = vmem_budget_bytes()
    build_lane = (n_payload + 1) * _ELEM_BYTES      # sorted keys + payload
    resident = _pow2_floor(max(budget // 2 // build_lane, _MIN_BLOCK))
    K, A = max(n_groups, 1), n_aggs

    def ws(b):
        return (resident * build_lane
                + (n_cols + 1) * b * _ELEM_BYTES
                + b * K * _ELEM_BYTES
                + K * (A + 1) * _ELEM_BYTES)
    # log2(B) binary-search compares + gathers + the agg update
    flops = 2.0 * math.log2(max(resident, 2)) + 2.0 * K * (A + 1)
    bpr = float((n_cols + 1) * _ELEM_BYTES)
    block = _grid_block(ws, flops, bpr)
    return _finish("join_probe_agg", block, resident, ws(block), flops,
                   bpr)


def resident_sort_tiling(kernel: str, *, n_arrays: int) -> KernelTiling:
    """Fully-resident sorting kernels (bitonic sort-aggregation, top-k):
    every operand array plus one scratch copy lives in VMEM for the whole
    sort network, so capacity — not block — is what the budget caps."""
    budget = vmem_budget_bytes()
    lane = 2 * max(n_arrays, 1) * _ELEM_BYTES       # arrays + shifted copy
    resident = _pow2_floor(max(budget // lane, _MIN_BLOCK))
    stages = math.log2(max(resident, 2))
    flops = n_arrays * stages * (stages + 1) / 2    # compare-exchange net
    bpr = float(n_arrays * _ELEM_BYTES)
    return _finish(kernel, resident, resident, resident * lane, flops,
                   bpr)


def bloom_probe_tiling(*, n_cols: int, n_bits: int) -> KernelTiling:
    """In-kernel semi-join Bloom probe: the filter words (n_bits/8 bytes,
    capped well under the VMEM budget by ``kernels.bloom.BLOOM_MAX_BITS``)
    stay resident across the whole row grid; each row pays two fmix32
    mixes plus k position/gather/bit-test steps — firmly memory-bound,
    like the filter_agg scan it fuses with."""
    words_bytes = max(n_bits, 32) // 8

    def ws(b):
        return words_bytes + (n_cols + 1) * b * _ELEM_BYTES
    # 2 finalizer mixes (5 ops each) + k * (mul-add, mask, shift, gather,
    # shift, and) with k = 6
    flops = 2 * 5.0 + 6 * 6.0
    bpr = float((n_cols + 1) * _ELEM_BYTES)
    block = _grid_block(ws, flops, bpr)
    return _finish("bloom_filter", block, _MAX_BLOCK * 16, ws(block),
                   flops, bpr)


def interpret_prefers_jnp(tiling: KernelTiling) -> bool:
    """Whether an interpreted (CPU) backend should skip this kernel for
    the identical-semantics jnp path.

    The fully-resident bitonic kernels (``block_rows == resident_rows``:
    sort_agg, topk) pay a log²-stage compare-exchange network per element
    — worth it on TPU, where VMEM residency removes the HBM round trips
    the network would otherwise issue, but pure overhead when the kernel
    body is interpreted on a host whose XLA sort is O(log n) per element.
    The tiling's flops-per-row already encodes the network depth, so the
    test is that compute per row dwarfs the byte traffic (a host has no
    MXU: its balance point is ~1 flop/byte, not the TPU's)."""
    return (tiling.resident_rows == tiling.block_rows
            and tiling.flops_per_row > tiling.bytes_per_row)


def onehot_group_capacity(n_aggs: int = 4) -> int:
    """Largest group domain K the one-hot kernels accept: at the minimum
    block the (block, K) one-hot plus the (K, A+1) accumulator must fit
    the VMEM budget. Replaces the hand-picked MAX_KERNEL_GROUPS."""
    budget = vmem_budget_bytes()
    lane = (_MIN_BLOCK + n_aggs + 1) * _ELEM_BYTES
    return _pow2_floor(max(budget // lane, 1))
