"""Analytic HBM-traffic and residency model per (arch × shape × mesh) cell.

Why analytic: the dry-run compiles against the CPU backend, whose scheduler
neither fuses like TPU XLA nor runs memory-pressure passes (no 16 GiB
limit), so neither `cost_analysis()['bytes accessed']` (unfused: ~40×
inflated) nor `memory_analysis().temp_size` (no rematerialization
scheduling) transfers to TPU. FLOPs and the GSPMD collective schedule *do*
transfer — those stay artifact-derived. The memory roofline term instead
uses this model, parameterized only by the cell config and mesh, assuming
TPU-standard fusion (flash attention keeps S×S tiles in VMEM; elementwise
chains fuse into one HBM pass).

All quantities are per device, per step.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


@dataclasses.dataclass
class MemoryModel:
    traffic_bytes: float          # HBM bytes moved per step
    residency_bytes: float        # steady-state HBM footprint
    detail: dict

    @property
    def fits_hbm(self) -> bool:
        return self.residency_bytes < 16 * 2**30


def _layer_param_count(cfg: ModelConfig) -> int:
    per = (cfg.param_count() - cfg.vocab * cfg.d_model
           * (1 if cfg.tie_embeddings else 2))
    return per // max(cfg.n_layers, 1)


def analyze_memory(cfg: ModelConfig, shape: ShapeConfig, *,
                   n_devices: int, dp: int, tp: int, kind: str,
                   accum_steps: int = 1,
                   opt_bytes_per_param: float = 12.0) -> MemoryModel:
    P = cfg.param_count()
    L = cfg.n_layers
    D = cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    tok_dp = B * S / dp if kind != "decode" else B / dp
    hd = cfg.head_dim_
    detail: dict = {}

    # -- parameter/optimizer traffic ------------------------------------------
    if kind == "train":
        # read f32 params + m/v, write all three (AdamW), plus one bf16
        # cast read of params in fwd and bwd each (all-gathered FSDP
        # shards are streamed, but each device still sources its 1/dev
        # share once).
        param_traffic = P / n_devices * (
            2 * (F32 + opt_bytes_per_param) + 2 * BF16)
        resid_params = P / n_devices * (F32 + opt_bytes_per_param)
    else:
        param_traffic = P / n_devices * BF16
        resid_params = P / n_devices * BF16
    detail["param_traffic"] = param_traffic

    # -- activation traffic -----------------------------------------------------
    # residual-stream tensors (not TP-sharded): ~6 HBM passes per layer fwd;
    # wide tensors (d_ff / head projections, TP-sharded): ~4 passes.
    wide = max(cfg.d_ff if not cfg.n_experts else cfg.top_k * cfg.d_ff,
               cfg.n_heads * hd)
    if cfg.family in ("ssm", "hybrid"):
        wide = max(wide, cfg.d_inner + 2 * cfg.ssm_state)
    passes = 3.0 if kind == "train" else 1.0   # fwd+bwd+remat-recompute
    act_layer = tok_dp * (6 * D + 4 * wide / tp) * BF16
    act_traffic = act_layer * L * passes
    detail["act_traffic"] = act_traffic

    # -- attention KV traffic (flash kernel: scores stay in VMEM) ---------------
    kv_traffic = 0.0
    if cfg.family != "ssm" and kind != "decode":
        eff_S = min(S, cfg.sliding_window) if cfg.sliding_window else S
        q_chunks = max(S // 1024, 1)
        reread = min(q_chunks, max(eff_S // 1024, 1))
        kv_traffic = (B / dp) * cfg.n_kv_heads * eff_S * hd * BF16 \
            * 2 * reread * L * passes
    if kind == "decode" and cfg.family != "ssm":
        C = min(S, cfg.sliding_window) if cfg.sliding_window else S
        kv_traffic = L * (B / dp) * cfg.n_kv_heads * (C / tp) * hd \
            * BF16 * 2                       # read full cache (k+v)
        kv_traffic += L * (B / dp) * cfg.n_kv_heads * hd * BF16 * 2  # write
    if cfg.enc_dec and kind == "decode":
        kv_traffic += L * (B / dp) * cfg.n_heads * (cfg.enc_frames / tp) \
            * hd * BF16 * 2
    detail["kv_traffic"] = kv_traffic

    # -- SSM state traffic --------------------------------------------------------
    ssm_traffic = 0.0
    if cfg.family in ("ssm", "hybrid"):
        state = cfg.n_ssm_heads * cfg.ssm_state * cfg.ssm_head_dim
        if kind == "decode":
            ssm_traffic = L * (B / dp) * (state / tp) * F32 * 2
        else:
            n_chunks = max(S // cfg.ssm_chunk, 1)
            ssm_traffic = L * (B / dp) * state * F32 * 2 * n_chunks \
                * passes
    detail["ssm_traffic"] = ssm_traffic

    # -- logits + loss ------------------------------------------------------------
    logit_traffic = 0.0
    if kind == "train":
        logit_traffic = tok_dp * (cfg.vocab / tp) * BF16 * 3
    elif kind == "prefill":
        logit_traffic = (B / dp) * (cfg.vocab / tp) * BF16
    else:
        logit_traffic = (B / dp) * (cfg.vocab / tp) * BF16
    detail["logit_traffic"] = logit_traffic

    traffic = (param_traffic + act_traffic + kv_traffic + ssm_traffic
               + logit_traffic)

    # -- residency ------------------------------------------------------------------
    resid = resid_params
    if kind == "train":
        # remat stash: one residual-stream activation per layer (sharded
        # over TP under sequence parallelism, divided by microbatching)
        stash = L * tok_dp * D * BF16 / accum_steps
        if cfg.seq_parallel:
            stash /= tp
        resid += stash
        resid += tok_dp * (cfg.padded_vocab / tp) * BF16 / accum_steps
        if accum_steps > 1:
            resid += P / n_devices * F32   # gradient accumulation buffer
    if kind != "train" and cfg.family != "ssm":
        C = min(S, cfg.sliding_window) if cfg.sliding_window else S
        resid += L * (B / dp) * cfg.n_kv_heads * (C / tp) * hd * BF16 * 2
    if cfg.family in ("ssm", "hybrid") and kind != "train":
        state = cfg.n_ssm_heads * cfg.ssm_state * cfg.ssm_head_dim
        resid += L * (B / dp) * (state / tp) * F32
    if cfg.enc_dec and kind != "train":
        resid += L * (B / dp) * cfg.n_heads * cfg.enc_frames * hd * BF16 \
            * 2 / tp
    detail["residency"] = resid

    return MemoryModel(traffic, resid, detail)
