"""Flash attention (causal / windowed, GQA) — Pallas TPU kernel.

Tiling: grid (batch·heads, S/BQ, S/BK). Each (q-block, k-block) step keeps
the (BQ, BK) score tile in VMEM, maintains online-softmax running max /
normalizer / accumulator in f32 VMEM scratch, and writes the output block
once on the final k step — the S×S score matrix never touches HBM. The
MXU sees (BQ, hd)×(hd, BK) and (BQ, BK)×(BK, hd) matmuls with BQ = BK =
128 (hardware-aligned).

GQA: query-head h reads kv-head h // group via the kv BlockSpec index
map — no repeated KV materialization.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    relevant = True
    if causal:
        # whole k-block strictly above the diagonal → nothing to do
        relevant = ki * block_k <= qi * block_q + block_q - 1
    if window:
        relevant = jnp.logical_and(
            relevant, ki * block_k + block_k - 1
            > qi * block_q - window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (BH, S, hd); k/v: (BHkv, S, hd) with BH % BHkv == 0.

    Sequences are padded to the block size internally; ``window`` > 0
    gives sliding-window causal attention.
    """
    BH, S, hd = q.shape
    BHkv = k.shape[0]
    group = BH // BHkv
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, max(S, 8))
    block_k = min(block_k, max(S, 8))
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sq, Sk = q.shape[1], k.shape[1]
    grid = (BH, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
