"""Fused top-k selection for ORDER BY … LIMIT — Pallas TPU kernel.

Final pipelines with both sort keys and a LIMIT only ever surface
``limit`` rows, yet the generic path ships the full filtered batch to the
host sorter. This kernel sorts the whole VMEM-resident batch with the
bitonic network (descending keys are per-key direction flips, invalid
rows sort last) and masks everything past the first ``limit`` survivors,
so the fragment emits at most ``limit`` valid rows. The coordinator's
final host sort still runs — the network's position tiebreak gives the
same stable tie order as ``np.lexsort``, making the pre-selection exactly
idempotent under it.

Capacity must be a power of two (``bucket_capacity`` guarantees it) and
fit the roofline resident cap; the dispatch wrapper falls back to the
generic path otherwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sortnet import bitonic_sort


def _topk_kernel(*refs, n_sort: int, directions, limit: int, n: int):
    inv_ref = refs[0]
    in_refs = refs[1:1 + (len(refs) - 1) // 2]
    out_refs = refs[1 + len(in_refs):]
    operands = [inv_ref[...][0]] + [r[...][0] for r in in_refs]
    res = bitonic_sort(operands, num_keys=1 + n_sort,
                       directions=[1] + list(directions))
    cols, mask = res[1:-1], res[-1]
    keep = jax.lax.broadcasted_iota(jnp.int32, (n,), 0) < limit
    for r, c in zip(out_refs[:-1], cols):
        r[...] = c[None, :]
    out_refs[-1][...] = ((mask != 0) & keep).astype(jnp.int32)[None, :]


def fused_topk(columns: dict, mask, *, pred, sort_keys, limit: int,
               interpret: bool = False):
    """Sort by ``sort_keys`` ([(name, desc), …]) and keep the top
    ``limit`` valid rows. Returns ``(out_cols, out_mask)`` at input
    capacity: columns in sorted order, mask true only on the first
    ``limit`` survivors. ``pred`` folds into the validity mask."""
    n = int(mask.shape[0])
    assert n & (n - 1) == 0, f"topk needs a power-of-two capacity: {n}"
    m = mask
    if pred is not None:
        m = m & pred(columns)
    key_names = [name for name, _ in sort_keys]
    directions = tuple(-1 if desc else 1 for _, desc in sort_keys)
    carry = [c for c in columns if c not in key_names]
    names = tuple(key_names + carry)
    arrs = [columns[c] for c in names]
    if not interpret:
        arrs = [a.astype(jnp.float32) if jnp.issubdtype(a.dtype,
                                                        jnp.floating)
                else a.astype(jnp.int32) for a in arrs]
    inv = (~m).astype(jnp.int32)

    spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    n_arr = len(arrs) + 1                            # columns + mask
    out_shape = ([jax.ShapeDtypeStruct((1, n), a.dtype) for a in arrs]
                 + [jax.ShapeDtypeStruct((1, n), jnp.int32)])
    res = pl.pallas_call(
        functools.partial(_topk_kernel, n_sort=len(sort_keys),
                          directions=directions, limit=limit, n=n),
        grid=(1,),
        in_specs=[spec] * (1 + n_arr),
        out_specs=[spec] * n_arr,
        out_shape=out_shape,
        interpret=interpret,
    )(inv.reshape(1, n),
      *[a.reshape(1, n) for a in arrs],
      m.astype(jnp.int32).reshape(1, n))
    out = {c: r[0] for c, r in zip(names, res[:-1])}
    return out, res[-1][0] != 0
