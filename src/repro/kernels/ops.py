"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs as traced jnp on the host, validating semantics; on TPU
the same call sites compile to Mosaic. ``interpret`` auto-detects the
backend so call sites never change.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import bloom as _bloom
from repro.kernels import filter_agg as _fa
from repro.kernels import flash_attention as _flash
from repro.kernels import groupby_onehot as _go
from repro.kernels import join_probe as _jp
from repro.kernels import segmented_minmax as _smm
from repro.kernels import sort_agg as _sa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import topk as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                   "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = _flash.DEFAULT_BLOCK_Q,
                    block_k: int = _flash.DEFAULT_BLOCK_K):
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A_log, B, C, *, chunk: int = 128):
    return _ssd.ssd_scan(x, dt, A_log, B, C, chunk=chunk,
                         interpret=_interpret())


@partial(jax.jit, static_argnames=("date_lo", "date_hi", "disc_lo",
                                   "disc_hi", "qty_hi", "block"))
def filter_agg(shipdate, discount, quantity, extendedprice, *,
               date_lo: int, date_hi: int, disc_lo: float,
               disc_hi: float, qty_hi: float,
               block: int = _fa.BLOCK_ROWS):
    return _fa.filter_agg(
        shipdate, discount, quantity, extendedprice, date_lo=date_lo,
        date_hi=date_hi, disc_lo=disc_lo, disc_hi=disc_hi, qty_hi=qty_hi,
        block=block, interpret=_interpret())


@partial(jax.jit, static_argnames=("n_groups", "block"))
def groupby_onehot(group_ids, values, *, n_groups: int,
                   block: int = _go.BLOCK_ROWS):
    return _go.groupby_onehot(group_ids, values, n_groups=n_groups,
                              block=block, interpret=_interpret())


# -- generic fused kernels (engine dispatch targets) --------------------------
#
# Not jitted here: the expression closures aren't stable jit keys, and the
# call sites — the lowered fragment programs built by ``repro.exec.lower``
# — are already traced inside one jitted program per fragment op tree.

def fused_filter_agg(columns: dict, mask, *, pred, aggs,
                     block: int = _fa.BLOCK_ROWS):
    return _fa.fused_filter_agg(columns, mask, pred=pred, aggs=aggs,
                                block=block, interpret=_interpret())


def fused_groupby(columns: dict, mask, *, pred, gid_fn, aggs,
                  n_groups: int, block: int = _go.BLOCK_ROWS):
    return _go.fused_groupby(columns, mask, pred=pred, gid_fn=gid_fn,
                             aggs=aggs, n_groups=n_groups, block=block,
                             interpret=_interpret())


def fused_groupby_minmax(columns: dict, mask, *, pred, gid_fn, aggs,
                         n_groups: int, block: int):
    return _smm.fused_groupby_minmax(
        columns, mask, pred=pred, gid_fn=gid_fn, aggs=aggs,
        n_groups=n_groups, block=block, interpret=_interpret())


def fused_join_probe_agg(probe_cols: dict, probe_mask, sorted_keys,
                         sorted_payload: dict, *, probe_key: str, pred,
                         gid_fn, aggs, n_groups: int, block: int):
    return _jp.fused_join_probe_agg(
        probe_cols, probe_mask, sorted_keys, sorted_payload,
        probe_key=probe_key, pred=pred, gid_fn=gid_fn, aggs=aggs,
        n_groups=n_groups, block=block, interpret=_interpret())


def fused_sort_agg(columns: dict, mask, *, group_cols, pred, aggs):
    return _sa.fused_sort_agg(columns, mask, group_cols=group_cols,
                              pred=pred, aggs=aggs,
                              interpret=_interpret())


def fused_bloom_filter(columns: dict, mask, *, pred, key: str, words,
                       bits: int, k: int, block: int):
    return _bloom.fused_bloom_filter(
        columns, mask, pred=pred, key=key, words=words, bits=bits, k=k,
        block=block, interpret=_interpret())


def fused_topk(columns: dict, mask, *, pred, sort_keys, limit: int):
    return _tk.fused_topk(columns, mask, pred=pred, sort_keys=sort_keys,
                          limit=limit, interpret=_interpret())


def join_key_dtype():
    """Key lane dtype the fused join/sort kernels will use on this
    backend — exposed so the XLA build-side prepass matches."""
    from repro.kernels.common import key_dtype
    return key_dtype(_interpret())
