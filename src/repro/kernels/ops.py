"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the
kernel body runs as traced jnp on the host, validating semantics; on TPU
the same call sites compile to Mosaic. ``interpret`` auto-detects the
backend so call sites never change.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import filter_agg as _fa
from repro.kernels import flash_attention as _flash
from repro.kernels import groupby_onehot as _go
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                   "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = _flash.DEFAULT_BLOCK_Q,
                    block_k: int = _flash.DEFAULT_BLOCK_K):
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A_log, B, C, *, chunk: int = 128):
    return _ssd.ssd_scan(x, dt, A_log, B, C, chunk=chunk,
                         interpret=_interpret())


@partial(jax.jit, static_argnames=("date_lo", "date_hi", "disc_lo",
                                   "disc_hi", "qty_hi", "block"))
def filter_agg(shipdate, discount, quantity, extendedprice, *,
               date_lo: int, date_hi: int, disc_lo: float,
               disc_hi: float, qty_hi: float,
               block: int = _fa.BLOCK_ROWS):
    return _fa.filter_agg(
        shipdate, discount, quantity, extendedprice, date_lo=date_lo,
        date_hi=date_hi, disc_lo=disc_lo, disc_hi=disc_hi, qty_hi=qty_hi,
        block=block, interpret=_interpret())


@partial(jax.jit, static_argnames=("n_groups", "block"))
def groupby_onehot(group_ids, values, *, n_groups: int,
                   block: int = _go.BLOCK_ROWS):
    return _go.groupby_onehot(group_ids, values, n_groups=n_groups,
                              block=block, interpret=_interpret())


# -- generic fused kernels (engine dispatch targets) --------------------------
#
# Not jitted here: the expression closures aren't stable jit keys, and the
# call sites — the lowered fragment programs built by ``repro.exec.lower``
# — are already traced inside one jitted program per fragment op tree.

def fused_filter_agg(columns: dict, mask, *, pred, aggs,
                     block: int = _fa.BLOCK_ROWS):
    return _fa.fused_filter_agg(columns, mask, pred=pred, aggs=aggs,
                                block=block, interpret=_interpret())


def fused_groupby(columns: dict, mask, *, pred, gid_fn, aggs,
                  n_groups: int, block: int = _go.BLOCK_ROWS):
    return _go.fused_groupby(columns, mask, pred=pred, gid_fn=gid_fn,
                             aggs=aggs, n_groups=n_groups, block=block,
                             interpret=_interpret())
