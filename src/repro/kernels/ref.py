"""Pure-jnp oracles for every Pallas kernel (correctness references)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """q: (BH, S, hd); k/v: (BHkv, S, hd)."""
    BH, S, hd = q.shape
    group = BH // k.shape[0]
    k = jnp.repeat(k, group, axis=0)
    v = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A_log, B, C) -> jnp.ndarray:
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x: (batch, S, H, P); dt: (batch, S, H); A_log: (H,);
    B/C: (batch, S, N). Returns (batch, S, H, P)."""
    bsz, S, H, P = x.shape
    N = B.shape[-1]
    a = -np.exp(np.asarray(A_log, np.float64))
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Bf = np.asarray(B, np.float64)
    Cf = np.asarray(C, np.float64)
    y = np.zeros((bsz, S, H, P))
    state = np.zeros((bsz, H, N, P))
    for t in range(S):
        decay = np.exp(dtf[:, t] * a)                      # (b,H)
        upd = np.einsum("bn,bhp->bhnp", Bf[:, t], xf[:, t]) \
            * dtf[:, t][:, :, None, None]
        state = state * decay[:, :, None, None] + upd
        y[:, t] = np.einsum("bn,bhnp->bhp", Cf[:, t], state)
    return jnp.asarray(y, x.dtype)


def filter_agg_ref(shipdate, discount, quantity, extendedprice, *,
                   date_lo, date_hi, disc_lo, disc_hi, qty_hi):
    """TPC-H Q6 oracle: sum(extendedprice * discount) over the mask."""
    m = ((shipdate >= date_lo) & (shipdate < date_hi)
         & (discount >= disc_lo) & (discount <= disc_hi)
         & (quantity < qty_hi))
    return jnp.sum(jnp.where(m, extendedprice * discount, 0.0),
                   dtype=jnp.float32)


def groupby_agg_ref(group_ids, values, n_groups: int):
    """Grouped sums: group_ids (n,), values (n, A) → (n_groups, A)."""
    return jax.ops.segment_sum(values, group_ids, num_segments=n_groups)
