"""One-hot grouped aggregation (TPC-H Q1) — Pallas TPU kernel.

For group keys with a small known domain K (Q1: returnflag × linestatus,
K = 6), grouped sums become a matmul: a (block, K) one-hot matrix of the
group ids against the (block, A) aggregate-input columns runs on the MXU
and accumulates into a persistent (K, A) VMEM tile — scatter-free
aggregation, the TPU-native replacement for the hash table a CPU engine
would use. Grid = row blocks, result accumulated across sequential steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 1024


def _groupby_kernel(gid_ref, val_ref, n_ref, o_ref, *, block: int,
                    n_groups: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = i * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = rows < n_ref[0]
    gid = gid_ref[0]                                     # (block,)
    onehot = (gid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, n_groups), 1))
    onehot = jnp.where(valid[:, None], onehot, False)
    vals = val_ref[0]                                    # (block, A)
    o_ref[...] += jax.lax.dot_general(
        onehot.astype(jnp.float32), vals,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (K, A)


def groupby_onehot(group_ids, values, *, n_groups: int,
                   block: int = BLOCK_ROWS,
                   interpret: bool = False) -> jnp.ndarray:
    """group_ids: (n,) int32 in [0, n_groups); values: (n, A) f32.
    Returns (n_groups, A) grouped sums (append a ones column for counts).
    """
    n, A = values.shape
    block = min(block, max(n, 8))
    pad = (-n) % block
    if pad:
        group_ids = jnp.pad(group_ids, (0, pad))
        values = jnp.pad(values, ((0, pad), (0, 0)))
    nb = (n + pad) // block

    out = pl.pallas_call(
        functools.partial(_groupby_kernel, block=block,
                          n_groups=n_groups),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block, A), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n_groups, A), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, A), jnp.float32),
        interpret=interpret,
    )(group_ids.astype(jnp.int32).reshape(nb, block),
      values.astype(jnp.float32).reshape(nb, block, A),
      jnp.asarray([n], jnp.int32))
    return out
