"""One-hot grouped aggregation (TPC-H Q1) — Pallas TPU kernel.

For group keys with a small known domain K (Q1: returnflag × linestatus,
K = 6), grouped sums become a matmul: a (block, K) one-hot matrix of the
group ids against the (block, A) aggregate-input columns runs on the MXU
and accumulates into a persistent (K, A) VMEM tile — scatter-free
aggregation, the TPU-native replacement for the hash table a CPU engine
would use. Grid = row blocks, result accumulated across sequential steps.

:func:`groupby_onehot` is the fixed-layout benchmark kernel;
:func:`fused_groupby` is the generic kernel behind the engine's dispatch
layer (``repro.exec.lower``): predicate, group-id, and aggregate-input
expressions are compiled jnp closures evaluated inside the kernel body,
so a matched scan→filter→partial_agg(grouped) fragment filters and
aggregates in one streaming matmul pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import acc_dtype, pad_block

BLOCK_ROWS = 1024


def _groupby_kernel(gid_ref, val_ref, n_ref, o_ref, *, block: int,
                    n_groups: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = i * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = rows < n_ref[0]
    gid = gid_ref[0]                                     # (block,)
    onehot = (gid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, n_groups), 1))
    onehot = jnp.where(valid[:, None], onehot, False)
    vals = val_ref[0]                                    # (block, A)
    o_ref[...] += jax.lax.dot_general(
        onehot.astype(jnp.float32), vals,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (K, A)


def groupby_onehot(group_ids, values, *, n_groups: int,
                   block: int = BLOCK_ROWS,
                   interpret: bool = False) -> jnp.ndarray:
    """group_ids: (n,) int32 in [0, n_groups); values: (n, A) f32.
    Returns (n_groups, A) grouped sums (append a ones column for counts).
    """
    n, A = values.shape
    block = min(block, max(n, 8))
    pad = (-n) % block
    if pad:
        group_ids = jnp.pad(group_ids, (0, pad))
        values = jnp.pad(values, ((0, pad), (0, 0)))
    nb = (n + pad) // block

    out = pl.pallas_call(
        functools.partial(_groupby_kernel, block=block,
                          n_groups=n_groups),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block, A), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n_groups, A), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, A), jnp.float32),
        interpret=interpret,
    )(group_ids.astype(jnp.int32).reshape(nb, block),
      values.astype(jnp.float32).reshape(nb, block, A),
      jnp.asarray([n], jnp.int32))
    return out


# -- generic fused filter+grouped-aggregate (kernel-dispatch target) ----------

def _fused_groupby_kernel(*refs, names, pred, gid_fn, aggs, acc,
                          n_groups: int, block: int):
    *col_refs, mask_ref, o_ref = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cols = {n: r[...][0] for n, r in zip(names, col_refs)}   # (block,)
    m = mask_ref[...][0] != 0
    if pred is not None:
        m = m & pred(cols)
    # masked rows get gid -1: their one-hot row is all-false, so they
    # contribute to no group — filter and aggregation fuse into one matmul
    gid = jnp.where(m, gid_fn(cols).astype(jnp.int32), -1)
    onehot = (gid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, n_groups), 1))
    vals = []
    for fn, argf in aggs:
        if fn == "count":
            vals.append(jnp.ones((block,), acc))
        else:
            v = jnp.broadcast_to(jnp.asarray(argf(cols), acc), (block,))
            vals.append(v.astype(acc))
    vals.append(jnp.ones((block,), acc))                     # presence
    V = jnp.stack(vals, axis=1)                              # (block, A+1)
    o_ref[...] += jax.lax.dot_general(
        onehot.astype(acc), V, (((0,), (0,)), ((), ())),
        preferred_element_type=acc)                          # (K, A+1)


def fused_groupby(columns: dict, mask, *, pred, gid_fn, aggs,
                  n_groups: int, block: int = BLOCK_ROWS,
                  interpret: bool = False) -> jnp.ndarray:
    """One-pass filtered grouped aggregation over named column blocks.

    ``gid_fn`` maps the column dict to mixed-radix group ids in
    [0, n_groups); ``aggs`` is a list of ``(fn, argf)`` with fn in
    {sum, count}. Returns (n_groups, A+1): the A aggregate columns plus
    a trailing per-group presence count (rows surviving the filter).
    """
    acc = acc_dtype(interpret)
    names = tuple(columns)
    n = mask.shape[0]
    block = min(block, max(n, 8))
    arrs, mask, nb = pad_block([columns[c] for c in names], mask, block)
    if not interpret:
        arrs = [a.astype(jnp.float32) if jnp.issubdtype(a.dtype,
                                                        jnp.floating)
                else a.astype(jnp.int32) for a in arrs]
    A = len(aggs)

    return pl.pallas_call(
        functools.partial(
            _fused_groupby_kernel, names=names, pred=pred, gid_fn=gid_fn,
            aggs=aggs, acc=acc, n_groups=n_groups, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))
                  for _ in range(len(names) + 1)],
        out_specs=pl.BlockSpec((n_groups, A + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, A + 1), acc),
        interpret=interpret,
    )(*[a.reshape(nb, block) for a in arrs],
      mask.astype(jnp.int32).reshape(nb, block))
