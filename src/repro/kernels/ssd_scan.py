"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid (batch·heads, n_chunks); the chunk axis is the innermost (sequential)
grid dim, so the running (N, P) state lives in f32 VMEM scratch and
carries across chunk steps (reset at chunk 0). Per chunk the kernel
computes the intra-chunk quadratic term ((Q, Q) masked-decay score tile —
MXU matmuls (Q,N)×(N,Q) and (Q,Q)×(Q,P)) plus the inter-chunk
contribution from the carried state, exactly the state-space-duality
formulation. The (Q, Q) tile stays in VMEM; HBM sees only the chunk
inputs and outputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, o_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    da = da_ref[0].astype(jnp.float32)        # (Q, 1)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    cum = jnp.cumsum(da, axis=0)              # (Q, 1) inclusive
    total = cum[chunk - 1]                    # (1,)

    # intra-chunk: y_s += Σ_{t<=s} (C_s·B_t)·exp(cum_s−cum_t)·dt_t·x_t
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    decay = jnp.exp(cum - cum[:, 0][None, :])  # (Q,Q): cum_s - cum_t
    s_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(t_pos <= s_pos, cb * decay * dt[:, 0][None, :], 0.0)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y_s += exp(cum_s) · C_s · state_in
    y += jax.lax.dot_general(Cm, state_scr[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)

    # state update: state = exp(total)·state + Σ_t exp(total−cum_t)·dt_t·B_t⊗x_t
    wb = Bm * (jnp.exp(total[None, :] - cum) * dt)     # (Q, N)
    state_scr[...] = state_scr[...] * jnp.exp(total)[0] + \
        jax.lax.dot_general(wb, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan(x, dt, A_log, B, C, *, chunk: int = 128,
             interpret: bool = False) -> jnp.ndarray:
    """SSD forward. x: (batch, S, H, P); dt: (batch, S, H) (softplus'd);
    A_log: (H,); B/C: (batch, S, N). Returns (batch, S, H, P)."""
    bsz, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    a = -jnp.exp(A_log.astype(jnp.float32))
    da = dt.astype(jnp.float32) * a[None, None, :]

    # head-major flattening: (B·H, S, ·)
    xh = x.transpose(0, 2, 1, 3).reshape(bsz * H, Sp, P)
    dth = dt.transpose(0, 2, 1).reshape(bsz * H, Sp, 1)
    dah = da.transpose(0, 2, 1).reshape(bsz * H, Sp, 1)

    grid = (bsz * H, Sp // chunk)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk, N),
                         lambda bh, c, h=H: (bh // h, c, 0)),
            pl.BlockSpec((1, chunk, N),
                         lambda bh, c, h=H: (bh // h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * H, Sp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xh, dth, dah, B, C)
    out = out.reshape(bsz, H, Sp, P).transpose(0, 2, 1, 3)
    return out[:, :S]
