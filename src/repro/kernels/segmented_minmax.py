"""Grouped aggregation with MIN/MAX — Pallas TPU kernel.

Completes the ``groupby_onehot`` coverage: sums and counts still run as
one one-hot matmul on the MXU, while min/max columns become masked
broadcast reductions on the VPU — ``min(where(onehot, v, +inf), axis=0)``
over the same (block, K) one-hot matrix, accumulated into the persistent
(K, A+1) tile with ``jnp.minimum``/``jnp.maximum``. Absent groups keep
the ±inf identities, exactly matching ``jax.ops.segment_min/max`` on the
generic path, so the dispatch layer's bit-parity contract holds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import NEUTRAL, acc_dtype, pad_block


def init_group_tile(aggs, n_groups: int, acc) -> jnp.ndarray:
    """(K, A+1) accumulator seeded with each aggregate's identity."""
    cols = [jnp.full((n_groups,), NEUTRAL[fn], acc) for fn, _ in aggs]
    cols.append(jnp.zeros((n_groups,), acc))           # presence count
    return jnp.stack(cols, axis=1)


def grouped_tile_update(tile, m, gid, cols, aggs, acc, *, block: int,
                        n_groups: int) -> jnp.ndarray:
    """One block's contribution folded into the (K, A+1) tile.

    ``m`` is the surviving-row mask, ``gid`` the raw group ids; masked
    rows get gid -1 — an all-false one-hot row — so they reach no group
    through either the matmul or the broadcast reductions. Shared by the
    segmented min/max and fused join-probe kernels.
    """
    gid = jnp.where(m, gid.astype(jnp.int32), -1)
    onehot = (gid[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block, n_groups), 1))              # (block, K)
    mm_vals = []
    for fn, argf in aggs:
        if fn == "count":
            mm_vals.append(jnp.ones((block,), acc))
        elif fn == "sum":
            v = jnp.broadcast_to(jnp.asarray(argf(cols), acc), (block,))
            mm_vals.append(v.astype(acc))
    mm_vals.append(jnp.ones((block,), acc))            # presence
    mm = jax.lax.dot_general(
        onehot.astype(acc), jnp.stack(mm_vals, axis=1),
        (((0,), (0,)), ((), ())),
        preferred_element_type=acc)                    # (K, n_mm)
    out_cols, k = [], 0
    for j, (fn, argf) in enumerate(aggs):
        if fn in ("sum", "count"):
            out_cols.append(tile[:, j] + mm[:, k])
            k += 1
            continue
        v = jnp.broadcast_to(jnp.asarray(argf(cols), acc), (block,))
        v = v.astype(acc)[:, None]                     # (block, 1)
        if fn == "min":
            colv = jnp.min(jnp.where(onehot, v, acc(jnp.inf)), axis=0)
            out_cols.append(jnp.minimum(tile[:, j], colv))
        else:                                          # max
            colv = jnp.max(jnp.where(onehot, v, acc(-jnp.inf)), axis=0)
            out_cols.append(jnp.maximum(tile[:, j], colv))
    out_cols.append(tile[:, -1] + mm[:, -1])
    return jnp.stack(out_cols, axis=1)


def _minmax_kernel(*refs, names, pred, gid_fn, aggs, acc, n_groups: int,
                   block: int):
    *col_refs, mask_ref, o_ref = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = init_group_tile(aggs, n_groups, acc)

    cols = {n: r[...][0] for n, r in zip(names, col_refs)}   # (block,)
    m = mask_ref[...][0] != 0
    if pred is not None:
        m = m & pred(cols)
    o_ref[...] = grouped_tile_update(o_ref[...], m, gid_fn(cols), cols,
                                     aggs, acc, block=block,
                                     n_groups=n_groups)


def fused_groupby_minmax(columns: dict, mask, *, pred, gid_fn, aggs,
                         n_groups: int, block: int,
                         interpret: bool = False) -> jnp.ndarray:
    """One-pass filtered grouped aggregation with min/max support.

    Same contract as :func:`repro.kernels.groupby_onehot.fused_groupby`
    but ``aggs`` fns may be any of {sum, count, min, max}. Returns
    (n_groups, A+1): aggregate columns (absent groups hold the identity:
    0 for sum/count, ±inf for min/max) plus the presence count.
    """
    acc = acc_dtype(interpret)
    names = tuple(columns)
    n = mask.shape[0]
    block = min(block, max(n, 8))
    arrs, mask, nb = pad_block([columns[c] for c in names], mask, block)
    if not interpret:
        arrs = [a.astype(jnp.float32) if jnp.issubdtype(a.dtype,
                                                        jnp.floating)
                else a.astype(jnp.int32) for a in arrs]
    A = len(aggs)

    return pl.pallas_call(
        functools.partial(
            _minmax_kernel, names=names, pred=pred, gid_fn=gid_fn,
            aggs=aggs, acc=acc, n_groups=n_groups, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))
                  for _ in range(len(names) + 1)],
        out_specs=pl.BlockSpec((n_groups, A + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_groups, A + 1), acc),
        interpret=interpret,
    )(*[a.reshape(nb, block) for a in arrs],
      mask.astype(jnp.int32).reshape(nb, block))
