"""Fused filter + aggregate scan (TPC-H Q6) — Pallas TPU kernel.

The hot loop of a scan-heavy serverless query worker: evaluate a
conjunctive range predicate over columnar blocks and accumulate
sum(extendedprice·discount) and the matching-row count in one pass —
columns stream HBM→VMEM once, no intermediate mask or filtered column is
ever materialized. Grid = row blocks; the (1, 2) result tile accumulates
across sequential grid steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 2048


def _filter_agg_kernel(ship_ref, disc_ref, qty_ref, price_ref, n_ref,
                       o_ref, *, date_lo, date_hi, disc_lo, disc_hi,
                       qty_hi, block: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    ship = ship_ref[...]
    disc = disc_ref[...]
    qty = qty_ref[...]
    price = price_ref[...]
    mask = ((ship >= date_lo) & (ship < date_hi)
            & (disc >= disc_lo) & (disc <= disc_hi)
            & (qty < qty_hi) & (rows < n_ref[0]))
    zero = jnp.zeros((), jnp.float32)
    val = jnp.where(mask, price * disc, zero)
    cnt = mask.astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(val, dtype=jnp.float32)
    o_ref[0, 1] += jnp.sum(cnt, dtype=jnp.float32)


def filter_agg(shipdate, discount, quantity, extendedprice, *,
               date_lo: int, date_hi: int, disc_lo: float, disc_hi: float,
               qty_hi: float, block: int = BLOCK_ROWS,
               interpret: bool = False) -> jnp.ndarray:
    """Columns are 1-D f32/i32 arrays of equal length n (padded
    internally). Returns (2,) f32: [revenue sum, match count]."""
    n = shipdate.shape[0]
    block = min(block, max(n, 8))
    pad = (-n) % block
    if pad:
        shipdate = jnp.pad(shipdate, (0, pad))
        discount = jnp.pad(discount, (0, pad))
        quantity = jnp.pad(quantity, (0, pad))
        extendedprice = jnp.pad(extendedprice, (0, pad))
    nb = (n + pad) // block

    def as2d(x, dtype):
        return x.astype(dtype).reshape(nb, block)

    out = pl.pallas_call(
        functools.partial(
            _filter_agg_kernel, date_lo=date_lo, date_hi=date_hi,
            disc_lo=disc_lo, disc_hi=disc_hi, qty_hi=qty_hi, block=block),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.float32),
        interpret=interpret,
    )(as2d(shipdate, jnp.int32), as2d(discount, jnp.float32),
      as2d(quantity, jnp.float32), as2d(extendedprice, jnp.float32),
      jnp.asarray([n], jnp.int32))
    return out[0]
