"""Fused filter + aggregate scan — Pallas TPU kernels.

The hot loop of a scan-heavy serverless query worker: evaluate a
conjunctive predicate over columnar blocks and accumulate the aggregates
in one pass — columns stream HBM→VMEM once, no intermediate mask or
filtered column is ever materialized. Grid = row blocks; the (1, A)
result tile accumulates across sequential grid steps.

Two entry points:

  * :func:`filter_agg` — the Q6-specialized benchmark kernel (fixed
    predicate shape, sum(price·discount) + count);
  * :func:`fused_filter_agg` — the generic kernel behind the engine's
    dispatch layer (``repro.exec.lower``): predicate and aggregate-input
    expressions are compiled jnp closures evaluated *inside* the kernel
    body over the VMEM-resident column tiles, so any matched
    scan→filter→partial_agg fragment runs as one streaming pass.

In interpret mode (CPU CI) the generic kernel accumulates in float64,
bit-comparable with the generic jnp operator path; on TPU it runs the
same program in float32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import NEUTRAL, acc_dtype, pad_block

BLOCK_ROWS = 2048


def _filter_agg_kernel(ship_ref, disc_ref, qty_ref, price_ref, n_ref,
                       o_ref, *, date_lo, date_hi, disc_lo, disc_hi,
                       qty_hi, block: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = i * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    ship = ship_ref[...]
    disc = disc_ref[...]
    qty = qty_ref[...]
    price = price_ref[...]
    mask = ((ship >= date_lo) & (ship < date_hi)
            & (disc >= disc_lo) & (disc <= disc_hi)
            & (qty < qty_hi) & (rows < n_ref[0]))
    zero = jnp.zeros((), jnp.float32)
    val = jnp.where(mask, price * disc, zero)
    cnt = mask.astype(jnp.float32)
    o_ref[0, 0] += jnp.sum(val, dtype=jnp.float32)
    o_ref[0, 1] += jnp.sum(cnt, dtype=jnp.float32)


def filter_agg(shipdate, discount, quantity, extendedprice, *,
               date_lo: int, date_hi: int, disc_lo: float, disc_hi: float,
               qty_hi: float, block: int = BLOCK_ROWS,
               interpret: bool = False) -> jnp.ndarray:
    """Columns are 1-D f32/i32 arrays of equal length n (padded
    internally). Returns (2,) f32: [revenue sum, match count]."""
    n = shipdate.shape[0]
    block = min(block, max(n, 8))
    pad = (-n) % block
    if pad:
        shipdate = jnp.pad(shipdate, (0, pad))
        discount = jnp.pad(discount, (0, pad))
        quantity = jnp.pad(quantity, (0, pad))
        extendedprice = jnp.pad(extendedprice, (0, pad))
    nb = (n + pad) // block

    def as2d(x, dtype):
        return x.astype(dtype).reshape(nb, block)

    out = pl.pallas_call(
        functools.partial(
            _filter_agg_kernel, date_lo=date_lo, date_hi=date_hi,
            disc_lo=disc_lo, disc_hi=disc_hi, qty_hi=qty_hi, block=block),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.float32),
        interpret=interpret,
    )(as2d(shipdate, jnp.int32), as2d(discount, jnp.float32),
      as2d(quantity, jnp.float32), as2d(extendedprice, jnp.float32),
      jnp.asarray([n], jnp.int32))
    return out[0]


# -- generic fused filter+aggregate (kernel-dispatch target) -----------------

def _fused_filter_agg_kernel(*refs, names, pred, aggs, acc, block: int):
    *col_refs, mask_ref, o_ref = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        for j, (fn, _) in enumerate(aggs):
            if NEUTRAL[fn]:
                o_ref[0, j] = acc(NEUTRAL[fn])

    cols = {n: r[...] for n, r in zip(names, col_refs)}   # (1, block)
    m = mask_ref[...] != 0
    if pred is not None:
        m = m & pred(cols)
    for j, (fn, argf) in enumerate(aggs):
        if fn == "count":
            o_ref[0, j] += jnp.sum(m.astype(acc))
            continue
        v = jnp.broadcast_to(jnp.asarray(argf(cols), acc), m.shape)
        v = v.astype(acc)
        if fn == "sum":
            o_ref[0, j] += jnp.sum(jnp.where(m, v, acc(0)))
        elif fn == "min":
            o_ref[0, j] = jnp.minimum(
                o_ref[0, j], jnp.min(jnp.where(m, v, acc(jnp.inf))))
        elif fn == "max":
            o_ref[0, j] = jnp.maximum(
                o_ref[0, j], jnp.max(jnp.where(m, v, acc(-jnp.inf))))


def fused_filter_agg(columns: dict, mask, *, pred, aggs,
                     block: int = BLOCK_ROWS,
                     interpret: bool = False) -> jnp.ndarray:
    """One-pass ungrouped filter+aggregate over named column blocks.

    ``columns``: dict of equal-length 1-D arrays; ``mask``: bool (n,)
    validity; ``pred``: compiled-expression closure over the column dict
    (or None); ``aggs``: list of ``(fn, argf)`` with fn in
    {sum, count, min, max} and argf a closure (None for count).
    Returns the (A,) accumulator vector.
    """
    acc = acc_dtype(interpret)
    names = tuple(columns)
    n = mask.shape[0]
    block = min(block, max(n, 8))
    arrs, m, nb = pad_block([columns[c] for c in names], mask, block)
    if not interpret:
        arrs = [a.astype(jnp.float32) if jnp.issubdtype(a.dtype,
                                                        jnp.floating)
                else a.astype(jnp.int32) for a in arrs]
    A = len(aggs)

    out = pl.pallas_call(
        functools.partial(
            _fused_filter_agg_kernel, names=names, pred=pred, aggs=aggs,
            acc=acc, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))
                  for _ in range(len(names) + 1)],
        out_specs=pl.BlockSpec((1, A), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, A), acc),
        interpret=interpret,
    )(*[a.reshape(nb, block) for a in arrs],
      m.astype(jnp.int32).reshape(nb, block))
    return out[0]
