"""Semi-join Bloom filters — build, merge, probe (np / jnp / Pallas).

When a pipeline materializes the build side of a repartition join, each
worker folds the join-key column of its output into a compact Bloom
filter; the coordinator OR-merges the per-fragment words and publishes
the merged filter in the build exchange's manifest. Probe-side scan
fragments then test every row against the filter *before* partitioning,
so rows that cannot find a join partner die on the worker that scanned
them instead of being shuffled (requests + bytes are the dominant
serverless cost — see ``CostModel.semijoin_benefit``).

All three probe paths — host numpy (the l0-write kill in
``exec.fragment``), traced jnp (the fallback fragment program), and the
Pallas kernel (``fused_bloom_filter``, dispatched by ``exec.lower``) —
share one hash family so a bit set by any builder is found by every
prober:

  * double hashing over a 32-bit murmur3 finalizer (``fmix32``):
    ``pos_i = (h1 + i·h2) & (n_bits − 1)`` with ``h2`` forced odd, so
    the k probes cycle the full power-of-two bit space. 32-bit lanes
    keep the same arithmetic exact on the TPU VPU (no 64-bit lanes in
    Mosaic) and in numpy.
  * two key modes, recorded in the filter so build and probe always
    apply the identical transform: ``u32`` truncates a single integer
    join-key column (kernel-eligible); ``hash64`` takes the low 32 bits
    of the engine's combined uint64 key hash (multi-column or
    non-integer keys; host-side only).

Sizing: ``n_bits = pow2(~12 bits per expected distinct key)``, k = 6,
for a theoretical false-positive rate around 0.4% (residue rows are
still shuffled but then dropped by the exact join).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOOM_K = 6
BLOOM_BITS_PER_KEY = 12
BLOOM_MIN_BITS = 1 << 10            # 128 B floor: never degenerate
BLOOM_MAX_BITS = 1 << 22            # 512 KiB cap: stays VMEM-resident
_SEED1 = np.uint32(0x9E3779B9)
_SEED2 = np.uint32(0x41C64E6D)
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35


def _fmix32_np(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32, copy=True)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint32(16)
        x *= np.uint32(_M1)
        x ^= x >> np.uint32(13)
        x *= np.uint32(_M2)
        x ^= x >> np.uint32(16)
    return x


def _fmix32_jnp(x):
    x = x.astype(jnp.uint32)
    x ^= x >> jnp.uint32(16)
    x *= jnp.uint32(_M1)
    x ^= x >> jnp.uint32(13)
    x *= jnp.uint32(_M2)
    x ^= x >> jnp.uint32(16)
    return x


def bloom_bits_for(n_keys: int, *, bits_per_key: int = BLOOM_BITS_PER_KEY,
                   max_bits: int = BLOOM_MAX_BITS) -> int:
    """Power-of-two filter size for an expected distinct-key count
    (typically a KMV estimate), clamped to [BLOOM_MIN_BITS, max_bits]."""
    want = max(int(n_keys), 1) * bits_per_key
    bits = 1 << max(math.ceil(math.log2(max(want, 1))), 0)
    return max(BLOOM_MIN_BITS, min(bits, max_bits))


def bloom_fpr(n_keys: int, n_bits: int, k: int = BLOOM_K) -> float:
    """Theoretical false-positive rate (1 - e^{-kn/m})^k."""
    if n_bits <= 0:
        return 1.0
    return (1.0 - math.exp(-k * max(n_keys, 0) / n_bits)) ** k


def bloom_build(keys_u32: np.ndarray, n_bits: int,
                k: int = BLOOM_K) -> np.ndarray:
    """Set the k bit positions of every key; returns the uint32 words
    (n_bits/32 of them). ``n_bits`` must be a power of two."""
    assert n_bits & (n_bits - 1) == 0, n_bits
    words = np.zeros(n_bits // 32, dtype=np.uint32)
    if keys_u32.size == 0:
        return words
    keys_u32 = keys_u32.astype(np.uint32, copy=False)
    with np.errstate(over="ignore"):
        h1 = _fmix32_np(keys_u32 ^ _SEED1)
        h2 = _fmix32_np(keys_u32 ^ _SEED2) | np.uint32(1)
        m = np.uint32(n_bits - 1)
        for i in range(k):
            pos = (h1 + np.uint32(i) * h2) & m
            np.bitwise_or.at(words, pos >> np.uint32(5),
                             np.uint32(1) << (pos & np.uint32(31)))
    return words


def bloom_merge(words_list) -> np.ndarray:
    """OR-union of same-size filters (build fragments are unioned the
    way KMV sketches are merged)."""
    out = None
    for w in words_list:
        w = np.asarray(w, dtype=np.uint32)
        out = w.copy() if out is None else np.bitwise_or(out, w)
    if out is None:
        raise ValueError("bloom_merge of zero filters")
    return out


def bloom_probe_np(keys_u32: np.ndarray, words: np.ndarray, n_bits: int,
                   k: int = BLOOM_K) -> np.ndarray:
    """Membership mask (bool) — no false negatives by construction."""
    if keys_u32.size == 0:
        return np.zeros(0, dtype=bool)
    keys_u32 = keys_u32.astype(np.uint32, copy=False)
    with np.errstate(over="ignore"):
        h1 = _fmix32_np(keys_u32 ^ _SEED1)
        h2 = _fmix32_np(keys_u32 ^ _SEED2) | np.uint32(1)
        m = np.uint32(n_bits - 1)
        hit = np.ones(keys_u32.shape, dtype=bool)
        for i in range(k):
            pos = (h1 + np.uint32(i) * h2) & m
            bit = (words[pos >> np.uint32(5)]
                   >> (pos & np.uint32(31))) & np.uint32(1)
            hit &= bit != 0
    return hit


def bloom_probe_jnp(keys, words, *, bits: int, k: int = BLOOM_K):
    """jnp twin of :func:`bloom_probe_np` — bit-identical positions.
    ``keys`` is any integer array (truncated to uint32 like the np
    path); ``words`` a uint32 array."""
    ku = keys.astype(jnp.uint32)
    h1 = _fmix32_jnp(ku ^ jnp.uint32(_SEED1))
    h2 = _fmix32_jnp(ku ^ jnp.uint32(_SEED2)) | jnp.uint32(1)
    m = jnp.uint32(bits - 1)
    hit = jnp.ones(ku.shape, dtype=bool)
    for i in range(k):
        pos = (h1 + jnp.uint32(i) * h2) & m
        w = jnp.take(words, (pos >> jnp.uint32(5)).astype(jnp.int32))
        hit &= ((w >> (pos & jnp.uint32(31))) & jnp.uint32(1)) != 0
    return hit


# -- key extraction (build and probe must agree) --------------------------------

def key_mode_for(columns: dict, key_cols: list[str]) -> str:
    """``u32`` for a single integer key column (kernel-eligible),
    ``hash64`` otherwise."""
    if len(key_cols) == 1:
        col = columns.get(key_cols[0])
        if col is not None and col.dtype.kind in "iu":
            return "u32"
    return "hash64"


def keys_u32(columns: dict, key_cols: list[str], mode: str) -> np.ndarray:
    """The 32-bit key stream a filter is built over / probed with.
    Both sides of a join must use the same mode or false negatives
    appear — the mode travels inside the published filter."""
    if mode == "u32":
        with np.errstate(over="ignore"):
            return columns[key_cols[0]].astype(np.uint32)
    from repro.exec.operators import np_key_hash
    h = np_key_hash(columns, key_cols)
    return (h & np.uint64(0xFFFFFFFF)).astype(np.uint32)


# -- serialization (registry manifests are msgpack) ------------------------------

def bloom_to_wire(words: np.ndarray, *, k: int = BLOOM_K,
                  mode: str = "u32") -> dict:
    words = np.asarray(words, dtype=np.uint32)
    return {"bits": int(words.size * 32), "k": int(k), "mode": mode,
            "words": words.tobytes()}


def bloom_from_wire(d: dict) -> dict:
    """Decoded filter: words as a uint32 array, ready to probe."""
    words = np.frombuffer(d["words"], dtype=np.uint32)
    return {"bits": int(d["bits"]), "k": int(d["k"]),
            "mode": d.get("mode", "u32"), "words": words}


# -- fused Pallas probe kernel (exec.lower dispatch target) ----------------------

def _bloom_filter_kernel(*refs, names, key, pred, bits, k, block: int):
    *col_refs, mask_ref, words_ref, o_ref = refs
    cols = {n: r[...] for n, r in zip(names, col_refs)}   # (1, block)
    m = mask_ref[...] != 0
    if pred is not None:
        m = m & pred(cols)
    ku = cols[key].astype(jnp.uint32)
    h1 = _fmix32_jnp(ku ^ jnp.uint32(_SEED1))
    h2 = _fmix32_jnp(ku ^ jnp.uint32(_SEED2)) | jnp.uint32(1)
    bm = jnp.uint32(bits - 1)
    words = words_ref[...]
    hit = m
    for i in range(k):
        pos = (h1 + jnp.uint32(i) * h2) & bm
        w = jnp.take(words, (pos >> jnp.uint32(5)).astype(jnp.int32))
        hit = hit & (((w >> (pos & jnp.uint32(31))) & jnp.uint32(1)) != 0)
    o_ref[...] = hit.astype(jnp.int32)


def fused_bloom_filter(columns: dict, mask, *, pred, key: str, words,
                       bits: int, k: int = BLOOM_K, block: int = 2048,
                       interpret: bool = False):
    """One-pass predicate + Bloom membership mask over column blocks.

    The filter words stay VMEM-resident across the whole row grid (the
    size cap keeps them ≤ 512 KiB); each grid step evaluates the
    compiled predicate closure and the k hash probes over one (1, block)
    tile and emits the surviving-row mask tile. Returns a bool (n,)
    mask aligned with the inputs — the caller compacts the columns.
    """
    from repro.kernels.common import pad_block
    names = tuple(columns)
    n = mask.shape[0]
    if n == 0:
        return jnp.zeros((0,), dtype=bool)
    block = min(block, max(n, 8))
    arrs, m, nb = pad_block([columns[c] for c in names], mask, block)
    if not interpret:
        arrs = [a.astype(jnp.float32) if jnp.issubdtype(a.dtype,
                                                        jnp.floating)
                else a.astype(jnp.int32) for a in arrs]
    words = jnp.asarray(words, dtype=jnp.uint32)
    nw = words.shape[0]

    out = pl.pallas_call(
        functools.partial(
            _bloom_filter_kernel, names=names, key=key, pred=pred,
            bits=bits, k=k, block=block),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))
                  for _ in range(len(names) + 1)]
        + [pl.BlockSpec((nw,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.int32),
        interpret=interpret,
    )(*[a.reshape(nb, block) for a in arrs],
      m.astype(jnp.int32).reshape(nb, block), words)
    return out.reshape(-1)[:n] != 0
