"""Sort-path grouped aggregation — Pallas TPU kernel.

For group domains too large (or unknown) for the one-hot MXU kernels the
planner picks the *sort* strategy: lexicographic sort by group keys, then
segment reduces. This kernel runs the whole thing inside one VMEM-resident
grid step: a bitonic sorting network orders ``[invalid] + keys`` (carrying
the aggregate inputs and mask), boundary flags and a log-step segmented
inclusive scan produce per-segment totals on each segment's last row, and
a second bitonic pass — a *placement* sort by destination — compacts those
rows to output positions 0..S-1 with the identity rows parked behind them.
Every output (segment order, empty-segment identities: int64 sentinel
keys, 0 sums, ±inf min/max, false mask) matches the generic
``operators.make_sort_agg`` lane for lane.

Everything is selects, static shifts, and reshapes (``kernels.sortnet``)
— no gathers — so the network vectorizes on the VPU. The input capacity
must be a power of two and fit the roofline's resident-rows cap; the
dispatch wrapper falls back to the identical XLA sort path otherwise
(capacities from ``bucket_capacity`` always qualify).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import acc_dtype, key_dtype
from repro.kernels.sortnet import bitonic_sort, segmented_scan


def _sort_agg_kernel(*refs, n_keys: int, fns, acc, kdt, n: int):
    inv_ref = refs[0]
    key_refs = refs[1:1 + n_keys]
    val_refs = refs[1 + n_keys:1 + n_keys + len(fns)]
    mask_ref = refs[1 + n_keys + len(fns)]
    out_key_refs = refs[2 + n_keys + len(fns):2 + 2 * n_keys + len(fns)]
    out_val_refs = refs[2 + 2 * n_keys + len(fns):-1]
    out_mask_ref = refs[-1]
    sentinel = jnp.asarray(jnp.iinfo(kdt).max, kdt)

    operands = ([inv_ref[...][0]]
                + [r[...][0] for r in key_refs]
                + [r[...][0] for r in val_refs]
                + [mask_ref[...][0]])
    res = bitonic_sort(operands, num_keys=1 + n_keys)
    s_keys = res[1:1 + n_keys]
    s_vals = res[1 + n_keys:-1]
    s_mask = res[-1] != 0

    diff = jnp.zeros((n - 1,), bool)
    for k in [res[0]] + list(s_keys):
        diff = diff | (k[1:] != k[:-1])
    flags = jnp.concatenate([jnp.ones((1,), bool), diff])
    is_last = jnp.concatenate([diff, jnp.ones((1,), bool)])
    seg = jnp.cumsum(flags.astype(jnp.int32)) - 1

    maskf = s_mask.astype(acc)
    totals = []
    for fn, v in zip(fns, s_vals):
        if fn in ("sum", "count"):
            totals.append(segmented_scan(v * maskf, flags,
                                         jnp.add, acc(0)))
        elif fn == "min":
            totals.append(segmented_scan(
                jnp.where(s_mask, v, acc(jnp.inf)), flags, jnp.minimum,
                acc(jnp.inf)))
        else:                                           # max
            totals.append(segmented_scan(
                jnp.where(s_mask, v, acc(-jnp.inf)), flags, jnp.maximum,
                acc(-jnp.inf)))

    # segment-last rows carry the results to their segment's output slot;
    # everything else parks behind with the empty-segment identities
    dest = jnp.where(is_last, seg, jnp.int32(n))
    carried = [jnp.where(is_last & s_mask, k, sentinel) for k in s_keys]
    for fn, t in zip(fns, totals):
        ident = acc({"min": jnp.inf, "max": -jnp.inf}.get(fn, 0.0))
        carried.append(jnp.where(is_last, t, ident))
    carried.append((is_last & s_mask).astype(jnp.int32))
    placed = bitonic_sort([dest] + carried, num_keys=1)[1:]

    for r, k in zip(out_key_refs, placed[:n_keys]):
        r[...] = k[None, :]
    for r, v in zip(out_val_refs, placed[n_keys:-1]):
        r[...] = v[None, :]
    out_mask_ref[...] = placed[-1][None, :]


def fused_sort_agg(columns: dict, mask, *, group_cols, pred, aggs,
                   interpret: bool = False):
    """One-pass filtered sort-strategy grouped aggregation.

    Same output contract as ``operators.make_sort_agg`` applied after the
    filters: ``(out_cols, out_mask)`` at input capacity, group keys int64
    with sentinel-filled empty segments. ``pred`` folds into the validity
    mask (filtered rows sort last as invalid). Capacity must be a power
    of two (callers go through ``bucket_capacity``).
    """
    acc = acc_dtype(interpret)
    kdt = key_dtype(interpret)
    n = int(mask.shape[0])
    assert n & (n - 1) == 0, f"sort_agg needs a power-of-two capacity: {n}"
    m = mask
    if pred is not None:
        m = m & pred(columns)
    inv = (~m).astype(jnp.int32)
    keys = [columns[c].astype(kdt) for c in group_cols]
    fns = []
    vals = []
    for _, fn, argf in aggs:
        fns.append(fn)
        if fn == "count":
            vals.append(m.astype(acc))
        else:
            v = jnp.asarray(argf(columns), acc)
            vals.append(jnp.broadcast_to(v, m.shape).astype(acc))
    fns = tuple(fns)

    spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    n_in = 2 + len(keys) + len(vals)
    out_shape = ([jax.ShapeDtypeStruct((1, n), kdt) for _ in keys]
                 + [jax.ShapeDtypeStruct((1, n), acc) for _ in vals]
                 + [jax.ShapeDtypeStruct((1, n), jnp.int32)])
    res = pl.pallas_call(
        functools.partial(_sort_agg_kernel, n_keys=len(keys), fns=fns,
                          acc=acc, kdt=kdt, n=n),
        grid=(1,),
        in_specs=[spec] * n_in,
        out_specs=[spec] * len(out_shape),
        out_shape=out_shape,
        interpret=interpret,
    )(inv.reshape(1, n),
      *[k.reshape(1, n) for k in keys],
      *[v.reshape(1, n) for v in vals],
      m.astype(jnp.int32).reshape(1, n))
    out_keys = res[:len(keys)]
    out_vals = res[len(keys):-1]
    out = {c: k[0] for c, k in zip(group_cols, out_keys)}
    for (name, _, _), v in zip(aggs, out_vals):
        out[name] = v[0]
    return out, res[-1][0] != 0
