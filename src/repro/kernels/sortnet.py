"""In-kernel sorting-network and segmented-scan primitives.

Shared by the fused sort-aggregation and top-k kernels: a bitonic sort
over equal-length power-of-two arrays and a log-step segmented inclusive
scan, both built entirely from reshapes, static slices, and element-wise
selects — no gathers — so they lower to Mosaic and vectorize on the VPU.

The compare-exchange partner at distance j (a power of two) is index
``i ^ j``: reshaping to ``(-1, 2, j)`` and flipping the middle axis swaps
exactly bit j. A trailing original-position key makes the comparison a
total order, which (a) removes the classic duplicate-key corruption of
select-based bitonic networks and (b) makes the sort *stable* — the same
tie order ``np.lexsort`` produces, which the top-k kernel relies on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _partner(a: jnp.ndarray, j: int) -> jnp.ndarray:
    """Value at index ``i ^ j`` for every i (j a power of two)."""
    return jnp.flip(a.reshape(-1, 2, j), axis=1).reshape(a.shape)


def _lex_less(keys, partner_keys, directions) -> jnp.ndarray:
    """Strict lexicographic self < partner, honoring per-key direction
    (+1 ascending, -1 descending). Built backwards so key 0 dominates."""
    less = jnp.zeros(keys[0].shape, bool)
    for k, pk, d in zip(keys[::-1], partner_keys[::-1],
                        directions[::-1]):
        lt = (k < pk) if d >= 0 else (k > pk)
        less = lt | ((k == pk) & less)
    return less


def bitonic_sort(arrays: list, num_keys: int,
                 directions: list | None = None) -> list:
    """Sort equal-length (n,) arrays, n a power of two, lexicographically
    by the first ``num_keys`` arrays; the rest are carried along. Returns
    the sorted arrays (original position breaks ties — stable)."""
    n = int(arrays[0].shape[0])
    assert n & (n - 1) == 0, f"bitonic sort needs a power of two, got {n}"
    dirs = list(directions or []) + [1] * (num_keys - len(directions or []))
    idx = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    arrays = list(arrays) + [idx]           # position tiebreak key
    keys = lambda arrs: arrs[:num_keys] + [arrs[-1]]
    kdirs = dirs[:num_keys] + [1]
    if n == 1:
        return arrays[:-1]
    for stage in range(n.bit_length() - 1):     # block size 2^(stage+1)
        asc = (idx & (1 << (stage + 1))) == 0
        for sub in range(stage, -1, -1):
            j = 1 << sub
            partners = [_partner(a, j) for a in arrays]
            less = _lex_less(keys(arrays), keys(partners), kdirs)
            is_left = (idx & j) == 0
            keep_small = is_left == asc
            # the total order (position tiebreak) makes `less` exactly
            # inverted on the partner lane, so min/max selects agree
            take_self = keep_small == less
            arrays = [jnp.where(take_self, a, p)
                      for a, p in zip(arrays, partners)]
    return arrays[:-1]


def _shift_right(a: jnp.ndarray, d: int, fill) -> jnp.ndarray:
    pad = jnp.full((d,), fill, a.dtype)
    return jnp.concatenate([pad, a[:-d]])


def segmented_scan(vals: jnp.ndarray, heads: jnp.ndarray,
                   combine, identity) -> jnp.ndarray:
    """Segmented *inclusive* scan (Hillis–Steele, log n static steps):
    ``heads`` marks segment starts; each segment's total lands on its
    last element. Static shifts only — no gathers."""
    n = int(vals.shape[0])
    flag = heads.astype(bool)
    d = 1
    while d < n:
        shifted = _shift_right(vals, d, identity)
        blocked = _shift_right(flag, d, True)
        vals = jnp.where(flag, vals, combine(vals, shifted))
        flag = flag | blocked
        d *= 2
    return vals
