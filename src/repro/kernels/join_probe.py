"""Fused broadcast-join probe + partial aggregation — Pallas TPU kernel.

``join → [filter…] → partial_agg`` chains (the Q12/Q14/Q19 shape) probe a
PK build side and immediately aggregate; on the generic path the probe
output materializes as full-width columns before the aggregate consumes
it. Here the *sorted* build side (keys + payload, prepared by one XLA
argsort outside the kernel — identical to ``make_pk_join_probe``) stays
VMEM-resident across every grid step, each probe block runs a vectorized
in-kernel binary search against it, gathers payload for the hits, applies
the residual predicates, and folds straight into the aggregation tile —
the joined relation never leaves VMEM.

The searches and payload gathers use ``jnp.take`` (dynamic gathers on
Mosaic); a one-hot matmul against the resident build side is the
MXU-friendly alternative if a target rejects them. Hit semantics mirror
the generic operator exactly: ``sorted_key[pos] == probe_key``, probe row
valid, and probe key ≠ the int64 mask sentinel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (NEUTRAL, acc_dtype, key_dtype,
                                  pad_block)
from repro.kernels.segmented_minmax import (grouped_tile_update,
                                            init_group_tile)


def _lower_bound(sk, pk, n_build: int):
    """Vectorized lower-bound binary search of ``pk`` (block,) in the
    sorted ``sk`` (B,): first index with sk[i] >= pk, like
    ``jnp.searchsorted(side='left')``. Static trip count."""
    lo = jnp.zeros(pk.shape, jnp.int32)
    hi = jnp.full(pk.shape, n_build, jnp.int32)
    for _ in range(max(int(n_build).bit_length(), 1)):
        active = lo < hi
        mid = (lo + hi) // 2
        mv = jnp.take(sk, mid)
        go_right = active & (mv < pk)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
    return lo


def _join_probe_kernel(*refs, names, bnames, pred, probe_key, gid_fn,
                       aggs, acc, kdt, n_groups: int, block: int,
                       n_build: int):
    n_probe_refs = len(names) + 1                 # probe columns + mask
    col_refs = refs[:len(names)]
    mask_ref = refs[len(names)]
    sk_ref = refs[n_probe_refs]
    payload_refs = refs[n_probe_refs + 1:-1]
    o_ref = refs[-1]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        if n_groups:
            o_ref[...] = init_group_tile(aggs, n_groups, acc)
        else:
            o_ref[...] = jnp.zeros_like(o_ref)
            for j, (fn, _) in enumerate(aggs):
                if NEUTRAL[fn]:
                    o_ref[0, j] = acc(NEUTRAL[fn])

    cols = {n: r[...][0] for n, r in zip(names, col_refs)}   # (block,)
    m = mask_ref[...][0] != 0
    sk = sk_ref[...][0]                                      # (B,)
    sentinel = jnp.asarray(jnp.iinfo(kdt).max, kdt)
    pk = cols[probe_key].astype(kdt)
    pos = _lower_bound(sk, pk, n_build)
    pos_c = jnp.clip(pos, 0, n_build - 1)
    hit = (jnp.take(sk, pos_c) == pk) & m & (pk != sentinel)
    for bn, r in zip(bnames, payload_refs):      # gather hits' payload
        cols[bn] = jnp.take(r[...][0], pos_c)
    if pred is not None:
        hit = hit & pred(cols)

    if n_groups:
        o_ref[...] = grouped_tile_update(o_ref[...], hit, gid_fn(cols),
                                         cols, aggs, acc, block=block,
                                         n_groups=n_groups)
        return
    for j, (fn, argf) in enumerate(aggs):
        if fn == "count":
            o_ref[0, j] += jnp.sum(hit.astype(acc))
            continue
        v = jnp.broadcast_to(jnp.asarray(argf(cols), acc), (block,))
        v = v.astype(acc)
        if fn == "sum":
            o_ref[0, j] += jnp.sum(jnp.where(hit, v, acc(0)))
        elif fn == "min":
            o_ref[0, j] = jnp.minimum(
                o_ref[0, j], jnp.min(jnp.where(hit, v, acc(jnp.inf))))
        elif fn == "max":
            o_ref[0, j] = jnp.maximum(
                o_ref[0, j], jnp.max(jnp.where(hit, v, acc(-jnp.inf))))


def fused_join_probe_agg(probe_cols: dict, probe_mask, sorted_keys,
                         sorted_payload: dict, *, probe_key: str, pred,
                         gid_fn, aggs, n_groups: int, block: int,
                         interpret: bool = False) -> jnp.ndarray:
    """One-pass join probe + filter + aggregation.

    ``sorted_keys``/``sorted_payload`` are the build side already sorted
    by join key (masked build rows pushed to the end under the int64
    sentinel — the caller reuses the generic operator's preparation).
    ``aggs`` fns may be any of {sum, count, min, max}. Returns the (A,)
    accumulator for ungrouped aggregation (``n_groups == 0``) or the
    (K, A+1) group tile with trailing presence counts.
    """
    acc = acc_dtype(interpret)
    kdt = key_dtype(interpret)
    names = tuple(probe_cols)
    bnames = tuple(sorted_payload)
    n = probe_mask.shape[0]
    block = min(block, max(n, 8))
    arrs, mask, nb = pad_block([probe_cols[c] for c in names],
                               probe_mask, block)
    sk = sorted_keys.astype(kdt)
    payload = [sorted_payload[c] for c in bnames]
    if not interpret:
        cast = lambda a: (a.astype(jnp.float32)
                          if jnp.issubdtype(a.dtype, jnp.floating)
                          else a.astype(jnp.int32))
        arrs = [cast(a) for a in arrs]
        payload = [cast(a) for a in payload]
    B = int(sk.shape[0])
    A = len(aggs)
    out_shape = (n_groups, A + 1) if n_groups else (1, A)

    out = pl.pallas_call(
        functools.partial(
            _join_probe_kernel, names=names, bnames=bnames, pred=pred,
            probe_key=probe_key, gid_fn=gid_fn, aggs=aggs, acc=acc,
            kdt=kdt, n_groups=n_groups, block=block, n_build=B),
        grid=(nb,),
        in_specs=(
            [pl.BlockSpec((1, block), lambda i: (i, 0))
             for _ in range(len(names) + 1)]
            + [pl.BlockSpec((1, B), lambda i: (0, 0))
               for _ in range(1 + len(bnames))]),
        out_specs=pl.BlockSpec(out_shape, lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(out_shape, acc),
        interpret=interpret,
    )(*[a.reshape(nb, block) for a in arrs],
      mask.astype(jnp.int32).reshape(nb, block),
      sk.reshape(1, B),
      *[p.reshape(1, B) for p in payload])
    return out if n_groups else out[0]
