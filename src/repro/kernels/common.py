"""Helpers shared by the generic fused kernels (the dispatch targets).

Single source of truth for the accumulation-dtype policy, block padding,
and aggregation neutral elements — the jnp operator path
(``repro.exec.operators``) and both fused kernels must agree on these or
their numerics silently diverge.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEUTRAL = {"sum": 0.0, "count": 0.0, "min": float("inf"),
           "max": float("-inf")}


def acc_dtype(interpret: bool):
    """Interpret mode runs on host XLA where f64 matches the generic jnp
    path exactly; Mosaic has no f64, so on-TPU accumulation is f32."""
    if interpret and jax.config.jax_enable_x64:
        return jnp.float64
    return jnp.float32


def key_dtype(interpret: bool):
    """Join/sort key lane dtype: int64 on the host-XLA interpret path
    (bit-parity with the generic operators and their int64 sentinel),
    int32 on TPU where Mosaic has no 64-bit lanes."""
    if interpret and jax.config.jax_enable_x64:
        return jnp.int64
    return jnp.int32


def pad_block(arrs, mask, block):
    """Zero-pad 1-D columns + validity mask to a multiple of ``block``;
    returns (arrs, mask, n_blocks). Pad rows are masked out."""
    n = mask.shape[0]
    pad = (-n) % block
    if pad:
        arrs = [jnp.pad(a, (0, pad)) for a in arrs]
        mask = jnp.pad(mask, (0, pad))
    return arrs, mask, (n + pad) // block
