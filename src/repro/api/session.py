"""``SkyriseSession``: the unified multi-query client entry point.

The paper's coordinator owns exactly one query (section 3.1); the
*service* is multi-tenant — many queries share one FaaS concurrency
quota, one object store, and one semantic result cache (section 3.4). A
session owns those shared pieces once::

    from repro.api import connect

    session = connect(quota=64)
    session.ensure_tpch(sf=0.01)
    handles = [session.submit(sql) for sql in queries]   # concurrent
    for h in handles:
        print(h.result().fetch(session.store))

``submit`` enqueues and returns a :class:`QueryHandle` immediately; a
small scheduler drives up to ``max_concurrent_queries`` per-query
engines. Their fragments run wall-clock-parallel on the platform's
thread pool, each holding one slot of the shared ``AdmissionController``
for exactly its own lifetime (per-fragment slot release) — so the
combined in-flight worker fleet of all queries never exceeds the
per-user quota, and a finished worker's slot immediately serves any
waiting query. Concurrent queries that want the same pipeline
(semantic hash) share one in-flight execution through the registry's
claim/publish/await_complete protocol instead of racing duplicates.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque

from repro.core.cost import CostModel
from repro.core.engine import (CoordinatorConfig, QueryCancelled,
                               QueryEngine, QueryResult)
from repro.core.events import ObserverMux, QueryObserver
from repro.core.platform import FaasPlatform, FaultPlan
from repro.core.registry import ResultRegistry
from repro.core.worker import make_worker_handler
from repro.data.catalog import Catalog
from repro.storage.object_store import (FilesystemBackend, ObjectStore)

from repro.api.handle import QueryHandle, QueryState

_session_counter = itertools.count()


class SkyriseSession:
    """Owns the shared serverless infrastructure of many queries."""

    def __init__(self, store: ObjectStore | None = None,
                 catalog: Catalog | None = None, *,
                 store_dir: str | None = None,
                 tier: str | None = None,
                 platform: FaasPlatform | None = None,
                 quota: int | None = None,
                 faults: FaultPlan | None = None,
                 config: CoordinatorConfig | None = None,
                 cost_model: CostModel | None = None,
                 registry: ResultRegistry | None = None,
                 chaos=None,
                 max_concurrent_queries: int = 4,
                 observers: tuple[QueryObserver, ...] = (),
                 seed: int = 0):
        # Reject conflicting arguments instead of silently ignoring the
        # knobs a pre-built component absorbs.
        if platform is not None and (quota is not None
                                     or faults is not None):
            raise ValueError("pass either a platform or quota/faults "
                             "(set them on the platform), not both")
        if store is not None and (store_dir is not None
                                  or tier is not None):
            raise ValueError("pass either a store or store_dir/tier "
                             "(they configure the built store), not both")
        if store is None:
            backend = FilesystemBackend(store_dir) if store_dir else None
            store = ObjectStore(backend, tier=tier or "s3-standard",
                                seed=seed)
        self.store = store
        self.catalog = catalog
        # A platform this session built is also torn down by close();
        # an externally passed one may be shared with other sessions.
        self._owns_platform = platform is None
        self.platform = platform or FaasPlatform(
            quota=1000 if quota is None else quota, seed=seed,
            faults=faults)
        self.config = config or CoordinatorConfig()
        self.cost_model = cost_model or CostModel()
        # Chaos engine (core.chaos): one shared, seeded fault schedule
        # attached to the store (storage faults + registry/ledger kill
        # points ride on it) and the platform (storms, worker kills).
        self.chaos = chaos
        if chaos is not None:
            self.store.chaos = chaos
            self.platform.chaos = chaos
        # Shared across every query of the session: one result cache,
        # one worker handler (code package) whose SPAX footer cache spans
        # all fragments of all queries, one admission ledger.
        self.registry = registry if registry is not None \
            else ResultRegistry(store)
        if chaos is not None:
            # a registry built before this session snapshotted its KV
            # view (with_tier copies `chaos` at construction) — attach
            # the schedule to that view too so protocol kill points fire
            self.registry.store.chaos = chaos
        self.handler = make_worker_handler(
            store, cost_model=(self.cost_model
                               if self.config.hedged_reads else None))
        self.footer_cache = self.handler.footer_cache
        self.observers = ObserverMux(list(observers))

        self.max_concurrent_queries = max(1, max_concurrent_queries)
        self._sid = next(_session_counter)
        self._qid = itertools.count()
        self._cv = threading.Condition()
        self._queue: deque[QueryHandle] = deque()
        self._threads: list[threading.Thread] = []
        self._active = 0
        self._paused = False
        self._closing = False
        self._handles: list[QueryHandle] = []

    # -- catalog management --------------------------------------------------
    def attach_catalog(self, catalog: Catalog) -> "SkyriseSession":
        self.catalog = catalog
        return self

    def ensure_tpch(self, sf: float = 0.01, *, n_parts: int | None = None,
                    seed: int = 0) -> Catalog:
        """Load the TPC-H catalog from the store, generating it first if
        absent (store-level idempotence: two sessions on one store share
        the dataset)."""
        key = f"tpch/sf{sf:g}/catalog"
        if self.store.exists(key):
            catalog = Catalog.load(self.store, key)
        else:
            from repro.data import generate_tpch
            catalog = generate_tpch(self.store, sf=sf, n_parts=n_parts,
                                    seed=seed)
        self.attach_catalog(catalog)
        return catalog

    # -- query API -----------------------------------------------------------
    def submit(self, sql: str, priority: int = 0, *,
               tenant: str | None = None,
               deadline_s: float | None = None,
               fleet_cap: int | None = None) -> QueryHandle:
        """Enqueue a query; returns its handle immediately.

        ``priority`` orders the session scheduler *and* the platform's
        admission ledger: freed queue positions and worker slots go to
        the highest-priority waiting query (ties FIFO), with an aging
        bump per ``aging_interval_s`` waited (see ``AdmissionController``)
        so low-priority queries are delayed but never starved.

        The service tier (``repro.service``) adds: ``tenant`` — the
        fair-share admission group the query's fragments charge;
        ``deadline_s`` — an SLO deadline in *simulated* seconds, split
        into per-stage latency budgets that drive fleet sizing;
        ``fleet_cap`` — a hard per-pipeline fleet clamp (degraded
        dispatch for over-budget tenants).
        """
        if self.catalog is None:
            raise RuntimeError("no catalog attached — call "
                               "attach_catalog() or ensure_tpch() first")
        handle = QueryHandle(f"s{self._sid}-q{next(self._qid)}", sql, self,
                             priority=priority, tenant=tenant,
                             deadline_s=deadline_s, fleet_cap=fleet_cap)
        handle._enqueued_at = time.monotonic()
        with self._cv:
            if self._closing:
                raise RuntimeError("session is closed")
            self._queue.append(handle)
            self._handles.append(handle)
            self._ensure_workers_locked()
            self._cv.notify_all()
        return handle

    def sql(self, text: str, timeout: float | None = None) -> QueryResult:
        """Submit and block for the result (single-query convenience)."""
        return self.submit(text).result(timeout)

    def explain(self, text: str) -> str:
        """Compile ``text`` and describe its physical plan (no workers
        are invoked)."""
        if self.catalog is None:
            raise RuntimeError("no catalog attached — call "
                               "attach_catalog() or ensure_tpch() first")
        return QueryHandle("explain", text, self).explain()

    # -- scheduler -----------------------------------------------------------
    def pause(self) -> None:
        """Stop admitting queued queries (already-running ones finish).
        Lets clients build a batch, reorder, or cancel before any worker
        is invoked."""
        with self._cv:
            self._paused = True

    def resume(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    def drain(self) -> None:
        """Block until every submitted query reached a terminal state."""
        for h in list(self._handles):
            h.wait()

    def close(self, *, cancel_pending: bool = False) -> None:
        """Finish (or cancel) outstanding queries and stop the workers."""
        if cancel_pending:
            for h in list(self._handles):
                h.cancel()
        with self._cv:
            self._closing = True
            self._paused = False
            self._cv.notify_all()
            threads = list(self._threads)
        for t in threads:
            t.join()
        if self._owns_platform:
            self.platform.close()

    def __enter__(self) -> "SkyriseSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- session-level introspection ----------------------------------------
    def stats(self) -> dict:
        """Aggregate session statistics (shared-infrastructure view)."""
        adm = self.platform.admission
        states = [h.state for h in self._handles]
        return {
            "queries_submitted": len(self._handles),
            "queries_by_state": {
                s.value: sum(1 for x in states if x is s)
                for s in QueryState if any(x is s for x in states)},
            "platform_invocations": self.platform.invocations,
            "platform_cold_starts": self.platform.cold_starts,
            "quota": adm.quota,
            "max_workers_in_flight": adm.max_in_flight,
            "registry_claims": self.registry.claims,
            "inflight_dedup_hits": self.registry.dedup_hits,
            "store_cost_cents": self.store.stats.cost_cents,
            "footer_cache_hits": self.footer_cache.hits,
            "footer_cache_entries": len(self.footer_cache),
            "adaptations": self._count_adaptations(),
            "exchange_strategies": self._count_exchange_strategies(),
            "calibrated_predicates": len(self.store.list("calibration/")),
        }

    def _count_adaptations(self) -> int:
        """Barrier re-optimizations applied across completed queries."""
        n = 0
        for h in self._handles:
            with h._lock:
                result = h._result
            if result is not None:
                n += sum(len(p.adaptations) for p in result.stats.pipelines)
        return n

    def _count_exchange_strategies(self) -> dict[str, int]:
        """Executed hash exchanges per shuffle strategy (exec.exchange)."""
        out: dict[str, int] = {}
        for h in self._handles:
            with h._lock:
                result = h._result
            if result is None:
                continue
            for p in result.stats.pipelines:
                if p.exchange_strategy and not p.cache_hit:
                    out[p.exchange_strategy] = \
                        out.get(p.exchange_strategy, 0) + 1
        return out

    def add_observer(self, observer: QueryObserver) -> None:
        self.observers.add(observer)

    # -- internals -----------------------------------------------------------
    def _engine(self, handle: QueryHandle) -> QueryEngine:
        return QueryEngine(
            self.store, self.catalog, platform=self.platform,
            config=self.config, cost_model=self.cost_model,
            registry=self.registry, handler=self.handler,
            observer=self.observers, query_id=handle.query_id,
            cancel_check=handle._raise_if_cancelled,
            priority=handle.priority, tenant=handle.tenant,
            deadline_s=handle.deadline_s, fleet_cap=handle.fleet_cap)

    def _plan_for(self, handle: QueryHandle):
        """Plan (but do not execute) a handle's query, caching the plan
        on the handle so the scheduler reuses it."""
        with handle._lock:
            plan = handle._plan
        if plan is None:
            plan = self._engine(handle).plan_sql(handle.sql)
            with handle._lock:
                handle._plan = plan
        return plan

    def _display_plan(self, handle: QueryHandle):
        """The *compile-time* plan for EXPLAIN. Once execution begins,
        the engine adapts the cached plan's params in place at stage
        barriers, so a fresh compile is needed to show the planner's
        choices (explain_analyze renders planned vs adapted instead)."""
        with handle._lock:
            state = handle._state
        if state is QueryState.QUEUED:
            return self._plan_for(handle)
        return self._engine(handle).plan_sql(handle.sql)

    def _notify_state(self, handle: QueryHandle, state: QueryState) -> None:
        self.observers.on_query_state(handle.query_id, state.value)

    def _ensure_workers_locked(self) -> None:
        want = min(self.max_concurrent_queries, len(self._queue))
        idle = len(self._threads) - self._active
        for _ in range(max(0, want - idle)):
            if len(self._threads) >= self.max_concurrent_queries:
                break
            t = threading.Thread(
                target=self._worker_loop,
                name=f"skyrise-s{self._sid}-w{len(self._threads)}",
                daemon=True)
            self._threads.append(t)
            t.start()

    def _pop_next_locked(self) -> QueryHandle:
        """Highest effective priority first (priority + aging bump),
        ties in submission order — mirrors the admission ledger, whose
        (configurable) aging interval it shares."""
        now = time.monotonic()
        aging_s = self.platform.admission.aging_interval_s

        def eff(h: QueryHandle) -> float:
            return h.priority + (now - getattr(h, "_enqueued_at", now)) \
                / aging_s

        best = max(range(len(self._queue)),
                   key=lambda i: (eff(self._queue[i]), -i))
        handle = self._queue[best]
        del self._queue[best]
        return handle

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while not self._closing and (self._paused
                                             or not self._queue):
                    self._cv.wait()
                if self._closing and not self._queue:
                    return
                handle = self._pop_next_locked()
                self._active += 1
            try:
                self._run(handle)
            finally:
                with self._cv:
                    self._active -= 1
                    self._cv.notify_all()

    def _run(self, handle: QueryHandle) -> None:
        if not handle._begin(QueryState.PLANNING):
            return  # cancelled while queued: no worker was ever invoked
        engine = self._engine(handle)
        try:
            plan = self._plan_for(handle)
            if not handle._begin(QueryState.RUNNING):
                return
            handle._finish(engine.execute_plan(plan))
        except QueryCancelled:
            handle._finish_cancelled()
        except BaseException as e:  # noqa: BLE001 - surfaced via result()
            handle._fail(e)


def connect(store: ObjectStore | None = None,
            catalog: Catalog | None = None, **kwargs) -> SkyriseSession:
    """Open a :class:`SkyriseSession` — the Skyrise client entry point.

    Accepts either pre-built components (``store``, ``catalog``,
    ``platform``) or the knobs to build them (``store_dir``, ``tier``,
    ``quota``, ``faults``, ``seed``); see :class:`SkyriseSession`.
    """
    return SkyriseSession(store, catalog, **kwargs)
