"""Query handles: the client-side view of one submitted query.

A handle is returned immediately by ``SkyriseSession.submit`` and tracks
the query through an explicit lifecycle::

    QUEUED → PLANNING → RUNNING → SUCCEEDED | FAILED | CANCELLED

``result()`` blocks for the terminal state; ``cancel()`` is guaranteed
never to invoke a worker when the query is still queued, and takes
effect at the next pipeline/wave boundary when it is already running.
"""

from __future__ import annotations

import enum
import threading

from repro.core.engine import QueryCancelled, QueryResult, QueryStats
from repro.core.retry import QueryFailedError


class QueryState(enum.Enum):
    QUEUED = "QUEUED"
    PLANNING = "PLANNING"
    RUNNING = "RUNNING"
    SUCCEEDED = "SUCCEEDED"
    FAILED = "FAILED"
    CANCELLED = "CANCELLED"

    @property
    def terminal(self) -> bool:
        return self in (QueryState.SUCCEEDED, QueryState.FAILED,
                        QueryState.CANCELLED)


class QueryHandle:
    """Lifecycle, result, and stats of one query in a session."""

    def __init__(self, query_id: str, sql: str, session,
                 priority: int = 0, tenant: str | None = None,
                 deadline_s: float | None = None,
                 fleet_cap: int | None = None):
        self.query_id = query_id
        self.sql = sql
        self.priority = priority
        # service-tier attributes (repro.service): fair-share admission
        # group, SLO deadline (simulated seconds), degraded-fleet clamp
        self.tenant = tenant
        self.deadline_s = deadline_s
        self.fleet_cap = fleet_cap
        self._session = session
        # RLock: state transitions notify observers while holding the
        # lock, and observers may read handle.state back.
        self._lock = threading.RLock()
        self._done = threading.Event()
        self._state = QueryState.QUEUED
        self._cancel_requested = False
        self._result: QueryResult | None = None
        self._error: BaseException | None = None
        self._plan = None

    def __repr__(self) -> str:
        return f"<QueryHandle {self.query_id} {self._state.value}>"

    # -- client API ----------------------------------------------------------
    @property
    def state(self) -> QueryState:
        with self._lock:
            return self._state

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until terminal; True if the query reached a terminal
        state within ``timeout``."""
        return self._done.wait(timeout)

    def result(self, timeout: float | None = None) -> QueryResult:
        """Block for the QueryResult; raises on FAILED/CANCELLED.

        Failures surface through the typed taxonomy (``core.retry``):
        an already-typed error — :class:`QueryAborted`,
        :class:`RetryBudgetExhausted`, any :class:`QueryFailedError` —
        is re-raised as-is; anything else is wrapped in a
        :class:`QueryFailedError` with the original exception chained
        (``__cause__``), so the causal chain from the failing fragment
        is preserved either way."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"query {self.query_id} still {self.state.value} "
                f"after {timeout}s")
        with self._lock:
            if self._state is QueryState.CANCELLED:
                raise QueryCancelled(f"query {self.query_id} was cancelled")
            if self._error is not None:
                if isinstance(self._error, QueryFailedError):
                    raise self._error
                raise QueryFailedError(
                    f"query {self.query_id} failed: "
                    f"{self._error}") from self._error
            assert self._result is not None
            return self._result

    def fetch(self, timeout: float | None = None):
        """Shorthand: block for the result and read its columns."""
        return self.result(timeout).fetch(self._session.store)

    def stats(self, timeout: float | None = None) -> QueryStats:
        """Execution statistics of the completed query (blocks)."""
        return self.result(timeout).stats

    def explain(self) -> str:
        """Compile-time physical plan description; plans the query if
        still queued (planning is pure — no workers are invoked)."""
        from repro.core.engine import explain_plan
        return explain_plan(self._session._display_plan(self))

    def explain_analyze(self, timeout: float | None = None) -> str:
        """EXPLAIN ANALYZE: blocks for the result, then renders the plan
        annotated with observed execution — est vs actual rows, planned
        vs invoked fleets, and the barrier adaptations applied."""
        from repro.core.engine import explain_analyze
        res = self.result(timeout)
        with self._lock:
            plan = self._plan
        return explain_analyze(plan, res.stats)

    def error(self) -> BaseException | None:
        """The failure cause once FAILED (None otherwise)."""
        with self._lock:
            return self._error

    def cancel(self) -> bool:
        """Request cancellation. Returns True if the query will not (or
        did not) produce a result: queued queries are cancelled before
        any worker is invoked; running queries stop at the next
        pipeline/wave boundary. False if already finished."""
        with self._lock:
            if self._state.terminal:
                return self._state is QueryState.CANCELLED
            self._cancel_requested = True
            if self._state is QueryState.QUEUED:
                self._transition_locked(QueryState.CANCELLED)
            return True

    # -- scheduler-side transitions -----------------------------------------
    def _transition_locked(self, state: QueryState) -> None:
        self._state = state
        if state.terminal:
            self._done.set()
        self._session._notify_state(self, state)

    def _begin(self, state: QueryState) -> bool:
        """QUEUED → PLANNING (or RUNNING); False if cancelled meanwhile."""
        with self._lock:
            if self._state.terminal:
                return False
            if self._cancel_requested:
                self._transition_locked(QueryState.CANCELLED)
                return False
            self._transition_locked(state)
            return True

    def _raise_if_cancelled(self) -> None:
        """Engine cancel_check hook (called at pipeline/wave boundaries)."""
        with self._lock:
            if self._cancel_requested:
                raise QueryCancelled(self.query_id)

    def _finish(self, result: QueryResult) -> None:
        with self._lock:
            self._result = result
            self._transition_locked(QueryState.SUCCEEDED)

    def _finish_cancelled(self) -> None:
        with self._lock:
            self._transition_locked(QueryState.CANCELLED)

    def _fail(self, error: BaseException) -> None:
        with self._lock:
            self._error = error
            self._transition_locked(QueryState.FAILED)
