"""Skyrise public client API.

Everything a client needs is here::

    from repro.api import connect

    session = connect(quota=128)          # shared platform + store + cache
    session.ensure_tpch(sf=0.01)

    res = session.sql("select count(*) as n from lineitem")   # blocking
    handle = session.submit(TPCH_Q12)                         # concurrent
    print(handle.explain())
    cols = handle.result().fetch(session.store)
    print(handle.stats().cost.total_cents)

Sessions multiplex concurrently submitted queries over one
``FaasPlatform`` concurrency quota (wave-based admission spanning
queries), one worker handler, and one semantic result cache — the
multi-tenant layer the paper's single-query coordinator deliberately
leaves out (section 3.1).
"""

from repro.core.chaos import ChaosConfig, ChaosEngine
from repro.core.engine import (CoordinatorConfig, QueryAborted,
                               QueryCancelled, QueryResult, QueryStats,
                               explain_analyze, explain_plan)
from repro.core.events import ConsoleObserver, QueryObserver
from repro.core.platform import FaasPlatform, FaultPlan
from repro.core.retry import (QueryFailedError, RetryBudgetExhausted,
                              RetryPolicy, TransientInfraError)

from repro.api.handle import QueryHandle, QueryState
from repro.api.session import SkyriseSession, connect

__all__ = [
    "ChaosConfig", "ChaosEngine", "ConsoleObserver", "CoordinatorConfig",
    "FaasPlatform", "FaultPlan", "QueryAborted", "QueryCancelled",
    "QueryFailedError", "QueryHandle", "QueryObserver", "QueryResult",
    "QueryState", "QueryStats", "RetryBudgetExhausted", "RetryPolicy",
    "SkyriseSession", "TransientInfraError", "connect", "explain_analyze",
    "explain_plan",
]
