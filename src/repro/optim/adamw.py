"""AdamW with gradient clipping and cosine schedule (self-contained)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # optimizer-state dtype: bf16 halves m/v residency (8-bit-Adam-style
    # distributed-optimization trick; EXPERIMENTS.md §Perf) — updates are
    # still computed in f32.
    state_dtype: object = jnp.float32

    def init(self, params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, self.state_dtype), params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.copy, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mhat = m / (1 - self.b1 ** step.astype(jnp.float32))
            vhat = v / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
                m.astype(self.state_dtype), v.astype(self.state_dtype)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
