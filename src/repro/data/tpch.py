"""Vectorized TPC-H data generator (dbgen analog).

Generates the eight TPC-H tables at a given scale factor with the spec's
essential value distributions and cross-table dependencies (dates chained
off o_orderdate, l_extendedprice from p_retailprice, returnflag/linestatus
from the 1995-06-17 current date, etc.), writes them as partitioned SPAX
objects, and registers them in a Catalog — mirroring the paper's setup of
Parquet/ZSTD files on S3 with no sort or partition keys (section 4.1).

Partition generation is deterministic per (seed, table, partition) so data
can be produced in parallel and regenerated idempotently.
"""

from __future__ import annotations

import numpy as np

from repro.data.catalog import Catalog, TableMeta
from repro.storage.object_store import ObjectStore
from repro.storage.pax import ColumnSpec, write_pax

EPOCH = np.datetime64("1970-01-01")
CURRENT_DATE = (np.datetime64("1995-06-17") - EPOCH).astype(int)
START_DATE = (np.datetime64("1992-01-01") - EPOCH).astype(int)
END_DATE = (np.datetime64("1998-12-31") - EPOCH).astype(int) - 151


def date_to_int(s: str) -> int:
    return int((np.datetime64(s) - EPOCH).astype(int))


# -- global dictionaries ------------------------------------------------------

RETURNFLAG = ("A", "N", "R")
LINESTATUS = ("F", "O")
SHIPMODE = ("AIR", "FOB", "MAIL", "RAIL", "REG AIR", "SHIP", "TRUCK")
SHIPINSTRUCT = ("COLLECT COD", "DELIVER IN PERSON", "NONE",
                "TAKE BACK RETURN")
ORDERPRIORITY = ("1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW")
ORDERSTATUS = ("F", "O", "P")
MKTSEGMENT = ("AUTOMOBILE", "BUILDING", "FURNITURE", "HOUSEHOLD", "MACHINERY")
BRAND = tuple(f"Brand#{m}{n}" for m in range(1, 6) for n in range(1, 6))
_TYPE_S1 = ("STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO")
_TYPE_S2 = ("ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED")
_TYPE_S3 = ("TIN", "NICKEL", "BRASS", "STEEL", "COPPER")
PTYPE = tuple(f"{a} {b} {c}" for a in _TYPE_S1 for b in _TYPE_S2
              for c in _TYPE_S3)
_CONT_S1 = ("SM", "LG", "MED", "JUMBO", "WRAP")
_CONT_S2 = ("CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM")
CONTAINER = tuple(f"{a} {b}" for a in _CONT_S1 for b in _CONT_S2)
NATION = ("ALGERIA", "ARGENTINA", "BRAZIL", "CANADA", "EGYPT", "ETHIOPIA",
          "FRANCE", "GERMANY", "INDIA", "INDONESIA", "IRAN", "IRAQ", "JAPAN",
          "JORDAN", "KENYA", "MOROCCO", "MOZAMBIQUE", "PERU", "CHINA",
          "ROMANIA", "SAUDI ARABIA", "VIETNAM", "RUSSIA", "UNITED KINGDOM",
          "UNITED STATES")
REGION = ("AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST")
NATION_REGION = (0, 1, 1, 1, 4, 0, 3, 3, 2, 2, 4, 4, 2, 4, 0, 0, 0, 1, 2, 3,
                 4, 2, 3, 3, 1)

I64, I32, F64 = "<i8", "<i4", "<f8"


def _num(n): return ColumnSpec(n, "num", I64)
def _f64(n): return ColumnSpec(n, "num", F64)
def _date(n): return ColumnSpec(n, "num", I32)
def _dict(n, d): return ColumnSpec(n, "dict", I32, d)
def _bytes(n, w): return ColumnSpec(n, "bytes", f"S{w}")


LINEITEM_SCHEMA = [
    _num("l_orderkey"), _num("l_partkey"), _num("l_suppkey"),
    _num("l_linenumber"), _f64("l_quantity"), _f64("l_extendedprice"),
    _f64("l_discount"), _f64("l_tax"), _dict("l_returnflag", RETURNFLAG),
    _dict("l_linestatus", LINESTATUS), _date("l_shipdate"),
    _date("l_commitdate"), _date("l_receiptdate"),
    _dict("l_shipinstruct", SHIPINSTRUCT), _dict("l_shipmode", SHIPMODE),
    _bytes("l_comment", 20),
]

ORDERS_SCHEMA = [
    _num("o_orderkey"), _num("o_custkey"),
    _dict("o_orderstatus", ORDERSTATUS), _f64("o_totalprice"),
    _date("o_orderdate"), _dict("o_orderpriority", ORDERPRIORITY),
    _bytes("o_clerk", 15), _num("o_shippriority"), _bytes("o_comment", 20),
]

CUSTOMER_SCHEMA = [
    _num("c_custkey"), _bytes("c_name", 18), _bytes("c_address", 20),
    _num("c_nationkey"), _bytes("c_phone", 15), _f64("c_acctbal"),
    _dict("c_mktsegment", MKTSEGMENT), _bytes("c_comment", 20),
]

PART_SCHEMA = [
    _num("p_partkey"), _bytes("p_name", 30), _bytes("p_mfgr", 14),
    _dict("p_brand", BRAND), _dict("p_type", PTYPE), _num("p_size"),
    _dict("p_container", CONTAINER), _f64("p_retailprice"),
    _bytes("p_comment", 14),
]

SUPPLIER_SCHEMA = [
    _num("s_suppkey"), _bytes("s_name", 18), _bytes("s_address", 20),
    _num("s_nationkey"), _bytes("s_phone", 15), _f64("s_acctbal"),
    _bytes("s_comment", 20),
]

PARTSUPP_SCHEMA = [
    _num("ps_partkey"), _num("ps_suppkey"), _num("ps_availqty"),
    _f64("ps_supplycost"), _bytes("ps_comment", 20),
]

NATION_SCHEMA = [
    _num("n_nationkey"), _dict("n_name", NATION), _num("n_regionkey"),
    _bytes("n_comment", 20),
]

REGION_SCHEMA = [
    _num("r_regionkey"), _dict("r_name", REGION), _bytes("r_comment", 20),
]

SCHEMAS = {
    "lineitem": LINEITEM_SCHEMA, "orders": ORDERS_SCHEMA,
    "customer": CUSTOMER_SCHEMA, "part": PART_SCHEMA,
    "supplier": SUPPLIER_SCHEMA, "partsupp": PARTSUPP_SCHEMA,
    "nation": NATION_SCHEMA, "region": REGION_SCHEMA,
}


def _retail_price(partkey: np.ndarray) -> np.ndarray:
    return (90000 + (partkey % 20001) + 100 * (partkey % 1000)) / 100.0


def _rand_bytes(rng: np.random.Generator, n: int, width: int) -> np.ndarray:
    letters = rng.integers(65, 91, size=(n, width), dtype=np.uint8)
    return letters.view(f"S{width}").reshape(n)


def _customer_count(sf: float) -> int: return max(int(150_000 * sf), 32)
def _orders_count(sf: float) -> int: return _customer_count(sf) * 10
def _part_count(sf: float) -> int: return max(int(200_000 * sf), 64)
def _supplier_count(sf: float) -> int: return max(int(10_000 * sf), 8)


def gen_orders_partition(sf: float, part: int, n_parts: int,
                         seed: int = 0) -> dict[str, np.ndarray]:
    """Orders rows [lo, hi) of the full table, plus their lineitems."""
    total = _orders_count(sf)
    lo = part * total // n_parts
    hi = (part + 1) * total // n_parts
    n = hi - lo
    rng = np.random.default_rng((seed, 1, part))
    okey = np.arange(lo + 1, hi + 1, dtype=np.int64)
    odate = rng.integers(START_DATE, END_DATE + 1, n).astype(np.int32)
    lines = rng.integers(1, 8, n)  # 1..7 lineitems per order

    orders = {
        "o_orderkey": okey,
        "o_custkey": rng.integers(1, _customer_count(sf) + 1, n,
                                  dtype=np.int64),
        "o_orderstatus": np.zeros(n, np.int32),  # fixed up below
        "o_totalprice": np.zeros(n),             # fixed up below
        "o_orderdate": odate,
        "o_orderpriority": rng.integers(0, len(ORDERPRIORITY), n,
                                        dtype=np.int32),
        "o_clerk": _rand_bytes(rng, n, 15),
        "o_shippriority": np.zeros(n, dtype=np.int64),
        "o_comment": _rand_bytes(rng, n, 20),
    }

    m = int(lines.sum())
    li_order = np.repeat(np.arange(n), lines)
    l_okey = okey[li_order]
    l_odate = odate[li_order].astype(np.int64)
    pk = rng.integers(1, _part_count(sf) + 1, m, dtype=np.int64)
    qty = rng.integers(1, 51, m).astype(np.float64)
    shipdate = (l_odate + rng.integers(1, 122, m)).astype(np.int32)
    commitdate = (l_odate + rng.integers(30, 91, m)).astype(np.int32)
    receiptdate = (shipdate.astype(np.int64)
                   + rng.integers(1, 31, m)).astype(np.int32)
    returned = receiptdate <= CURRENT_DATE
    rflag = np.where(returned,
                     rng.integers(0, 2, m) * 2,      # A(0) or R(2)
                     np.int64(1)).astype(np.int32)   # N(1)
    lstatus = (shipdate > CURRENT_DATE).astype(np.int32)  # F(0)/O(1)
    eprice = qty * _retail_price(pk)

    lineitem = {
        "l_orderkey": l_okey,
        "l_partkey": pk,
        "l_suppkey": rng.integers(1, _supplier_count(sf) + 1, m,
                                  dtype=np.int64),
        "l_linenumber": (np.arange(m, dtype=np.int64)
                         - np.repeat(np.cumsum(lines) - lines, lines) + 1),
        "l_quantity": qty,
        "l_extendedprice": eprice,
        "l_discount": rng.integers(0, 11, m) / 100.0,
        "l_tax": rng.integers(0, 9, m) / 100.0,
        "l_returnflag": rflag,
        "l_linestatus": lstatus,
        "l_shipdate": shipdate,
        "l_commitdate": commitdate,
        "l_receiptdate": receiptdate,
        "l_shipinstruct": rng.integers(0, len(SHIPINSTRUCT), m,
                                       dtype=np.int32),
        "l_shipmode": rng.integers(0, len(SHIPMODE), m, dtype=np.int32),
        "l_comment": _rand_bytes(rng, m, 20),
    }

    # Order-level aggregates derived from lineitems.
    price = eprice * (1 + lineitem["l_tax"]) * (1 - lineitem["l_discount"])
    orders["o_totalprice"] = np.bincount(li_order, weights=price,
                                         minlength=n)
    all_f = np.bincount(li_order, weights=(lstatus == 0), minlength=n) \
        == lines
    all_o = np.bincount(li_order, weights=(lstatus == 1), minlength=n) \
        == lines
    orders["o_orderstatus"] = np.where(
        all_f, 0, np.where(all_o, 1, 2)).astype(np.int32)
    return {"orders": orders, "lineitem": lineitem}


def gen_customer(sf: float, seed: int = 0) -> dict[str, np.ndarray]:
    n = _customer_count(sf)
    rng = np.random.default_rng((seed, 2))
    return {
        "c_custkey": np.arange(1, n + 1, dtype=np.int64),
        "c_name": _rand_bytes(rng, n, 18),
        "c_address": _rand_bytes(rng, n, 20),
        "c_nationkey": rng.integers(0, 25, n, dtype=np.int64),
        "c_phone": _rand_bytes(rng, n, 15),
        "c_acctbal": rng.integers(-99999, 1000000, n) / 100.0,
        "c_mktsegment": rng.integers(0, len(MKTSEGMENT), n, dtype=np.int32),
        "c_comment": _rand_bytes(rng, n, 20),
    }


def gen_part(sf: float, seed: int = 0) -> dict[str, np.ndarray]:
    n = _part_count(sf)
    rng = np.random.default_rng((seed, 3))
    pk = np.arange(1, n + 1, dtype=np.int64)
    return {
        "p_partkey": pk,
        "p_name": _rand_bytes(rng, n, 30),
        "p_mfgr": _rand_bytes(rng, n, 14),
        "p_brand": rng.integers(0, len(BRAND), n, dtype=np.int32),
        "p_type": rng.integers(0, len(PTYPE), n, dtype=np.int32),
        "p_size": rng.integers(1, 51, n, dtype=np.int64),
        "p_container": rng.integers(0, len(CONTAINER), n, dtype=np.int32),
        "p_retailprice": _retail_price(pk),
        "p_comment": _rand_bytes(rng, n, 14),
    }


def gen_supplier(sf: float, seed: int = 0) -> dict[str, np.ndarray]:
    n = _supplier_count(sf)
    rng = np.random.default_rng((seed, 4))
    return {
        "s_suppkey": np.arange(1, n + 1, dtype=np.int64),
        "s_name": _rand_bytes(rng, n, 18),
        "s_address": _rand_bytes(rng, n, 20),
        "s_nationkey": rng.integers(0, 25, n, dtype=np.int64),
        "s_phone": _rand_bytes(rng, n, 15),
        "s_acctbal": rng.integers(-99999, 1000000, n) / 100.0,
        "s_comment": _rand_bytes(rng, n, 20),
    }


def gen_partsupp(sf: float, seed: int = 0) -> dict[str, np.ndarray]:
    n = _part_count(sf) * 4
    rng = np.random.default_rng((seed, 5))
    return {
        "ps_partkey": np.repeat(
            np.arange(1, _part_count(sf) + 1, dtype=np.int64), 4),
        "ps_suppkey": rng.integers(1, _supplier_count(sf) + 1, n,
                                   dtype=np.int64),
        "ps_availqty": rng.integers(1, 10000, n, dtype=np.int64),
        "ps_supplycost": rng.integers(100, 100001, n) / 100.0,
        "ps_comment": _rand_bytes(rng, n, 20),
    }


def gen_nation(seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, 6))
    return {
        "n_nationkey": np.arange(25, dtype=np.int64),
        "n_name": np.arange(25, dtype=np.int32),
        "n_regionkey": np.asarray(NATION_REGION, dtype=np.int64),
        "n_comment": _rand_bytes(rng, 25, 20),
    }


def gen_region(seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng((seed, 7))
    return {
        "r_regionkey": np.arange(5, dtype=np.int64),
        "r_name": np.arange(5, dtype=np.int32),
        "r_comment": _rand_bytes(rng, 5, 20),
    }


def generate_tpch(store: ObjectStore, sf: float = 0.01, *,
                  n_parts: int | None = None, seed: int = 0,
                  row_group_rows: int = 65536,
                  prefix: str | None = None) -> Catalog:
    """Generate all eight tables into the store; return the catalog.

    ``n_parts`` controls lineitem/orders partition-file counts (defaults to
    a size-derived value so partitions stay ~modest); the small tables are
    single objects, matching the paper's unpartitioned-Parquet setup.
    """
    prefix = prefix if prefix is not None else f"tpch/sf{sf:g}"
    if n_parts is None:
        n_parts = max(1, int(np.ceil(_orders_count(sf) / 250_000)))

    catalog = Catalog()
    col_stats: dict[str, dict[str, tuple[float, float]]] = {}

    def _roll_stats(table: str, columns: dict[str, np.ndarray]) -> None:
        # per-column (min, max) zone-map hints for the planner's
        # selectivity estimator (num/dict columns only)
        stats = col_stats.setdefault(table, {})
        for c in SCHEMAS[table]:
            if c.kind not in ("num", "dict") or not len(columns[c.name]):
                continue
            lo = columns[c.name].min().item()
            hi = columns[c.name].max().item()
            if c.name in stats:
                lo, hi = min(lo, stats[c.name][0]), max(hi, stats[c.name][1])
            stats[c.name] = (lo, hi)

    def _write(table: str, columns: dict[str, np.ndarray],
               part: int) -> tuple[str, int, int]:
        key = f"{prefix}/{table}/part-{part:05d}.spax"
        data = write_pax(columns, SCHEMAS[table], row_group_rows)
        store.put(key, data)
        _roll_stats(table, columns)
        return key, len(next(iter(columns.values()))), len(data)

    acc: dict[str, tuple[list[str], int, int]] = {
        t: ([], 0, 0) for t in ("orders", "lineitem")}
    for p in range(n_parts):
        out = gen_orders_partition(sf, p, n_parts, seed)
        for table in ("orders", "lineitem"):
            key, rows, nbytes = _write(table, out[table], p)
            files, r, b = acc[table]
            files.append(key)
            acc[table] = (files, r + rows, b + nbytes)
    for table in ("orders", "lineitem"):
        files, rows, nbytes = acc[table]
        catalog.add(TableMeta(table, SCHEMAS[table], files, rows, nbytes,
                              col_stats.get(table, {})))

    singles = {
        "customer": gen_customer(sf, seed), "part": gen_part(sf, seed),
        "supplier": gen_supplier(sf, seed),
        "partsupp": gen_partsupp(sf, seed),
        "nation": gen_nation(seed), "region": gen_region(seed),
    }
    for table, columns in singles.items():
        key, rows, nbytes = _write(table, columns, 0)
        catalog.add(TableMeta(table, SCHEMAS[table], [key], rows, nbytes,
                              col_stats.get(table, {})))

    catalog.save(store, f"{prefix}/catalog")
    return catalog
