"""External database catalog (the paper's Glue analog, section 3.2).

Maps table names to schemas, partition file lists, global dictionaries, and
simple statistics (row/byte counts). The logical planner validates column
references against it; the physical planner sizes worker fleets from its
byte statistics. Persisted as a msgpack object in the object store so the
coordinator — itself a stateless function — can reconstruct all state.
"""

from __future__ import annotations

import dataclasses

import msgpack

from repro.storage.object_store import ObjectStore
from repro.storage.pax import ColumnSpec


@dataclasses.dataclass
class TableMeta:
    name: str
    schema: list[ColumnSpec]
    files: list[str]
    rows: int
    total_bytes: int
    # Per-column (min, max) zone-map hints for num/dict columns, rolled up
    # from the partition-file zone maps at generation time. Optional: the
    # planner's selectivity estimator falls back to its constant guess for
    # columns (or whole catalogs) without hints.
    column_stats: dict[str, tuple[float, float]] = \
        dataclasses.field(default_factory=dict)

    def spec(self, column: str) -> ColumnSpec:
        for c in self.schema:
            if c.name == column:
                return c
        raise KeyError(f"{self.name}.{column}")

    def has_column(self, column: str) -> bool:
        return any(c.name == column for c in self.schema)

    def column_range(self, column: str) -> tuple[float, float] | None:
        """(min, max) hint for a column, or None when unknown."""
        r = self.column_stats.get(column)
        return (r[0], r[1]) if r is not None else None


@dataclasses.dataclass
class Catalog:
    tables: dict[str, TableMeta] = dataclasses.field(default_factory=dict)

    def add(self, meta: TableMeta) -> None:
        self.tables[meta.name] = meta

    def table(self, name: str) -> TableMeta:
        if name not in self.tables:
            raise KeyError(f"unknown table: {name}")
        return self.tables[name]

    def resolve_column(self, column: str,
                       tables: list[str]) -> tuple[str, ColumnSpec]:
        hits = [(t, self.tables[t].spec(column)) for t in tables
                if self.tables[t].has_column(column)]
        if not hits:
            raise KeyError(f"column {column} not found in {tables}")
        if len(hits) > 1:
            raise KeyError(f"ambiguous column {column} in {tables}")
        return hits[0]

    # -- persistence --------------------------------------------------------
    def to_bytes(self) -> bytes:
        return msgpack.packb({
            "tables": {
                name: {
                    "schema": [
                        {"name": c.name, "kind": c.kind, "dtype": c.dtype,
                         "dict": list(c.dictionary) if c.dictionary else None}
                        for c in t.schema],
                    "files": t.files,
                    "rows": t.rows,
                    "total_bytes": t.total_bytes,
                    "column_stats": {c: [v[0], v[1]]
                                     for c, v in t.column_stats.items()},
                } for name, t in self.tables.items()
            }
        })

    @classmethod
    def from_bytes(cls, data: bytes) -> "Catalog":
        raw = msgpack.unpackb(data)
        cat = cls()
        for name, t in raw["tables"].items():
            schema = [ColumnSpec(c["name"], c["kind"], c["dtype"],
                                 tuple(c["dict"]) if c["dict"] else None)
                      for c in t["schema"]]
            cat.add(TableMeta(name, schema, list(t["files"]), t["rows"],
                              t["total_bytes"],
                              {c: (v[0], v[1]) for c, v in
                               (t.get("column_stats") or {}).items()}))
        return cat

    def save(self, store: ObjectStore, key: str) -> None:
        store.put(key, self.to_bytes())

    @classmethod
    def load(cls, store: ObjectStore, key: str) -> "Catalog":
        return cls.from_bytes(store.get(key).data)
