"""Data pipeline: TPC-H generator and catalog."""

from repro.data.catalog import Catalog, TableMeta
from repro.data.tpch import SCHEMAS, date_to_int, generate_tpch

__all__ = ["Catalog", "SCHEMAS", "TableMeta", "date_to_int", "generate_tpch"]
