"""Logical→physical sharding rules for the production meshes.

Parallelism layout (GSPMD via pjit sharding annotations):

  * TP  ("model" axis): attention head projections, MLP hidden dim, MoE
    expert axis (expert parallelism), vocab dim of embed/lm_head.
  * FSDP ("data" axis): the non-TP dim of every large parameter is sharded
    over the data axis; parameters are all-gathered at use, gradients
    reduce-scattered — XLA's latency-hiding scheduler overlaps both with
    the layer-scan compute.
  * DP  ("pod" + "data"): batch dim of activations; the pod axis is pure
    data parallelism (only gradient all-reduce crosses pods).
  * SP  (long_500k): batch=1, so the sequence dim shards over "data"
    (context parallelism); the SSD inter-chunk recurrence is an
    associative scan, which parallelizes across sequence shards.
  * SSM internals stay TP-free (heads/state dims of the assigned SSM archs
    don't divide 16; the mixers are small) — noted in DESIGN.md.

Every rule is divisibility-guarded: if a dim doesn't divide the axis size
the axis is dropped for that dim (e.g. whisper's 20 heads on a 16-way
model axis), so every (arch × shape × mesh) cell lowers cleanly.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    mesh: Mesh
    dp_axes: tuple[str, ...]        # ("data",) or ("pod", "data")
    tp_axis: str = "model"
    fsdp_axis: str = "data"
    shard_sequence: bool = False    # long_500k context parallelism

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    def axis_size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            return int(np.prod([self.mesh.shape[a] for a in axis]))
        return self.mesh.shape[axis]


def make_plan(mesh: Mesh, *, shard_sequence: bool = False) -> MeshPlan:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return MeshPlan(mesh, dp, shard_sequence=shard_sequence)


def _spec(plan: MeshPlan, shape, axes, *, strict: bool = True) -> P:
    """Build a PartitionSpec with per-dim guards.

    ``strict`` (pjit input shardings): the axis must divide the dim — jax
    rejects padded *argument* layouts. Non-strict (internal
    with_sharding_constraint): GSPMD pads non-divisible dims (e.g. vocab
    49155 over 16 shards), so only dim ≥ axis size is required. Dims
    smaller than the axis (8 kv heads on a 16-way model axis) always stay
    replicated."""
    out = []
    for dim, axis in zip(shape, axes):
        if axis is None:
            out.append(None)
            continue
        size = plan.axis_size(axis)
        ok = (dim % size == 0) if strict else (size <= dim)
        out.append(axis if size > 1 and ok else None)
    return P(*out)


def _named(plan: MeshPlan, shape, axes) -> NamedSharding:
    return NamedSharding(plan.mesh, _spec(plan, shape, axes))


# -- parameters -------------------------------------------------------------------

def param_shardings(plan: MeshPlan, params_shapes):
    """Sharding tree matching a params pytree of ShapeDtypeStructs."""
    tp, fs = plan.tp_axis, plan.fsdp_axis

    def rule(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        s = leaf.shape
        nd = len(s)
        if name == "embed":
            return _named(plan, s, (tp, fs))
        if name == "lm_head":
            return _named(plan, s, (fs, tp))
        def col_parallel(shape):
            # Column-parallel weights (qkv, gate/up): sharding the D rows
            # over the fsdp axis invites the partitioner to contraction-
            # split the dot and replicate the 1M-token activation
            # (multi-GB all-reduces per layer — §Perf it5/it6). Shard the
            # columns over BOTH axes instead: at-rest memory is identical
            # (fully sharded), the at-use gather is over fsdp only, and D
            # stays whole so the clean batch-parallel dot is forced.
            if shape[-1] % (plan.axis_size(fs) * plan.axis_size(tp)) == 0:
                return _named(plan, shape,
                              (None,) * (len(shape) - 1) + ((fs, tp),))
            return _named(plan, shape,
                          (None,) * (len(shape) - 2) + (fs, tp))

        if name in ("wq", "wk", "wv"):
            return col_parallel(s)
        if name == "wo":
            return _named(plan, s, (None, tp, fs))
        if name in ("w1", "w3"):
            if nd == 4:  # MoE (L, E, D, F): expert parallel
                return _named(plan, s, (None, tp, fs, None))
            return col_parallel(s)
        if name == "w2":
            if nd == 4:  # (L, E, F, D)
                return _named(plan, s, (None, tp, None, fs))
            return _named(plan, s, (None, tp, fs))
        if name == "router":
            return _named(plan, s, (None, fs, None))
        if name == "ssm_in":
            return _named(plan, s, (None, fs, None))
        if name == "ssm_out":
            return _named(plan, s, (None, None, fs))
        if name in ("enc_pos", "dec_pos"):
            return _named(plan, s, (None, fs))
        # norms, biases, A_log, conv_w, step counters → replicated
        return NamedSharding(plan.mesh, P())

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def opt_state_shardings(plan: MeshPlan, params_shapes, opt_shapes):
    """m/v mirror the parameter shardings; scalars replicate."""
    pshard = param_shardings(plan, params_shapes)
    return {
        "m": pshard,
        "v": pshard,
        "step": NamedSharding(plan.mesh, P()),
    }


# -- activations / batches -----------------------------------------------------------

def batch_shardings(plan: MeshPlan, batch_shapes):
    dp = plan.dp_axes

    def rule(path, leaf):
        s = leaf.shape
        if len(s) >= 2 and plan.shard_sequence and s[0] == 1:
            # long-context: batch 1 → shard sequence (context parallelism)
            return _named(plan, s, (None, plan.fsdp_axis)
                          + (None,) * (len(s) - 2))
        if len(s) >= 1:
            return _named(plan, s, (dp,) + (None,) * (len(s) - 1))
        return NamedSharding(plan.mesh, P())

    return jax.tree_util.tree_map_with_path(rule, batch_shapes)


def cache_shardings(plan: MeshPlan, cache_shapes):
    """KV/SSM cache: batch over DP; cache length over the model axis
    (sequence-sharded KV — works for any kv-head count); SSM state P-dim
    over the model axis."""
    dp, tp = plan.dp_axes, plan.tp_axis

    def rule(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        s = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v"):
            return _named(plan, s, (None, dp, None, tp, None))
        if name == "ssm_state":
            return _named(plan, s, (None, dp, None, None, tp))
        if name == "conv_state":
            return _named(plan, s, (None, dp, None, tp))
        return NamedSharding(plan.mesh, P())

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def constrain(mesh, x, axes):
    """with_sharding_constraint with divisibility-guarded axes.

    ``axes``: one entry per dim — an axis name, a tuple of axis names, or
    None. Used inside model code where only the mesh is in scope."""
    if mesh is None:
        return x
    plan = make_plan(mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, _spec(plan, x.shape, axes,
                                          strict=False)))


def dp_axes_of(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def head_constraint(mesh, x):
    """(B, H, S, hd) attention tensors: batch over DP, heads over TP."""
    if mesh is None:
        return x
    return constrain(mesh, x, (dp_axes_of(mesh), "model", None, None))


def logits_constraint(plan: MeshPlan, x):
    """Keep logits vocab-sharded to avoid a (B, S, V) replicated tensor."""
    return jax.lax.with_sharding_constraint(
        x, _named(plan, x.shape,
                  (plan.dp_axes,) + (None,) * (x.ndim - 2)
                  + (plan.tp_axis,)))
