"""Checkpointing to serverless object storage.

Training stages are the ML analog of Skyrise's query stages: each stage's
result (params + optimizer state at step N) is written as immutable,
content-addressed objects, so a restarted (or elastically re-scaled)
training job resumes from the last complete stage exactly like an aborted
query resumes from its last registered pipeline result. Writes are
deterministic per (run, step) → idempotent across racing re-executions.

Layout: one compressed object per pytree leaf (parallel ranged restore),
plus a msgpack manifest recording the codec (zstd when available, stdlib
zlib otherwise); a per-run ``latest`` pointer is the only mutated key.
"""

from __future__ import annotations

import jax
import msgpack
import numpy as np

from repro.storage import compression
from repro.storage.object_store import ObjectStore


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save_checkpoint(store: ObjectStore, run: str, step: int,
                    tree) -> str:
    """Returns the manifest key."""
    prefix = f"ckpt/{run}/step{step:08d}"
    codec = compression.DEFAULT_CODEC
    manifest = {"step": step, "codec": codec, "leaves": []}
    for name, leaf in _flatten_with_names(tree):
        arr = np.asarray(leaf)
        key = f"{prefix}/{name.replace('/', '.')}.{codec}"
        store.put(key, compression.compress(arr.tobytes(), codec, level=1))
        manifest["leaves"].append({
            "name": name, "key": key, "dtype": str(arr.dtype),
            "shape": list(arr.shape)})
    mkey = f"{prefix}/MANIFEST"
    store.put(mkey, msgpack.packb(manifest))
    store.put(f"ckpt/{run}/latest", msgpack.packb({"manifest": mkey,
                                                   "step": step}))
    return mkey


def latest_step(store: ObjectStore, run: str) -> int | None:
    key = f"ckpt/{run}/latest"
    if not store.exists(key):
        return None
    return msgpack.unpackb(store.get(key).data)["step"]


def load_checkpoint(store: ObjectStore, run: str, template,
                    step: int | None = None):
    """Restore a pytree matching ``template``'s structure."""
    if step is None:
        step = latest_step(store, run)
        if step is None:
            raise FileNotFoundError(f"no checkpoint for run {run}")
    mkey = f"ckpt/{run}/step{step:08d}/MANIFEST"
    manifest = msgpack.unpackb(store.get(mkey).data)
    codec = manifest.get("codec", "zstd")
    by_name = {}
    for leaf in manifest["leaves"]:
        raw = compression.decompress(store.get(leaf["key"]).data, codec,
                                     max_output_size=1 << 31)
        by_name[leaf["name"]] = np.frombuffer(
            raw, dtype=np.dtype(leaf["dtype"])).reshape(leaf["shape"])
    names = [n for n, _ in _flatten_with_names(template)]
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    assert len(names) == len(flat_t)
    leaves = []
    for name, t in zip(names, flat_t):
        arr = by_name[name]
        assert tuple(arr.shape) == tuple(t.shape), (name, arr.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
