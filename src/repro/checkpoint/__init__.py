from repro.checkpoint.store import (latest_step, load_checkpoint,
                                    save_checkpoint)

__all__ = ["latest_step", "load_checkpoint", "save_checkpoint"]
