"""Skyrise storage I/O stack (paper section 3.4, Fig. 4).

The *input handler* splits large object reads into parallel ranged requests
aligned to the PAX layout so only relevant columns and row groups are
fetched; straggling requests are re-triggered aggressively after a short
timeout. The *output handler* serializes, compresses, and buffers batches
and writes the worker's complete result as a single object.

Both handlers are decoupled from query execution and account simulated
request latencies under a bounded request pool (the analog of the dedicated
I/O thread pool in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.storage import pax
from repro.storage.object_store import ObjectStore


@dataclasses.dataclass
class IoStats:
    requests: int = 0
    retriggers: int = 0
    bytes: int = 0
    sim_time_s: float = 0.0          # makespan under the request pool
    row_groups_read: int = 0
    row_groups_pruned: int = 0

    def merge(self, other: "IoStats") -> None:
        self.requests += other.requests
        self.retriggers += other.retriggers
        self.bytes += other.bytes
        self.sim_time_s += other.sim_time_s
        self.row_groups_read += other.row_groups_read
        self.row_groups_pruned += other.row_groups_pruned


def _pool_makespan(latencies: Sequence[float], pool: int) -> float:
    """LPT lower-bound approximation of running N requests on a pool."""
    if not latencies:
        return 0.0
    return max(max(latencies), sum(latencies) / max(pool, 1))


class InputHandler:
    """Ranged, parallel, straggler-retriggering PAX reader."""

    def __init__(self, store: ObjectStore, *, pool_size: int = 16,
                 straggler_timeout_s: float = 0.2, max_retriggers: int = 2):
        self.store = store
        self.pool_size = pool_size
        self.straggler_timeout_s = straggler_timeout_s
        self.max_retriggers = max_retriggers

    # -- single requests with retriggering ---------------------------------
    def _get(self, key: str, rng: tuple[int, int] | None,
             stats: IoStats) -> bytes:
        """Issue one ranged GET; re-trigger if the (simulated) first-byte
        latency exceeds the timeout. All issued requests are charged; the
        effective latency is the earliest completion (racing duplicates)."""
        res = self.store.get(key, rng)
        stats.requests += 1
        stats.bytes += res.nbytes
        effective = res.sim_latency_s
        deadline = self.straggler_timeout_s
        retriggers = 0
        while effective > deadline and retriggers < self.max_retriggers:
            retry = self.store.get(key, rng)
            stats.requests += 1
            stats.retriggers += 1
            stats.bytes += retry.nbytes
            effective = min(effective, deadline + retry.sim_latency_s)
            deadline += self.straggler_timeout_s
            retriggers += 1
        stats.sim_time_s += 0.0  # per-request latencies combined by caller
        return res.data

    def read_footer(self, key: str, stats: IoStats) -> pax.PaxFooter:
        size = self.store.size(key)
        tail = self._get(key, (size - pax.TAIL_LEN, pax.TAIL_LEN), stats)
        off, length = pax.footer_byte_range(size, tail)
        footer_bytes = self._get(key, (off, length), stats)
        return pax.parse_footer(footer_bytes)

    def read_table(self, key: str, columns: Sequence[str] | None = None,
                   predicates: Sequence[pax.ZonePredicate] = (),
                   ) -> tuple[dict[str, np.ndarray], pax.PaxFooter, IoStats]:
        """Read (a projection of) one PAX object with zone-map pruning.

        Returns concatenated column arrays for surviving row groups only.
        """
        stats = IoStats()
        footer = self.read_footer(key, stats)
        names = list(columns) if columns is not None else [
            c.name for c in footer.columns]
        keep = pax.surviving_row_groups(footer, predicates)
        stats.row_groups_read = len(keep)
        stats.row_groups_pruned = len(footer.row_groups) - len(keep)

        # Plan one ranged request per (row group, column) chunk; draw their
        # latencies; combine under the pool to a makespan.
        latencies: list[float] = []
        parts: dict[str, list[np.ndarray]] = {n: [] for n in names}
        for gi in keep:
            rg = footer.row_groups[gi]
            for n in names:
                meta = rg.chunks[n]
                before = stats.sim_time_s
                # track each request's effective latency explicitly
                res = self.store.get(key, (meta.off, meta.length))
                stats.requests += 1
                stats.bytes += res.nbytes
                eff = res.sim_latency_s
                deadline = self.straggler_timeout_s
                retriggers = 0
                while eff > deadline and retriggers < self.max_retriggers:
                    retry = self.store.get(key, (meta.off, meta.length))
                    stats.requests += 1
                    stats.retriggers += 1
                    stats.bytes += retry.nbytes
                    eff = min(eff, deadline + retry.sim_latency_s)
                    deadline += self.straggler_timeout_s
                    retriggers += 1
                latencies.append(eff)
                del before
                spec = footer.spec(n)
                parts[n].append(
                    pax.decompress_chunk(spec, meta.raw_len, res.data,
                                         footer.codec))
        stats.sim_time_s += _pool_makespan(latencies, self.pool_size)

        out = {}
        for n in names:
            spec = footer.spec(n)
            if parts[n]:
                out[n] = np.concatenate(parts[n])
            else:
                out[n] = np.empty((0,), dtype=spec.np_dtype())
        return out, footer, stats


class OutputHandler:
    """Buffers result batches, serializes once, writes a single object."""

    def __init__(self, store: ObjectStore,
                 row_group_rows: int = 65536) -> None:
        self.store = store
        self.row_group_rows = row_group_rows
        self._batches: list[dict[str, np.ndarray]] = []

    def append(self, batch: dict[str, np.ndarray]) -> None:
        self._batches.append(batch)

    def finish(self, key: str,
               schema: Sequence[pax.ColumnSpec]) -> IoStats:
        stats = IoStats()
        if self._batches:
            columns = {
                c.name: np.concatenate([b[c.name] for b in self._batches])
                for c in schema}
        else:
            columns = {c.name: np.empty((0,), dtype=c.np_dtype())
                       for c in schema}
        data = pax.write_pax(columns, schema, self.row_group_rows)
        res = self.store.put(key, data)
        stats.requests += 1
        stats.bytes += res.nbytes
        stats.sim_time_s += res.sim_latency_s
        self._batches.clear()
        return stats
