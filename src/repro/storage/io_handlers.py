"""Skyrise storage I/O stack (paper section 3.4, Fig. 4).

The *input handler* splits large object reads into parallel ranged requests
aligned to the PAX layout so only relevant columns and row groups are
fetched; straggling requests are re-triggered aggressively after a short
timeout. Two request-economy optimizations sit on top (per the Lambada
observation that per-request overheads dominate serverless storage):

  * adjacent/near-adjacent column-chunk ranges of one read are *coalesced*
    into single ranged GETs (bounded byte waste buys a large request-count
    reduction), and
  * SPAX footers are served from a shared :class:`FooterCache` keyed by
    ``(object key, etag)``, so F fragments scanning G partitions parse each
    footer exactly once per object version.

The *output handler* serializes, compresses, and buffers batches and writes
the worker's complete result as a single object.

Both handlers are decoupled from query execution and account simulated
request latencies under a bounded request pool (the analog of the dedicated
I/O thread pool in the paper): a read's simulated time is the pool makespan
over *all* requests it issued — footer fetches, data fetches, and straggler
re-triggers alike.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Sequence

import numpy as np

from repro.storage import pax
from repro.storage.object_store import ObjectStore

COALESCE_GAP_BYTES = 32 << 10


@dataclasses.dataclass
class IoStats:
    requests: int = 0
    retriggers: int = 0
    bytes: int = 0
    sim_time_s: float = 0.0          # makespan under the request pool
    row_groups_read: int = 0
    row_groups_pruned: int = 0
    footer_hits: int = 0             # footer served from the shared cache
    coalesced_chunks: int = 0        # chunk fetches merged into ranged GETs
    hedges: int = 0                  # cost-model-priced duplicate GETs

    def merge(self, other: "IoStats") -> None:
        self.requests += other.requests
        self.retriggers += other.retriggers
        self.bytes += other.bytes
        self.sim_time_s += other.sim_time_s
        self.row_groups_read += other.row_groups_read
        self.row_groups_pruned += other.row_groups_pruned
        self.footer_hits += other.footer_hits
        self.coalesced_chunks += other.coalesced_chunks
        self.hedges += other.hedges


@dataclasses.dataclass
class _LatencyLog:
    """Per-read request latencies, combined into one pool makespan.

    ``effective`` holds one entry per *logical* fetch (re-triggered
    duplicates race the original; the earliest completion wins), while
    ``busy`` holds one entry per *issued* request — a duplicate cannot be
    cancelled, so its full latency occupies a pool slot either way.
    """

    effective: list[float] = dataclasses.field(default_factory=list)
    busy: list[float] = dataclasses.field(default_factory=list)


def _pool_makespan(lat: _LatencyLog, pool: int) -> float:
    """LPT lower-bound approximation of running the read on the pool."""
    if not lat.effective:
        return 0.0
    return max(max(lat.effective), sum(lat.busy) / max(pool, 1))


class FooterCache:
    """Shared (session-scoped) SPAX footer cache keyed by key + etag.

    Thread-safe: worker fragments on the platform's thread pool consult
    one instance. A changed etag (object overwritten) misses and the
    stale entry is replaced; capacity is bounded FIFO.
    """

    def __init__(self, max_entries: int = 8192) -> None:
        self.max_entries = max_entries
        self._entries: dict[str, tuple[str, pax.PaxFooter]] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str, etag: str) -> pax.PaxFooter | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and entry[0] == etag:
                self.hits += 1
                return entry[1]
            self.misses += 1
            return None

    def put(self, key: str, etag: str, footer: pax.PaxFooter) -> None:
        with self._lock:
            while len(self._entries) >= self.max_entries:
                self._entries.pop(next(iter(self._entries)))
            self._entries[key] = (etag, footer)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class InputHandler:
    """Ranged, parallel, straggler-retriggering PAX reader."""

    def __init__(self, store: ObjectStore, *, pool_size: int = 16,
                 straggler_timeout_s: float = 0.2, max_retriggers: int = 2,
                 footer_cache: FooterCache | None = None,
                 coalesce_gap: int = COALESCE_GAP_BYTES,
                 cost_model=None):
        # coalesce_gap: max wasted bytes between chunks sharing one GET;
        # 0 merges only strictly adjacent chunks, negative disables
        # coalescing (one GET per chunk)
        self.store = store
        self.pool_size = pool_size
        self.straggler_timeout_s = straggler_timeout_s
        self.max_retriggers = max_retriggers
        self.footer_cache = footer_cache if footer_cache is not None \
            else FooterCache()
        self.coalesce_gap = coalesce_gap
        # hedged reads: with a cost model attached, the re-trigger
        # timeout is not a constant but the tier's break-even point —
        # hedge exactly when waiting longer costs more GiB-seconds than
        # the duplicate request costs in read-request cents
        self.hedged = cost_model is not None
        if self.hedged:
            self.straggler_timeout_s = cost_model.hedge_timeout_s(store.tier)

    # -- single requests with retriggering ---------------------------------
    def _get(self, key: str, rng: tuple[int, int] | None, stats: IoStats,
             lat: _LatencyLog) -> bytes:
        """Issue one ranged GET; re-trigger if the (simulated) first-byte
        latency exceeds the timeout. All issued requests are charged and
        occupy the request pool; the fetch's effective latency is the
        earliest completion (racing duplicates)."""
        res = self.store.get(key, rng)
        stats.requests += 1
        stats.bytes += res.nbytes
        lat.busy.append(res.sim_latency_s)
        effective = res.sim_latency_s
        deadline = self.straggler_timeout_s
        retriggers = 0
        while effective > deadline and retriggers < self.max_retriggers:
            try:
                retry = self.store.get(key, rng)
            except Exception:
                # a failed duplicate never hurts: the original request
                # already returned the bytes — stop hedging this fetch
                break
            stats.requests += 1
            stats.retriggers += 1
            if self.hedged:
                stats.hedges += 1
            stats.bytes += retry.nbytes
            lat.busy.append(retry.sim_latency_s)
            effective = min(effective, deadline + retry.sim_latency_s)
            deadline += self.straggler_timeout_s
            retriggers += 1
        lat.effective.append(effective)
        return res.data

    def read_footer(self, key: str, stats: IoStats,
                    lat: _LatencyLog | None = None) -> pax.PaxFooter:
        """Fetch-or-recall the footer. A cache hit issues zero requests —
        the metadata of a partition is parsed once per object version no
        matter how many fragments scan it."""
        lat = lat if lat is not None else _LatencyLog()
        etag = self.store.etag(key)
        footer = self.footer_cache.get(key, etag)
        if footer is not None:
            stats.footer_hits += 1
            return footer
        size = self.store.size(key)
        tail = self._get(key, (size - pax.TAIL_LEN, pax.TAIL_LEN), stats,
                         lat)
        off, length = pax.footer_byte_range(size, tail)
        footer_bytes = self._get(key, (off, length), stats, lat)
        footer = pax.parse_footer(footer_bytes)
        self.footer_cache.put(key, etag, footer)
        return footer

    def read_table(self, key: str, columns: Sequence[str] | None = None,
                   predicates: Sequence[pax.ZonePredicate] = (),
                   ) -> tuple[dict[str, np.ndarray], pax.PaxFooter, IoStats]:
        """Read (a projection of) one PAX object with zone-map pruning.

        Returns concatenated column arrays for surviving row groups only.
        Chunk fetches are planned from the (cached) footer, merged into
        coalesced ranged GETs, and their latencies — footer and
        re-triggered duplicates included — combine into one pool
        makespan.
        """
        stats = IoStats()
        lat = _LatencyLog()
        cols, footer = self._read_object(key, columns, predicates, stats,
                                         lat)
        stats.sim_time_s += _pool_makespan(lat, self.pool_size)
        return cols, footer, stats

    def read_tables(self, keys: Sequence[str],
                    columns: Sequence[str] | None = None,
                    predicates: Sequence[pax.ZonePredicate] = (),
                    ) -> tuple[list[dict[str, np.ndarray]], IoStats]:
        """Read many PAX objects as *one parallel batch*.

        A worker scanning several scan units — or the full producer ×
        partition grid of an exchange — issues the requests of all
        objects through its one bounded request pool, so the batch's
        simulated time is a single pool makespan over every request
        rather than a sum of per-object reads. This is what keeps a
        deliberately small (cost-optimal) adaptive fleet from paying
        object-count × first-byte-latency serially.
        """
        stats = IoStats()
        lat = _LatencyLog()
        out = [self._read_object(k, columns, predicates, stats, lat)[0]
               for k in keys]
        stats.sim_time_s += _pool_makespan(lat, self.pool_size)
        return out, stats

    def prefetch_tables(self, keys: Sequence[str],
                        columns: Sequence[str] | None = None,
                        predicates: Sequence[pax.ZonePredicate] = (),
                        ) -> "Prefetch":
        """Start a ``read_tables`` batch on a background thread and
        return immediately — the double-buffering half of pipelined
        consumption: a fragment collects the *previous* batch's arrays
        (and feeds its kernel) while the next top-up batch is in flight.
        The wall-clock overlap is real (two host threads); the simulated
        overlap is accounted by the worker's overlap term, not here —
        the returned ``IoStats`` still carries the batch's full pool
        makespan."""
        return Prefetch(self, keys, columns, predicates)

    def _read_object(self, key: str, columns, predicates, stats: IoStats,
                     lat: _LatencyLog,
                     ) -> tuple[dict[str, np.ndarray], pax.PaxFooter]:
        """One object's footer + chunk reads, accounted into a shared
        latency log (the caller turns the log into a pool makespan)."""
        footer = self.read_footer(key, stats, lat)
        names = list(columns) if columns is not None else [
            c.name for c in footer.columns]
        if footer.n_rows == 0:
            # the footer alone proves the partition is empty: skip every
            # chunk request
            return ({n: np.empty((0,), dtype=footer.spec(n).np_dtype())
                     for n in names}, footer)
        keep = pax.surviving_row_groups(footer, predicates)
        stats.row_groups_read += len(keep)
        stats.row_groups_pruned += len(footer.row_groups) - len(keep)

        reqs = pax.plan_chunk_requests(footer, names, keep)
        chunks: dict[tuple[int, str], np.ndarray] = {}
        for off, length, members in pax.coalesce_ranges(reqs,
                                                        self.coalesce_gap):
            data = self._get(key, (off, length), stats, lat)
            stats.coalesced_chunks += len(members) - 1
            for m in members:
                spec = footer.spec(m.column)
                meta = footer.row_groups[m.group].chunks[m.column]
                chunks[(m.group, m.column)] = pax.decompress_chunk(
                    spec, meta.raw_len,
                    data[m.off - off:m.off - off + m.length],
                    footer.codec)

        out = {}
        for n in names:
            spec = footer.spec(n)
            parts = [chunks[(gi, n)] for gi in keep]
            if parts:
                out[n] = np.concatenate(parts)
            else:
                out[n] = np.empty((0,), dtype=spec.np_dtype())
        return out, footer


class Prefetch:
    """In-flight background ``read_tables`` batch (see
    ``InputHandler.prefetch_tables``). ``result()`` joins the reader
    thread and returns ``(tables, IoStats)``, re-raising any reader
    failure in the caller's thread."""

    def __init__(self, handler: InputHandler, keys, columns, predicates):
        self._box: list = []
        self._keys = list(keys)

        def _run() -> None:
            try:
                self._box.append(handler.read_tables(
                    self._keys, columns, predicates))
            except BaseException as e:  # noqa: BLE001 - re-raised in result
                self._box.append(e)

        self._thread = threading.Thread(target=_run, daemon=True,
                                        name="prefetch-reader")
        self._thread.start()

    @property
    def keys(self) -> list[str]:
        return self._keys

    def result(self) -> tuple[list[dict[str, np.ndarray]], IoStats]:
        self._thread.join()
        out = self._box[0]
        if isinstance(out, BaseException):
            raise out
        return out


class OutputHandler:
    """Buffers result batches, serializes once, writes a single object."""

    def __init__(self, store: ObjectStore,
                 row_group_rows: int = 65536) -> None:
        self.store = store
        self.row_group_rows = row_group_rows
        self._batches: list[dict[str, np.ndarray]] = []

    def append(self, batch: dict[str, np.ndarray]) -> None:
        self._batches.append(batch)

    def finish(self, key: str, schema: Sequence[pax.ColumnSpec],
               splits: Sequence[int] | None = None) -> IoStats:
        """Write the buffered batches as one object. ``splits`` forces
        row-group boundaries at the given row indices (exchange writers
        align groups to partition boundaries for exact zone pruning)."""
        stats = IoStats()
        if self._batches:
            columns = {
                c.name: np.concatenate([b[c.name] for b in self._batches])
                for c in schema}
        else:
            columns = {c.name: np.empty((0,), dtype=c.np_dtype())
                       for c in schema}
        data = pax.write_pax(columns, schema, self.row_group_rows,
                             splits=splits)
        # torn-write protection: a producer killed mid-PUT must never
        # leave a readable partial object at the final key
        res = self.store.put_committed(key, data)
        stats.requests += 1
        stats.bytes += res.nbytes
        stats.sim_time_s += res.sim_latency_s
        self._batches.clear()
        return stats
