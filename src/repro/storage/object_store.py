"""Serverless object store abstraction (Amazon S3 analog).

Immutable binary objects addressed by string keys, with ranged reads,
per-request simulated latency draws (tier models from ``tiers.py``), and
cost accounting. Backends: in-memory (tests, single process) and local
filesystem (shared across processes).

Workers in Skyrise communicate *only* through this store; object writes are
atomic and last-writer-wins, which together with deterministic worker outputs
makes re-triggering and racing duplicate workers safe (paper section 3.3).
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
import time
import uuid
from typing import Callable, Iterable

import numpy as np

from repro.storage.tiers import TIERS, StorageTier


def _transient(msg: str) -> Exception:
    # Lazy import: the storage layer must not trigger the repro.core
    # package init at module-import time (core → sql → data → storage
    # would cycle). sys.modules caching makes the per-call cost a dict
    # lookup; fault paths are rare anyway.
    from repro.core.retry import TransientInfraError
    return TransientInfraError(msg)

# Watch (version-polling) backoff: polling a key's version is a HEAD
# analog — free — but each poll is a syscall/lock acquisition, so waiters
# back off exponentially between polls. The cap doubles as the
# cancel-check interval, so a cancelled waiter never sleeps longer.
WATCH_BACKOFF_INITIAL_S = 0.002
WATCH_BACKOFF_MAX_S = 0.05


@dataclasses.dataclass
class RequestResult:
    """Outcome of a single storage request (one HTTP round trip analog)."""

    data: bytes | None
    sim_latency_s: float
    cost_cents: float
    nbytes: int


@dataclasses.dataclass
class StoreStats:
    get_requests: int = 0
    put_requests: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    cost_cents: float = 0.0
    sim_latency_s: float = 0.0

    def merge(self, other: "StoreStats") -> None:
        self.get_requests += other.get_requests
        self.put_requests += other.put_requests
        self.bytes_read += other.bytes_read
        self.bytes_written += other.bytes_written
        self.cost_cents += other.cost_cents
        self.sim_latency_s += other.sim_latency_s


class Backend:
    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str, rng: tuple[int, int] | None) -> bytes:
        raise NotImplementedError

    def size(self, key: str) -> int:
        raise NotImplementedError

    def etag(self, key: str) -> str:
        """Opaque version token; changes whenever the object's bytes may
        have changed (the S3 ETag analog). Used by metadata caches."""
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        raise NotImplementedError

    def list(self, prefix: str) -> list[str]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def rename(self, src: str, dst: str) -> None:
        """Move ``src`` to ``dst`` (atomic where the backend allows it) —
        the commit step of torn-write-protected puts."""
        data = self.get(src, None)
        self.put(dst, data)
        self.delete(src)

    def put_if_version(self, key: str, data: bytes,
                       expected: str | None) -> bool:
        """Conditional put: lands only if the key's current version
        equals ``expected`` (None = key absent). Returns True iff the
        write landed. Base implementation is check-then-put — atomic
        enough for single-process backends only; backends with an
        in-process lock override with a real compare-and-swap."""
        if self.version(key) != expected:
            return False
        self.put(key, data)
        return True

    # -- watch/notify seam -------------------------------------------------
    def version(self, key: str) -> str | None:
        """The key's version token, or None while the key is absent —
        unlike ``etag``, never raises; absence is a observable state a
        watcher can wait on (claim deleted, entry not yet written)."""
        try:
            return self.etag(key)
        except (KeyError, FileNotFoundError, OSError):
            return None

    def watch(self, key: str, token: str | None, deadline: float,
              cancel_check: Callable[[], None] | None = None) -> str | None:
        """Block until ``key``'s version differs from ``token`` or the
        monotonic ``deadline`` passes; returns the current version.

        Base implementation: version polling with exponential backoff
        (shared-filesystem stores have no notification channel).
        Backends with an in-process write path override this with a
        notify-on-put wait. ``cancel_check`` is polled between sleeps
        and may raise to abort the wait.
        """
        delay = WATCH_BACKOFF_INITIAL_S
        while True:
            cur = self.version(key)
            if cur != token:
                return cur
            now = time.monotonic()
            if now >= deadline:
                return cur
            if cancel_check is not None:
                cancel_check()
            time.sleep(min(delay, deadline - now))
            delay = min(delay * 2, WATCH_BACKOFF_MAX_S)


class MemoryBackend(Backend):
    """Dict-backed store; thread-safe; shared within one process."""

    def __init__(self) -> None:
        self._objects: dict[str, bytes] = {}
        self._versions: dict[str, int] = {}
        self._lock = threading.Lock()
        # watch/notify: every put/delete wakes watchers instantly, so
        # in-process waiters never pay the polling backoff
        self._watch_cv = threading.Condition(self._lock)

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._objects[key] = bytes(data)
            self._versions[key] = self._versions.get(key, 0) + 1
            self._watch_cv.notify_all()

    def get(self, key: str, rng: tuple[int, int] | None) -> bytes:
        with self._lock:
            obj = self._objects[key]
        if rng is None:
            return obj
        off, length = rng
        return obj[off:off + length]

    def size(self, key: str) -> int:
        with self._lock:
            return len(self._objects[key])

    def etag(self, key: str) -> str:
        with self._lock:
            if key not in self._objects:
                raise KeyError(key)
            return f"v{self._versions[key]}-{len(self._objects[key])}"

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._objects

    def list(self, prefix: str) -> list[str]:
        with self._lock:
            return sorted(k for k in self._objects if k.startswith(prefix))

    def delete(self, key: str) -> None:
        with self._lock:
            self._objects.pop(key, None)
            self._watch_cv.notify_all()

    def rename(self, src: str, dst: str) -> None:
        with self._lock:
            data = self._objects.pop(src)
            self._versions.pop(src, None)
            self._objects[dst] = data
            self._versions[dst] = self._versions.get(dst, 0) + 1
            self._watch_cv.notify_all()

    def put_if_version(self, key: str, data: bytes,
                       expected: str | None) -> bool:
        # real CAS: version check and write under one lock acquisition
        with self._lock:
            cur = (f"v{self._versions[key]}-{len(self._objects[key])}"
                   if key in self._objects else None)
            if cur != expected:
                return False
            self._objects[key] = bytes(data)
            self._versions[key] = self._versions.get(key, 0) + 1
            self._watch_cv.notify_all()
            return True

    def watch(self, key: str, token: str | None, deadline: float,
              cancel_check: Callable[[], None] | None = None) -> str | None:
        with self._watch_cv:
            while True:
                cur = (f"v{self._versions[key]}-{len(self._objects[key])}"
                       if key in self._objects else None)
                if cur != token:
                    return cur
                now = time.monotonic()
                if now >= deadline:
                    return cur
                if cancel_check is not None:
                    cancel_check()
                # bounded wait: cancel_check stays responsive even if no
                # writer ever notifies
                self._watch_cv.wait(
                    timeout=min(WATCH_BACKOFF_MAX_S, deadline - now))


class FilesystemBackend(Backend):
    """Local-FS store; keys map to paths; atomic renames emulate S3 puts."""

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        path = os.path.join(self.root, key)
        if os.path.commonpath([os.path.abspath(path), self.root]) != \
                os.path.abspath(self.root):
            raise ValueError(f"key escapes store root: {key}")
        return path

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)  # atomic, last-writer-wins
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def get(self, key: str, rng: tuple[int, int] | None) -> bytes:
        with open(self._path(key), "rb") as f:
            if rng is None:
                return f.read()
            off, length = rng
            f.seek(off)
            return f.read(length)

    def size(self, key: str) -> int:
        return os.path.getsize(self._path(key))

    def etag(self, key: str) -> str:
        # the inode distinguishes rapid same-size overwrites that land
        # within one mtime tick: every put() replaces via a fresh temp
        # file, so the inode changes even when mtime_ns + size do not
        st = os.stat(self._path(key))
        return f"{st.st_ino}-{st.st_mtime_ns}-{st.st_size}"

    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def list(self, prefix: str) -> list[str]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            for name in files:
                rel = os.path.relpath(os.path.join(dirpath, name), self.root)
                rel = rel.replace(os.sep, "/")
                if rel.startswith(prefix):
                    out.append(rel)
        return sorted(out)

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass

    def rename(self, src: str, dst: str) -> None:
        dpath = self._path(dst)
        os.makedirs(os.path.dirname(dpath), exist_ok=True)
        os.replace(self._path(src), dpath)  # atomic on one filesystem


class ObjectStore:
    """A keyed object store with a tier latency/cost model attached.

    Multiple ObjectStore views (different tiers) may share one backend —
    Skyrise tiers shuffle data to hotter storage while table data stays on
    the standard tier (paper sections 3.2, 5.1).
    """

    def __init__(self, backend: Backend | None = None,
                 tier: str | StorageTier = "s3-standard",
                 seed: int = 0) -> None:
        self.backend = backend if backend is not None else MemoryBackend()
        self.tier = TIERS[tier] if isinstance(tier, str) else tier
        self._rng = np.random.default_rng(seed)
        self._rng_lock = threading.Lock()
        self.stats = StoreStats()
        self._stats_lock = threading.Lock()
        self.chaos = None  # optional ChaosEngine injecting storage faults

    # -- tier views --------------------------------------------------------
    def with_tier(self, tier: str | StorageTier) -> "ObjectStore":
        view = ObjectStore.__new__(ObjectStore)
        view.backend = self.backend
        view.tier = TIERS[tier] if isinstance(tier, str) else tier
        view._rng = self._rng
        view._rng_lock = self._rng_lock
        view.stats = self.stats        # shared accounting
        view._stats_lock = self._stats_lock
        view.chaos = self.chaos        # shared fault schedule
        return view

    # -- chaos -------------------------------------------------------------
    def _chaos(self):
        """The attached chaos engine, or None. The KV tier is exempt
        from random storage faults (conditional writes are atomic in the
        modeled backend); its failure modes are the explicit protocol
        kill points instead."""
        ch = self.chaos
        if ch is None or self.tier.name == "dynamodb":
            return None
        return ch

    # -- accounting --------------------------------------------------------
    def _account(self, *, write: bool, nbytes: int,
                 scale: float = 1.0) -> tuple[float, float]:
        with self._rng_lock:
            latency = self.tier.draw_latency_s(self._rng, write=write,
                                               nbytes=nbytes) * scale
        cost = self.tier.request_cost_cents(write=write, nbytes=nbytes)
        with self._stats_lock:
            if write:
                self.stats.put_requests += 1
                self.stats.bytes_written += nbytes
            else:
                self.stats.get_requests += 1
                self.stats.bytes_read += nbytes
            self.stats.cost_cents += cost
            self.stats.sim_latency_s += latency
        return latency, cost

    # -- object API --------------------------------------------------------
    def put(self, key: str, data: bytes) -> RequestResult:
        ch = self._chaos()
        scale = 1.0
        if ch is not None:
            fault = ch.storage_fault("put", key)
            if fault == "transient":
                raise _transient(
                    f"chaos: transient PUT failure for {key}")
            if fault == "throttle":
                # 503 SlowDown: the round trip happened and its latency
                # is billed, but no bytes landed
                with self._stats_lock:
                    self.stats.sim_latency_s += ch.config.throttle_latency_s
                raise _transient(f"chaos: 503 SlowDown on PUT {key}")
            if fault == "torn":
                # sandbox died mid-PUT: a strict prefix of the bytes
                # lands under the key, and nobody cleans it up
                self.backend.put(key, bytes(data)[:max(1, len(data) // 2)])
                raise _transient(
                    f"chaos: sandbox died mid-PUT of {key} (torn object)")
            scale = ch.latency_scale("put")
        self.backend.put(key, data)
        latency, cost = self._account(write=True, nbytes=len(data),
                                      scale=scale)
        return RequestResult(None, latency, cost, len(data))

    def put_committed(self, key: str, data: bytes) -> RequestResult:
        """Torn-write-protected put: write to a temp key, validate that
        every byte landed (etag/size check), then commit with an atomic
        rename. A producer killed mid-PUT leaves only an orphaned temp
        object under ``_tmp/`` — a readable partial object never appears
        at the final key. Billed as the data PUT; the commit rename is a
        metadata operation (S3 COPY analog on the same backend, not a
        second data round trip)."""
        data = bytes(data)
        tmp = f"_tmp/{uuid.uuid4().hex}"
        res = self.put(tmp, data)  # chaos may fail or tear THIS write
        # etag-validated commit: confirm the temp object is whole before
        # it becomes visible under the final key
        if self.backend.version(tmp) is None \
                or self.backend.size(tmp) != len(data):
            self.backend.delete(tmp)
            raise _transient(
                f"chaos: torn temp object detected before commit of {key}")
        ch = self._chaos()
        if ch is not None:
            # optional kill point: death after upload, before commit —
            # the final key must stay absent
            ch.kill_once("storage.commit")
        self.backend.rename(tmp, key)
        return RequestResult(None, res.sim_latency_s, res.cost_cents,
                             len(data))

    def put_if_version(self, key: str, data: bytes,
                       expected: str | None) -> bool:
        """Conditional put (DynamoDB conditional-write analog): lands
        only if the key's current version equals ``expected`` (None =
        absent). Returns True iff the write landed; billed as one PUT
        either way (the request happens, condition or not)."""
        data = bytes(data)
        ok = self.backend.put_if_version(key, data, expected)
        self._account(write=True, nbytes=len(data))
        return ok

    def get(self, key: str,
            rng: tuple[int, int] | None = None) -> RequestResult:
        ch = self._chaos()
        scale = 1.0
        if ch is not None:
            fault = ch.storage_fault("get", key)
            if fault == "transient":
                raise _transient(
                    f"chaos: transient GET failure for {key}")
            if fault == "throttle":
                with self._stats_lock:
                    self.stats.sim_latency_s += ch.config.throttle_latency_s
                raise _transient(f"chaos: 503 SlowDown on GET {key}")
            scale = ch.latency_scale("get")
        data = self.backend.get(key, rng)
        latency, cost = self._account(write=False, nbytes=len(data),
                                      scale=scale)
        return RequestResult(data, latency, cost, len(data))

    def size(self, key: str) -> int:
        return self.backend.size(key)

    def etag(self, key: str) -> str:
        """Version token for ``key`` (HEAD analog; not a billed request)."""
        return self.backend.etag(key)

    def version(self, key: str) -> str | None:
        """Like ``etag`` but None for an absent key (never raises)."""
        return self.backend.version(key)

    def watch(self, key: str, token: str | None = None, *,
              timeout_s: float | None = None,
              cancel_check: Callable[[], None] | None = None) -> str | None:
        """Block until ``key``'s version differs from ``token``.

        The store-level notification primitive (DynamoDB-streams / etcd
        watch analog): waiters observe a version token with ``version``,
        then ``watch`` until a writer changes (or deletes/creates) the
        key. Returns the current version — equal to ``token`` iff the
        wait timed out. Memory backends wake watchers on every put and
        delete; filesystem backends fall back to version polling with
        exponential backoff. Version reads are HEAD analogs: no billed
        KV requests are issued while waiting.
        """
        deadline = time.monotonic() + (3600.0 if timeout_s is None
                                       else max(timeout_s, 0.0))
        return self.backend.watch(key, token, deadline, cancel_check)

    def exists(self, key: str) -> bool:
        return self.backend.exists(key)

    def list(self, prefix: str = "") -> list[str]:
        return self.backend.list(prefix)

    def delete(self, key: str) -> None:
        self.backend.delete(key)

    def delete_prefix(self, prefix: str) -> None:
        for key in self.list(prefix):
            self.backend.delete(key)

    def total_bytes(self, keys: Iterable[str]) -> int:
        return sum(self.size(k) for k in keys)
