"""Storage management layer: object store, tiers, PAX format, I/O handlers."""

from repro.storage.io_handlers import InputHandler, IoStats, OutputHandler
from repro.storage.object_store import (FilesystemBackend, MemoryBackend,
                                        ObjectStore, StoreStats)
from repro.storage.pax import (ColumnSpec, PaxFooter, ZonePredicate,
                               parse_footer, surviving_row_groups, write_pax)
from repro.storage.tiers import TIERS, StorageTier

__all__ = [
    "ColumnSpec", "FilesystemBackend", "InputHandler", "IoStats",
    "MemoryBackend", "ObjectStore", "OutputHandler", "PaxFooter",
    "StorageTier", "StoreStats", "TIERS", "ZonePredicate", "parse_footer",
    "surviving_row_groups", "write_pax",
]
