"""Storage management layer: object store, tiers, PAX format, I/O handlers."""

from repro.storage.io_handlers import (FooterCache, InputHandler, IoStats,
                                       OutputHandler)
from repro.storage.object_store import (FilesystemBackend, MemoryBackend,
                                        ObjectStore, StoreStats)
from repro.storage.pax import (ColumnSpec, PaxFooter, ZonePredicate,
                               coalesce_ranges, parse_footer,
                               plan_chunk_requests, surviving_row_groups,
                               write_pax)
from repro.storage.tiers import TIERS, StorageTier

__all__ = [
    "ColumnSpec", "FilesystemBackend", "FooterCache", "InputHandler",
    "IoStats", "MemoryBackend", "ObjectStore", "OutputHandler", "PaxFooter",
    "StorageTier", "StoreStats", "TIERS", "ZonePredicate",
    "coalesce_ranges", "parse_footer", "plan_chunk_requests",
    "surviving_row_groups", "write_pax",
]
