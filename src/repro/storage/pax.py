"""PAX-style columnar file format ("SPAX") for object storage.

Mirrors the Parquet/ORC layout the paper's storage stack targets (section
3.4): a file holds row groups; each row group holds one compressed chunk per
column; a footer indexes chunk byte ranges and per-chunk min/max zone maps so
readers fetch *only relevant columns and rows* via ranged requests.

Column kinds:
  * ``num``   — fixed-width numeric (int32/int64/float32/float64); dates are
                int32 days since 1970-01-01.
  * ``dict``  — low-cardinality strings stored as int32 codes against a
                dictionary recorded in the footer. Dictionaries are assigned
                globally by the data generator/catalog so codes are
                consistent across partition files.
  * ``bytes`` — fixed-width opaque bytes (high-cardinality strings); stored
                and round-tripped but not computable inside XLA programs.
"""

from __future__ import annotations

import dataclasses
import io
from typing import Callable, Sequence

import msgpack
import numpy as np

from repro.storage import compression

MAGIC = b"SPAX1\x00"
TAIL_LEN = 4 + len(MAGIC)  # u32 footer length + magic

_COMPRESS_LEVEL = 3


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    name: str
    kind: str                    # num | dict | bytes
    dtype: str                   # numpy dtype string, e.g. "<i4", "S10"
    dictionary: tuple[str, ...] | None = None

    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)


@dataclasses.dataclass
class ChunkMeta:
    off: int
    length: int
    raw_len: int
    vmin: float | int | None
    vmax: float | int | None


@dataclasses.dataclass
class RowGroupMeta:
    n_rows: int
    chunks: dict[str, ChunkMeta]


@dataclasses.dataclass
class PaxFooter:
    n_rows: int
    columns: list[ColumnSpec]
    row_groups: list[RowGroupMeta]
    codec: str = "zstd"

    def spec(self, name: str) -> ColumnSpec:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)


def _stats(spec: ColumnSpec, arr: np.ndarray):
    if spec.kind in ("num", "dict") and arr.size:
        return arr.min().item(), arr.max().item()
    return None, None


def _group_bounds(n_rows: int, row_group_rows: int,
                  splits: Sequence[int] | None) -> list[tuple[int, int]]:
    """(start, stop) row-group bounds: fixed-size groups by default;
    ``splits`` forces group boundaries at the given row indices (an
    exchange writer splits at partition boundaries so zone maps on the
    destination column prune exactly), with oversized segments still
    chunked to ``row_group_rows``."""
    if not splits:
        return [(s, min(s + row_group_rows, n_rows))
                for s in range(0, max(n_rows, 1), row_group_rows)]
    edges = sorted({0, n_rows, *(s for s in splits if 0 < s < n_rows)})
    out = []
    for lo, hi in zip(edges, edges[1:]):
        for s in range(lo, hi, row_group_rows):
            out.append((s, min(s + row_group_rows, hi)))
    return out or [(0, 0)]


def write_pax(columns: dict[str, np.ndarray],
              schema: Sequence[ColumnSpec],
              row_group_rows: int = 65536,
              codec: str | None = None,
              splits: Sequence[int] | None = None) -> bytes:
    """Serialize columns (all equal length) to SPAX bytes.

    ``codec`` defaults to zstd when available, else zlib; the choice is
    recorded in the footer so readers dispatch per file. ``splits``
    forces row-group boundaries at the given row indices.
    """
    codec = codec or compression.DEFAULT_CODEC
    names = [c.name for c in schema]
    assert set(names) == set(columns), (names, list(columns))
    n_rows = len(columns[names[0]]) if names else 0
    for c in schema:
        arr = columns[c.name]
        assert len(arr) == n_rows, (c.name, len(arr), n_rows)
        assert arr.dtype == c.np_dtype(), (c.name, arr.dtype, c.dtype)

    buf = io.BytesIO()
    buf.write(MAGIC)
    row_groups: list[RowGroupMeta] = []
    for start, stop in _group_bounds(n_rows, row_group_rows, splits):
        if stop <= start and row_groups:
            break
        chunks: dict[str, ChunkMeta] = {}
        for c in schema:
            arr = np.ascontiguousarray(columns[c.name][start:stop])
            raw = arr.tobytes()
            comp = compression.compress(raw, codec, level=_COMPRESS_LEVEL)
            off = buf.tell()
            buf.write(comp)
            vmin, vmax = _stats(c, arr)
            chunks[c.name] = ChunkMeta(off, len(comp), len(raw), vmin, vmax)
        row_groups.append(RowGroupMeta(stop - start, chunks))
        if stop >= n_rows:
            break

    footer = {
        "version": 1,
        "codec": codec,
        "n_rows": n_rows,
        "columns": [
            {"name": c.name, "kind": c.kind, "dtype": c.dtype,
             "dict": list(c.dictionary) if c.dictionary else None}
            for c in schema
        ],
        "row_groups": [
            {"n_rows": rg.n_rows,
             "chunks": {
                 n: {"off": m.off, "len": m.length, "raw_len": m.raw_len,
                     "min": m.vmin, "max": m.vmax}
                 for n, m in rg.chunks.items()}}
            for rg in row_groups
        ],
    }
    footer_bytes = msgpack.packb(footer)
    buf.write(footer_bytes)
    buf.write(np.uint32(len(footer_bytes)).tobytes())
    buf.write(MAGIC)
    return buf.getvalue()


def parse_footer(footer_bytes: bytes) -> PaxFooter:
    raw = msgpack.unpackb(footer_bytes)
    columns = [
        ColumnSpec(c["name"], c["kind"], c["dtype"],
                   tuple(c["dict"]) if c["dict"] else None)
        for c in raw["columns"]
    ]
    row_groups = [
        RowGroupMeta(
            rg["n_rows"],
            {n: ChunkMeta(m["off"], m["len"], m["raw_len"], m["min"], m["max"])
             for n, m in rg["chunks"].items()})
        for rg in raw["row_groups"]
    ]
    return PaxFooter(raw["n_rows"], columns, row_groups,
                     raw.get("codec", "zstd"))


def footer_byte_range(file_size: int, tail: bytes) -> tuple[int, int]:
    """Given the file's trailing TAIL_LEN bytes, locate the footer."""
    assert tail[-len(MAGIC):] == MAGIC, "not a SPAX file"
    footer_len = int(np.frombuffer(tail[:4], np.uint32)[0])
    return file_size - TAIL_LEN - footer_len, footer_len


def decompress_chunk(spec: ColumnSpec, meta_raw_len: int,
                     comp: bytes, codec: str = "zstd") -> np.ndarray:
    raw = compression.decompress(comp, codec,
                                 max_output_size=meta_raw_len)
    return np.frombuffer(raw, dtype=spec.np_dtype())


# -- ranged-read planning ----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkRequest:
    """One (row group, column) chunk a reader must fetch."""

    group: int
    column: str
    off: int
    length: int


def plan_chunk_requests(footer: PaxFooter, names: Sequence[str],
                        groups: Sequence[int]) -> list[ChunkRequest]:
    """The chunk fetches for a projection over surviving row groups,
    ordered by file offset (the write order interleaves columns within a
    row group, so adjacent chunks of one projection are often adjacent
    in the file)."""
    reqs = [ChunkRequest(gi, n, footer.row_groups[gi].chunks[n].off,
                         footer.row_groups[gi].chunks[n].length)
            for gi in groups for n in names]
    reqs.sort(key=lambda r: r.off)
    return reqs


def coalesce_ranges(reqs: Sequence[ChunkRequest],
                    gap: int) -> list[tuple[int, int, list[ChunkRequest]]]:
    """Merge offset-sorted chunk requests into ranged GETs.

    Requests whose byte ranges are adjacent or separated by at most
    ``gap`` wasted bytes share one GET (Lambada-style request batching:
    per-request cost dominates small reads, so a bounded amount of
    discarded bytes buys a large request-count reduction). Returns
    ``(off, length, members)`` triples covering every request.
    """
    out: list[tuple[int, int, list[ChunkRequest]]] = []
    for r in reqs:
        if out:
            off, length, members = out[-1]
            if r.off <= off + length + gap:
                end = max(off + length, r.off + r.length)
                out[-1] = (off, end - off, members + [r])
                continue
        out.append((r.off, r.length, [r]))
    return out


# -- zone-map predicate pruning ---------------------------------------------

@dataclasses.dataclass(frozen=True)
class ZonePredicate:
    """Conjunct usable for row-group pruning: ``col op literal``.

    ``op`` in {"<", "<=", ">", ">=", "==", "in"}. For dict columns the
    literal(s) must already be dictionary codes (the planner rewrites string
    literals via the catalog dictionary — including LIKE-prefix → IN-codes).
    """

    column: str
    op: str
    value: float | int | tuple

    def may_match(self, vmin, vmax) -> bool:
        if vmin is None or vmax is None:
            return True
        v = self.value
        if self.op == "<":
            return vmin < v
        if self.op == "<=":
            return vmin <= v
        if self.op == ">":
            return vmax > v
        if self.op == ">=":
            return vmax >= v
        if self.op == "==":
            return vmin <= v <= vmax
        if self.op == "in":
            return any(vmin <= x <= vmax for x in v)
        return True


def surviving_row_groups(footer: PaxFooter,
                         predicates: Sequence[ZonePredicate]) -> list[int]:
    """Indices of row groups that may contain matching rows."""
    out = []
    for i, rg in enumerate(footer.row_groups):
        keep = True
        for p in predicates:
            meta = rg.chunks.get(p.column)
            if meta is None:
                continue
            if not p.may_match(meta.vmin, meta.vmax):
                keep = False
                break
        if keep:
            out.append(i)
    return out
