"""Serverless storage tier models (paper Table 3).

Latency and cost characteristics of the storage services Skyrise builds on.
Latencies are modeled as lognormal distributions fit to the paper's reported
median / tail (~p99) figures; costs follow the paper's per-request, per-GiB
transfer, and per GiB-month storage prices.

The simulator never sleeps: latency draws are *accounted* into simulated
worker runtimes by the I/O handlers and the platform's critical-path model.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

_P99_Z = 2.326  # standard normal quantile for p99


@dataclasses.dataclass(frozen=True)
class StorageTier:
    """One serverless storage service (row of paper Table 3)."""

    name: str
    # Latency model inputs [seconds].
    read_median_s: float
    write_median_s: float
    read_tail_s: float
    write_tail_s: float
    # Request pricing [cents per 1M requests] (Table 3 "Requests").
    read_request_cents_per_1m: float
    write_request_cents_per_1m: float
    # Transfer pricing [cents per GiB].
    read_transfer_cents_per_gib: float
    write_transfer_cents_per_gib: float
    # At-rest pricing [cents per GiB-month].
    storage_cents_per_gib_month: float
    # Sustained per-connection bandwidth [bytes/s] for large ranged reads.
    # S3-class stores stream ~90 MB/s per connection; KV stores are for
    # small values only.
    bandwidth_bytes_per_s: float = 90e6

    def _sigma(self, median_s: float, tail_s: float) -> float:
        return max(1e-6, (math.log(tail_s) - math.log(median_s)) / _P99_Z)

    def draw_latency_s(self, rng: np.random.Generator, *, write: bool,
                       nbytes: int = 0) -> float:
        """First-byte latency draw plus bandwidth term for the payload."""
        median = self.write_median_s if write else self.read_median_s
        tail = self.write_tail_s if write else self.read_tail_s
        sigma = self._sigma(median, tail)
        first_byte = float(rng.lognormal(mean=math.log(median), sigma=sigma))
        return first_byte + nbytes / self.bandwidth_bytes_per_s

    def request_cost_cents(self, *, write: bool, nbytes: int) -> float:
        per_1m = (self.write_request_cents_per_1m if write
                  else self.read_request_cents_per_1m)
        per_gib = (self.write_transfer_cents_per_gib if write
                   else self.read_transfer_cents_per_gib)
        return per_1m / 1e6 + per_gib * nbytes / 2**30

    def storage_cost_cents(self, nbytes: int, seconds: float) -> float:
        month_s = 30 * 24 * 3600.0
        return self.storage_cents_per_gib_month * (nbytes / 2**30) * (
            seconds / month_s)


# Paper Table 3, us-east-1, Aug 2024 - Jan 2025.
S3_STANDARD = StorageTier(
    name="s3-standard",
    read_median_s=0.027, write_median_s=0.040,
    read_tail_s=1.0, write_tail_s=0.500,
    read_request_cents_per_1m=40.0, write_request_cents_per_1m=500.0,
    read_transfer_cents_per_gib=0.0, write_transfer_cents_per_gib=0.0,
    storage_cents_per_gib_month=2.2,
)

S3_EXPRESS = StorageTier(
    name="s3-express",
    read_median_s=0.005, write_median_s=0.008,
    read_tail_s=0.120, write_tail_s=0.150,
    read_request_cents_per_1m=20.0, write_request_cents_per_1m=250.0,
    read_transfer_cents_per_gib=0.15, write_transfer_cents_per_gib=0.8,
    storage_cents_per_gib_month=16.0,
    bandwidth_bytes_per_s=200e6,
)

DYNAMODB = StorageTier(
    name="dynamodb",
    read_median_s=0.004, write_median_s=0.006,
    read_tail_s=0.100, write_tail_s=0.250,
    read_request_cents_per_1m=25.0, write_request_cents_per_1m=125.0,
    read_transfer_cents_per_gib=0.0, write_transfer_cents_per_gib=0.0,
    storage_cents_per_gib_month=25.0,
    bandwidth_bytes_per_s=20e6,
)

EFS = StorageTier(
    name="efs",
    read_median_s=0.006, write_median_s=0.015,
    read_tail_s=0.100, write_tail_s=0.600,
    read_request_cents_per_1m=0.0, write_request_cents_per_1m=0.0,
    read_transfer_cents_per_gib=3.0, write_transfer_cents_per_gib=6.0,
    storage_cents_per_gib_month=23.0,
)

# Zero-latency, zero-cost tier for unit tests.
LOCAL = StorageTier(
    name="local",
    read_median_s=1e-9, write_median_s=1e-9,
    read_tail_s=2e-9, write_tail_s=2e-9,
    read_request_cents_per_1m=0.0, write_request_cents_per_1m=0.0,
    read_transfer_cents_per_gib=0.0, write_transfer_cents_per_gib=0.0,
    storage_cents_per_gib_month=0.0,
    bandwidth_bytes_per_s=1e12,
)

TIERS: dict[str, StorageTier] = {
    t.name: t for t in (S3_STANDARD, S3_EXPRESS, DYNAMODB, EFS, LOCAL)
}
