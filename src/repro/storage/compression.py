"""Pluggable chunk compression for the storage stack.

``zstandard`` gives the ratios/speeds the paper's storage numbers assume,
but it is an optional native dependency; environments without it fall back
to stdlib ``zlib``. Every on-store artifact records the codec it was
written with (SPAX footer, checkpoint manifest), so files stay readable
across environments as long as the writing codec is available — a zlib
reader never needs zstd to read zlib files.
"""

from __future__ import annotations

import zlib

try:
    import zstandard
    HAVE_ZSTD = True
except ImportError:          # pragma: no cover - environment-dependent
    zstandard = None
    HAVE_ZSTD = False

DEFAULT_CODEC = "zstd" if HAVE_ZSTD else "zlib"
CODECS = ("zstd", "zlib")


def compress(data: bytes, codec: str = DEFAULT_CODEC, *,
             level: int = 3) -> bytes:
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise RuntimeError("zstandard is not installed; "
                               "write with codec='zlib'")
        return zstandard.ZstdCompressor(level=level).compress(data)
    if codec == "zlib":
        return zlib.compress(data, min(level, 9))
    raise ValueError(f"unknown codec {codec!r} (expected one of {CODECS})")


def decompress(data: bytes, codec: str, *, max_output_size: int) -> bytes:
    cap = max(max_output_size, 1)
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise RuntimeError(
                "object was written with zstd but zstandard is not "
                "installed in this environment")
        return zstandard.ZstdDecompressor().decompress(
            data, max_output_size=cap)
    if codec == "zlib":
        # bound the output like the zstd path: a corrupt chunk must
        # error, not balloon to arbitrary memory
        dobj = zlib.decompressobj()
        out = dobj.decompress(data, cap)
        if dobj.unconsumed_tail:
            raise ValueError(
                f"zlib chunk decompressed past its declared size ({cap})")
        return out
    raise ValueError(f"unknown codec {codec!r} (expected one of {CODECS})")
