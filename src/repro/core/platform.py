"""FaaS platform simulation (paper section 2.1).

Models the pieces of AWS Lambda that shape Skyrise's behavior:

  * cold vs. warm sandbox starts (latencies per paper Table 2) — the warm
    pool grows as sandboxes are created and persists across stages, so cold
    starts are "negligible and only occur in the initial query stage";
  * per-user concurrency quota (admission control) → execution in waves;
  * asynchronous invocation with a small per-request dispatch overhead, and
    the paper's two-level √W invocation tree for large fleets;
  * fault injection (transient errors, stragglers, worker kills) to
    exercise the coordinator's adaptive re-triggering.

Execution is *wall-clock parallel* on this host: the platform owns a
thread-pool ``executor`` bounded by the admission quota, and
``invoke_many`` runs a fleet of fragments concurrently — each fragment
occupies exactly one admission slot, acquired before it is submitted and
released the moment it completes (per-slot release, no wave barrier).
*Simulated* wall-clock is accounted separately as the parallel critical
path over the quota (list-scheduling makespan, see
``QueryEngine._sim_makespan``).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable

import numpy as np

from repro.core.cost import LAMBDA_COLD_START, LAMBDA_WARM_START
from repro.core.retry import TransientInfraError

# Starvation-avoidance aging: a waiter's effective priority gains one
# level per interval spent waiting, so a steady stream of high-priority
# work can delay low-priority queries but never starve them.
AGING_INTERVAL_S = 5.0


@dataclasses.dataclass
class _Waiter:
    priority: int
    enqueued: float
    seq: int
    group: str | None = None

    def effective(self, now: float, aging_s: float) -> float:
        return self.priority + (now - self.enqueued) / max(aging_s, 1e-9)


class AdmissionController:
    """Cross-query admission control over one function-concurrency quota.

    Every query engine sharing a platform draws its execution waves from
    this ledger, so *concurrently submitted queries* — not just fragments
    within one pipeline — are bounded by the per-user quota (paper
    section 2.1). ``acquire`` blocks until at least one slot is free and
    grants up to ``want`` slots; callers release after the wave returns.

    Freed slots go to the *highest-priority* waiter rather than FIFO:
    waiters carry the owning query's ``priority``, aged upward by
    ``aging_interval_s`` spent waiting (starvation avoidance); ties
    break in arrival order, so equal-priority traffic — the default —
    keeps the original FIFO behavior.

    Multi-tenant weighted fair share (the service tier's per-tenant
    token bucket over this quota): ``set_share(group, weight)`` registers
    a tenant's weight; waiters carrying a weighted ``group`` are ordered
    by *normalized admitted work* — slots ever granted to the group
    divided by its weight — so under sustained contention the granted
    invocation counts converge to the weight ratio. Fair share
    dominates between distinct weighted groups; priority+aging decides
    within a group (and for all group-less waiters, preserving the
    original behavior). A group that stops contending simply stops
    accumulating work — no tenant starves, no tenant banks idle credit
    forever beyond its deficit.

    ``max_in_flight`` is the observed high-water mark (test/ops signal
    that the quota was never exceeded).
    """

    def __init__(self, quota: int, *,
                 aging_interval_s: float = AGING_INTERVAL_S,
                 shares: dict[str, float] | None = None):
        if quota < 1:
            raise ValueError(f"concurrency quota must be >= 1, got {quota}")
        self.quota = quota
        self.aging_interval_s = aging_interval_s
        self._cv = threading.Condition()
        self._in_flight = 0
        self._waiters: list[_Waiter] = []
        self._seq = itertools.count()
        self.max_in_flight = 0
        self.shares: dict[str, float] = dict(shares or {})
        self._admitted: dict[str, int] = {}

    @property
    def in_flight(self) -> int:
        with self._cv:
            return self._in_flight

    def set_share(self, group: str, weight: float) -> None:
        """Register (or update) a tenant group's fair-share weight."""
        if weight <= 0:
            raise ValueError(f"share weight must be > 0, got {weight}")
        with self._cv:
            self.shares[group] = float(weight)
            self._cv.notify_all()

    @property
    def admitted_by_group(self) -> dict[str, int]:
        """Slots ever granted per weighted group (fair-share evidence)."""
        with self._cv:
            return dict(self._admitted)

    def _normalized(self, group: str | None) -> float | None:
        """Admitted work normalized by weight; None for unweighted."""
        weight = self.shares.get(group) if group is not None else None
        if not weight:
            return None
        return self._admitted.get(group, 0) / weight

    def _beats(self, a: _Waiter, b: _Waiter, now: float) -> bool:
        """Strict service order: fair-share deficit between distinct
        weighted groups, then effective priority, then arrival."""
        na, nb = self._normalized(a.group), self._normalized(b.group)
        if na is not None and nb is not None and a.group != b.group \
                and na != nb:
            return na < nb
        ae = a.effective(now, self.aging_interval_s)
        be = b.effective(now, self.aging_interval_s)
        if ae != be:
            return ae > be
        return a.seq < b.seq

    def _is_best(self, w: _Waiter, now: float) -> bool:
        return all(o is w or self._beats(w, o, now)
                   for o in self._waiters)

    def acquire(self, want: int, priority: int = 0,
                group: str | None = None) -> int:
        """Block until slots are free *and* this caller is the
        best-ranked waiter (fair share, then priority); grant
        ``min(want, available)``."""
        if want <= 0:
            return 0
        with self._cv:
            w = _Waiter(priority, time.monotonic(), next(self._seq), group)
            self._waiters.append(w)
            try:
                while True:
                    now = time.monotonic()
                    if self.quota - self._in_flight > 0 \
                            and self._is_best(w, now):
                        break
                    # bounded wait: aging can promote a waiter past its
                    # peers even without a release notification
                    self._cv.wait(timeout=self.aging_interval_s / 2)
            finally:
                self._waiters.remove(w)
            grant = min(want, self.quota - self._in_flight)
            self._in_flight += grant
            self.max_in_flight = max(self.max_in_flight, self._in_flight)
            if group is not None and grant > 0:
                self._admitted[group] = self._admitted.get(group, 0) + grant
            # remaining capacity may serve the next-best waiter
            self._cv.notify_all()
            return grant

    def release(self, n: int) -> None:
        if n <= 0:
            return
        with self._cv:
            self._in_flight -= n
            assert self._in_flight >= 0, "admission release underflow"
            self._cv.notify_all()


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection, seeded per (pipeline, fragment,
    attempt)."""
    transient_error_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_factor: float = 10.0      # runtime multiplier when straggling
    kill_fragments: tuple = ()          # (pipeline, fragment, attempt) kills
    straggle_fragments: tuple = ()      # deterministic stragglers
    # Wall-clock sleep added to a straggling invocation (sim time is
    # scaled by straggler_factor regardless). Zero keeps fault tests
    # instant; the pipelined benchmark sets it so barrier-vs-pipelined
    # first-byte gains show up in *real* wall-clock, not just sim time.
    straggle_wall_s: float = 0.0
    seed: int = 0

    def roll(self, pipeline: int, fragment: int, attempt: int):
        rng = np.random.default_rng(
            (self.seed, pipeline, fragment, attempt))
        killed = (pipeline, fragment, attempt) in set(self.kill_fragments)
        transient = rng.random() < self.transient_error_prob
        straggle = (rng.random() < self.straggler_prob
                    or (pipeline, fragment, attempt)
                    in set(self.straggle_fragments))
        return killed or transient, straggle


class TransientWorkerError(TransientInfraError):
    """Infrastructure-level failure (sandbox died, network blip).
    Subclass of the shared :class:`TransientInfraError` taxonomy —
    kept as a name for back-compat with existing callers."""


@dataclasses.dataclass
class InvocationResult:
    payload: dict | None            # worker response (None if failed)
    error: str | None
    sim_start_s: float              # cold/warm start latency
    sim_runtime_s: float            # start + io + compute (straggle-scaled)
    cold: bool
    response: object = None


class FaasPlatform:
    """Simulated function platform shared by all queries in a session."""

    INVOKE_OVERHEAD_S = 0.002       # one async Invoke API call
    MAX_HOST_THREADS = 64           # host-resource cap on the pool size

    def __init__(self, *, quota: int = 1000, seed: int = 0,
                 faults: FaultPlan | None = None, chaos=None):
        self.quota = quota
        self.faults = faults or FaultPlan()
        self.chaos = chaos  # optional ChaosEngine (storms, worker kills)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._warm_sandboxes = 0
        self.invocations = 0
        self.cold_starts = 0
        # Shared ledger: all queries on this platform draw slots from it.
        self.admission = AdmissionController(quota)
        self._executor: ThreadPoolExecutor | None = None

    @property
    def executor(self) -> ThreadPoolExecutor:
        """Wall-clock backend, created on first use: admission bounds
        in-flight fragments to the quota; the pool size additionally
        caps host threads (slots held by queued-but-unstarted tasks
        still release when they run, so a pool smaller than the quota
        cannot deadlock)."""
        with self._lock:
            if self._executor is None:
                self._executor = ThreadPoolExecutor(
                    max_workers=min(self.quota, self.MAX_HOST_THREADS),
                    thread_name_prefix="faas-worker")
            return self._executor

    def close(self) -> None:
        """Shut down the thread pool (a later invocation transparently
        recreates it). Sessions that built their own platform call this
        from ``SkyriseSession.close``."""
        with self._lock:
            executor, self._executor = self._executor, None
        if executor is not None:
            executor.shutdown(wait=True)

    def __del__(self):
        # backstop for standalone engines/coordinators that default-
        # construct a platform and have no close path: wake the pool's
        # idle threads so an unreferenced platform doesn't strand them
        executor = self.__dict__.get("_executor")
        if executor is not None:
            executor.shutdown(wait=False)

    # -- startup latency draws -------------------------------------------------
    def _start_latency(self, cold: bool) -> float:
        m = LAMBDA_COLD_START if cold else LAMBDA_WARM_START
        lo, hi, avg = m["min"], m["max"], m["avg"]
        # right-skewed: shifted exponential matching the observed mean,
        # clipped to the observed max (paper Table 2)
        return float(min(lo + self._rng.exponential(avg - lo), hi))

    def dispatch_time_s(self, n: int, *, two_level: bool) -> float:
        """Critical-path time to issue n async invocations.

        Flat: the coordinator issues all n serially. Two-level (paper
        section 3.3): it invokes √n workers, each of which invokes √n−1
        more before running its own fragment.
        """
        if n <= 1 or not two_level:
            return n * self.INVOKE_OVERHEAD_S
        root = int(math.ceil(math.sqrt(n)))
        return (root + max(root - 1, 0)) * self.INVOKE_OVERHEAD_S

    # -- invocation --------------------------------------------------------------
    def invoke(self, handler: Callable[[dict], tuple[dict, float]],
               payload: dict, *, pipeline: int, fragment: int,
               attempt: int) -> InvocationResult:
        """Run one worker function. The handler returns
        (response_payload, sim_worker_runtime_s). Thread-safe: sandbox
        bookkeeping is locked; the handler itself runs unlocked so
        concurrent queries overlap."""
        storm = self.chaos is not None and self.chaos.cold_storm()
        with self._lock:
            self.invocations += 1
            # a cold-start storm forces a cold start without draining
            # the warm pool (availability blip, not a pool reset)
            cold = storm or self._warm_sandboxes <= 0
            if cold:
                self.cold_starts += 1
            else:
                self._warm_sandboxes -= 1
            start = self._start_latency(cold)

        fail, straggle = self.faults.roll(pipeline, fragment, attempt)
        if self.chaos is not None and self.chaos.worker_kill():
            fail = True
        if fail:
            # the sandbox died mid-flight; it still cost its startup time
            # but must NOT rejoin the warm pool — the retry pays a fresh
            # (usually cold) start instead of warm-starting on a sandbox
            # the simulation just declared dead
            return InvocationResult(None, "transient", start, start, cold)
        try:
            response, runtime = handler(payload)
        except TransientInfraError as e:
            # worker-side infrastructure failure (sandbox death, storage
            # 503, chaos injection): surfaced as a failed invocation so
            # the coordinator's fragment retry handles it uniformly
            return InvocationResult(None, str(e), start, start, cold)
        if straggle:
            runtime = runtime * self.faults.straggler_factor
            if self.faults.straggle_wall_s > 0:
                time.sleep(self.faults.straggle_wall_s)
        with self._lock:
            self._warm_sandboxes += 1
        return InvocationResult(response, None, start, start + runtime,
                                cold)

    def invoke_many(self, handler: Callable[[dict], tuple[dict, float]],
                    specs: list[dict], *, pipeline: int, attempt: int = 0,
                    cancel_check: Callable[[], None] | None = None,
                    run: Callable[[dict], InvocationResult] | None = None,
                    priority: int = 0, group: str | None = None,
                    on_all_submitted: Callable[[], None] | None = None,
                    ) -> list[InvocationResult]:
        """Run a fleet of fragments concurrently in wall-clock.

        Every fragment occupies exactly one admission slot: the slot is
        acquired (blocking) before the fragment is submitted to the
        executor and released the moment that fragment completes — *not*
        when the whole fleet finishes — so a finished worker's slot is
        immediately available to any query on the platform (per-slot
        release; no wave barrier).

        ``run`` overrides the per-fragment body (the engine passes its
        retry/reassignment wrapper); the default is a single ``invoke``.
        Returns one ``InvocationResult`` per spec, in spec order. If any
        fragment raises, the remaining fragments are drained and the
        first error is re-raised.

        ``on_all_submitted`` fires once the whole fleet sits in the
        executor's FIFO queue. The pipelined engine uses it to flip the
        manifest's ``all_submitted`` flag: consumers admitted after this
        point only wait on work already scheduled ahead of them, which
        keeps partial-input waiting deadlock-free at any quota.
        """
        if run is None:
            def run(spec: dict) -> InvocationResult:
                return self.invoke(handler, spec, pipeline=pipeline,
                                   fragment=spec["fragment"],
                                   attempt=attempt)
        futures: list[Future] = []
        try:
            for spec in specs:
                if cancel_check is not None:
                    cancel_check()
                self.admission.acquire(1, priority=priority, group=group)
                try:
                    fut = self.executor.submit(self._run_slot, run, spec)
                except BaseException:
                    self.admission.release(1)  # slot has no task to free it
                    raise
                futures.append(fut)
        except BaseException:
            # cancelled (or failed) mid-submission: queued-but-unstarted
            # fragments are cancelled outright (their slot never reaches
            # _run_slot, so release it here); already-running ones finish
            # (idempotent writers) and are drained so their slots are
            # back before propagating
            for fut in futures:
                if fut.cancel():
                    self.admission.release(1)
                    continue
                try:
                    fut.result()
                except BaseException:  # noqa: BLE001 - draining
                    pass
            raise
        if on_all_submitted is not None:
            on_all_submitted()
        results: list[InvocationResult] = []
        first_error: BaseException | None = None
        for fut in futures:
            try:
                results.append(fut.result())
            except BaseException as e:  # noqa: BLE001 - re-raised below
                if first_error is None:
                    first_error = e
        if first_error is not None:
            raise first_error
        return results

    def _run_slot(self, run: Callable[[dict], InvocationResult],
                  spec: dict) -> InvocationResult:
        try:
            return run(spec)
        finally:
            self.admission.release(1)

