"""FaaS platform simulation (paper section 2.1).

Models the pieces of AWS Lambda that shape Skyrise's behavior:

  * cold vs. warm sandbox starts (latencies per paper Table 2) — the warm
    pool grows as sandboxes are created and persists across stages, so cold
    starts are "negligible and only occur in the initial query stage";
  * per-user concurrency quota (admission control) → execution in waves;
  * asynchronous invocation with a small per-request dispatch overhead, and
    the paper's two-level √W invocation tree for large fleets;
  * fault injection (transient errors, stragglers, worker kills) to
    exercise the coordinator's adaptive re-triggering.

Execution is sequential on this host; *simulated* wall-clock is accounted
as the parallel critical path: dispatch + max over workers of
(start latency + worker runtime), per wave.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable

import numpy as np

from repro.core.cost import LAMBDA_COLD_START, LAMBDA_WARM_START


class AdmissionController:
    """Cross-query admission control over one function-concurrency quota.

    Every query engine sharing a platform draws its execution waves from
    this ledger, so *concurrently submitted queries* — not just fragments
    within one pipeline — are bounded by the per-user quota (paper
    section 2.1). ``acquire`` blocks until at least one slot is free and
    grants up to ``want`` slots; callers release after the wave returns.

    ``max_in_flight`` is the observed high-water mark (test/ops signal
    that the quota was never exceeded).
    """

    def __init__(self, quota: int):
        if quota < 1:
            raise ValueError(f"concurrency quota must be >= 1, got {quota}")
        self.quota = quota
        self._cv = threading.Condition()
        self._in_flight = 0
        self.max_in_flight = 0

    @property
    def in_flight(self) -> int:
        with self._cv:
            return self._in_flight

    def acquire(self, want: int) -> int:
        """Block until slots are free; grant ``min(want, available)``."""
        if want <= 0:
            return 0
        with self._cv:
            while self.quota - self._in_flight <= 0:
                self._cv.wait()
            grant = min(want, self.quota - self._in_flight)
            self._in_flight += grant
            self.max_in_flight = max(self.max_in_flight, self._in_flight)
            return grant

    def release(self, n: int) -> None:
        if n <= 0:
            return
        with self._cv:
            self._in_flight -= n
            assert self._in_flight >= 0, "admission release underflow"
            self._cv.notify_all()


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault injection, seeded per (pipeline, fragment,
    attempt)."""
    transient_error_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_factor: float = 10.0      # runtime multiplier when straggling
    kill_fragments: tuple = ()          # (pipeline, fragment, attempt) kills
    straggle_fragments: tuple = ()      # deterministic stragglers
    seed: int = 0

    def roll(self, pipeline: int, fragment: int, attempt: int):
        rng = np.random.default_rng(
            (self.seed, pipeline, fragment, attempt))
        killed = (pipeline, fragment, attempt) in set(self.kill_fragments)
        transient = rng.random() < self.transient_error_prob
        straggle = (rng.random() < self.straggler_prob
                    or (pipeline, fragment, attempt)
                    in set(self.straggle_fragments))
        return killed or transient, straggle


class TransientWorkerError(RuntimeError):
    """Infrastructure-level failure (sandbox died, network blip)."""


@dataclasses.dataclass
class InvocationResult:
    payload: dict | None            # worker response (None if failed)
    error: str | None
    sim_start_s: float              # cold/warm start latency
    sim_runtime_s: float            # start + io + compute (straggle-scaled)
    cold: bool
    response: object = None


class FaasPlatform:
    """Simulated function platform shared by all queries in a session."""

    INVOKE_OVERHEAD_S = 0.002       # one async Invoke API call

    def __init__(self, *, quota: int = 1000, seed: int = 0,
                 faults: FaultPlan | None = None):
        self.quota = quota
        self.faults = faults or FaultPlan()
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._warm_sandboxes = 0
        self.invocations = 0
        self.cold_starts = 0
        # Shared ledger: all queries on this platform draw waves from it.
        self.admission = AdmissionController(quota)

    # -- startup latency draws -------------------------------------------------
    def _start_latency(self, cold: bool) -> float:
        m = LAMBDA_COLD_START if cold else LAMBDA_WARM_START
        lo, hi, avg = m["min"], m["max"], m["avg"]
        # right-skewed: shifted exponential matching the observed mean,
        # clipped to the observed max (paper Table 2)
        return float(min(lo + self._rng.exponential(avg - lo), hi))

    def dispatch_time_s(self, n: int, *, two_level: bool) -> float:
        """Critical-path time to issue n async invocations.

        Flat: the coordinator issues all n serially. Two-level (paper
        section 3.3): it invokes √n workers, each of which invokes √n−1
        more before running its own fragment.
        """
        if n <= 1 or not two_level:
            return n * self.INVOKE_OVERHEAD_S
        root = int(math.ceil(math.sqrt(n)))
        return (root + max(root - 1, 0)) * self.INVOKE_OVERHEAD_S

    # -- invocation --------------------------------------------------------------
    def invoke(self, handler: Callable[[dict], tuple[dict, float]],
               payload: dict, *, pipeline: int, fragment: int,
               attempt: int) -> InvocationResult:
        """Run one worker function. The handler returns
        (response_payload, sim_worker_runtime_s). Thread-safe: sandbox
        bookkeeping is locked; the handler itself runs unlocked so
        concurrent queries overlap."""
        with self._lock:
            self.invocations += 1
            cold = self._warm_sandboxes <= 0
            if cold:
                self.cold_starts += 1
            else:
                self._warm_sandboxes -= 1
            start = self._start_latency(cold)

        fail, straggle = self.faults.roll(pipeline, fragment, attempt)
        if fail:
            # the sandbox died mid-flight; it still cost its startup time
            with self._lock:
                self._warm_sandboxes += 1
            return InvocationResult(None, "transient", start, start, cold)
        try:
            response, runtime = handler(payload)
        except TransientWorkerError as e:  # pragma: no cover - defensive
            with self._lock:
                self._warm_sandboxes += 1
            return InvocationResult(None, str(e), start, start, cold)
        if straggle:
            runtime = runtime * self.faults.straggler_factor
        with self._lock:
            self._warm_sandboxes += 1
        return InvocationResult(response, None, start, start + runtime,
                                cold)

