"""Per-query execution engine (paper sections 3.1, 3.3).

One ``QueryEngine`` manages the lifecycle of exactly one query: it
compiles SQL to pipelines, schedules them stage-wise by dependency,
invokes one worker function per fragment (two-level √W fan-out for large
fleets), tracks worker progress, and adapts:

  * stragglers → re-triggered mid-query (safe: workers are idempotent and
    write deterministic single objects; racing duplicates overwrite
    identical results);
  * transient infrastructure failures → bounded retries; on repeated
    failure the fragment's input units are *reassigned to more workers*;
  * deterministic (code/data) failures → abort; completed pipelines stay
    registered, so a re-run restarts from the last complete stage
    (stage results are checkpoints);
  * completed pipelines are registered in the result cache under their
    semantic hash and skipped by later queries (section 3.4);
  * *in-flight* pipelines are claimed in the registry, so a concurrent
    query wanting the same semantic hash blocks on the one running
    execution (claim/publish/await_complete) instead of racing it.

A pipeline's fragments execute concurrently in wall-clock on the
platform's thread pool. Admission is per *fragment slot*: each fragment
holds exactly one quota slot for exactly its own lifetime, so a finished
worker's slot is instantly available to any fragment of any query — no
wave barrier on the slowest worker.

Engines are cheap and stateless between queries: everything they need is
in the catalog, the registry, and the object store. A ``SkyriseSession``
(``repro.api``) runs many engines concurrently against one shared
``FaasPlatform``; fragments — *across* queries, not just within one
pipeline — are admitted through the platform's ``AdmissionController``
so the fleet never exceeds the function-concurrency quota.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from typing import Callable

import numpy as np

from repro.core.adaptive import Reoptimizer, apply_broadcast
from repro.core.cost import CostBreakdown, CostModel
from repro.core.events import QueryObserver
from repro.core.platform import (AdmissionController, FaasPlatform,
                                 InvocationResult)
from repro.core.registry import ResultRegistry
from repro.core.retry import (QueryFailedError, RetryBudget,
                              RetryBudgetExhausted, RetryPolicy,
                              TransientInfraError, is_transient)
from repro.core.worker import make_worker_handler
from repro.data.catalog import Catalog
from repro.exec import exchange
from repro.exec.operators import kmv_estimate, kmv_merge
from repro.sql.calibration import (SelectivityCalibration,
                                   scan_filter_signature)
from repro.sql.logical import Binder
from repro.sql.parser import parse
from repro.sql.physical import (PhysicalPlan, Pipeline, PlannerConfig,
                                compile_query)
from repro.sql.rules import optimize
from repro.storage.io_handlers import InputHandler
from repro.storage.object_store import ObjectStore


class QueryAborted(QueryFailedError):
    """Permanent query failure with a post-mortem (bad plan, repeated
    deterministic worker failure, missing upstream)."""

    def __init__(self, msg: str, post_mortem: dict):
        super().__init__(msg)
        self.post_mortem = post_mortem


class QueryCancelled(RuntimeError):
    """Raised inside the engine when the owning handle was cancelled."""


@dataclasses.dataclass
class PipelineReport:
    pid: int
    sem_hash: str
    n_fragments: int               # fragments actually invoked
    cache_hit: bool = False
    deduped: bool = False    # in-flight dedup: shared a peer's execution
    attempts: int = 0
    stragglers_retriggered: int = 0
    transient_failures: int = 0
    reassignments: int = 0
    sim_s: float = 0.0
    rows_out: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    requests: int = 0
    # kernel dispatch + metadata-cache visibility (section 3.3/3.4 hot path)
    kernel: str = ""               # fused kernel the plan lowers to
    kernel_fragments: int = 0      # fragments that ran on the fused path
    kernel_miss_reason: str = ""   # why the matcher fell back (if it did)
    kernel_roofline: dict | None = None   # roofline-chosen tiling
    footer_cache_hits: int = 0
    # adaptive re-optimization (core.adaptive): the static plan's fleet,
    # the planner's row estimate (EXPLAIN ANALYZE est vs actual), the
    # barrier decisions applied, and the per-partition output manifest
    # accumulated from worker responses
    n_planned: int = 0
    est_rows: int = -1
    adaptations: list = dataclasses.field(default_factory=list)
    partition_stats: list | None = None
    # exchange subsystem (exec.exchange): the shuffle strategy this
    # pipeline's output exchange ran under, its estimated vs observed
    # producer-side storage requests, and the injected merge-wave width
    exchange_strategy: str = ""
    est_exchange_requests: int = 0
    exchange_requests: int = 0
    merge_fragments: int = 0
    # barrier-free pipelined execution: whether this pipeline consumed
    # partial upstream manifests, how many producers' stats seeded its
    # re-optimization (pilot-K), the sim time of its first available
    # input batch, the top-up batches drained after launch, and the read
    # time hidden behind kernel compute (double-buffering)
    pipelined: bool = False
    pilot_k: int = 0
    first_input_s: float = 0.0
    topups: int = 0
    overlap_saved_s: float = 0.0
    # semi-join filter pushdown: the decision record of an annotated
    # probe pipeline (plan annotation + runtime verdict + ``applied``),
    # the probe rows the filter killed before partitioning, and — build
    # side — the merged Bloom filter wire dict OR-accumulated from the
    # fleet's responses and published with the exchange manifest
    semijoin: dict | None = None
    semijoin_killed: int = 0
    semijoin_bloom: dict | None = None
    # the pipeline's window on the query's simulated timeline, and the
    # per-fragment completion offsets downstream admission gates key on
    sim_start_s: float = 0.0
    sim_end_s: float = 0.0
    dispatch_s: float = 0.0
    producer_completions: list = dataclasses.field(default_factory=list)
    # recompute cost of this pipeline alone — the registry's age×cost
    # eviction keep-score
    cost_cents: float = 0.0


@dataclasses.dataclass
class QueryStats:
    sim_latency_s: float = 0.0
    wall_s: float = 0.0
    pipelines: list[PipelineReport] = dataclasses.field(default_factory=list)
    cost: CostBreakdown = dataclasses.field(default_factory=CostBreakdown)
    query_id: str = ""

    @property
    def cache_hits(self) -> int:
        return sum(1 for p in self.pipelines if p.cache_hit)


@dataclasses.dataclass
class QueryResult:
    locations: list[str]
    output_names: list[str]
    stats: QueryStats

    @property
    def location(self) -> str:
        """First result object (back-compat; see ``locations``)."""
        return self.locations[0]

    def fetch(self, store: ObjectStore) -> dict[str, np.ndarray]:
        """Read and concatenate all result fragments, in fragment order."""
        ih = InputHandler(store)
        parts = [ih.read_table(loc)[0] for loc in self.locations]
        if len(parts) == 1:
            return parts[0]
        return {k: np.concatenate([p[k] for p in parts])
                for k in parts[0]}


@dataclasses.dataclass
class CoordinatorConfig:
    planner: PlannerConfig = dataclasses.field(default_factory=PlannerConfig)
    straggler_detect_factor: float = 3.0
    straggler_min_timeout_s: float = 0.5
    max_attempts: int = 3
    two_level_threshold: int = 16
    response_poll_overhead_s: float = 0.01
    use_result_cache: bool = True
    # Adaptive re-optimization at stage barriers (core.adaptive): re-size
    # downstream fleets cost-optimally under the latency budget, prune
    # empty exchange partitions, downgrade shuffle joins to broadcast
    # when the observed build side fits the memory budget (None → the
    # planner's broadcast threshold), and re-pick exchange tiers.
    adaptive: bool = True
    adaptive_latency_budget_s: float = 2.0
    broadcast_downgrade_bytes: int | None = None
    # Persist observed per-(table, predicate) selectivities in the KV
    # tier and seed the planner's estimates with them (downward-only),
    # so recurring predicates converge without waiting for a barrier.
    calibrate_selectivity: bool = True
    # Barrier-free pipelined execution (incremental exchange manifests):
    # every pipeline runs on its own scheduler thread; a consumer
    # launches once the admission fraction of each upstream fleet's
    # partitions has landed *and* that fleet is fully submitted (the
    # deadlock-freedom gate), tops up as later manifests arrive, and
    # re-optimizes on the first `pilot_k` producers' observed stats
    # extrapolated to the fleet. `pipelined=False` restores the
    # bit-compatible all-or-nothing stage-barrier schedule.
    # `pipeline_start_fraction=None` (the default) lets the cost model
    # choose the fraction per upstream fleet from its observed runtime
    # skew (CostModel.pipeline_admission_fraction); a float forces that
    # constant fraction everywhere, e.g. the seed behavior's 0.5.
    pipelined: bool = True
    pipeline_start_fraction: float | None = None
    pilot_k: int = 2
    pipelined_wait_timeout_s: float = 600.0
    # Scan-selectivity pilot: an uncalibrated scan→filter pipeline with
    # at least this many scan units probes one unit first and records
    # the observed selectivity before the fleet launches.
    pilot_scan_min_units: int = 4
    # Unified retry policy (core.retry): bounded exponential backoff
    # with full jitter, one per-query budget spent by every layer that
    # retries a transient failure (fragment re-invokes and query-level
    # re-drives after coordinator-side infrastructure errors).
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    # Hedged storage reads: replace the constant straggler re-trigger
    # timeout with the cost model's per-tier break-even point (duplicate
    # request cents vs GiB-seconds spent waiting). Off by default —
    # identical request counts to the seed behavior.
    hedged_reads: bool = False
    # Semi-join filter pushdown: build fleets of planner-annotated
    # repartition joins construct a Bloom filter over the join key and
    # publish the merged words through the partial-manifest protocol;
    # eligible probe pipelines wait (bounded) for the *sealed* filter
    # and kill non-matching rows before partitioning. A partial filter
    # is never applied — missing producers would mean false negatives.
    # Off → annotated plans run unfiltered (sem hashes are identical
    # either way, so both settings share the result cache).
    semijoin: bool = True
    semijoin_wait_timeout_s: float = 30.0


class QueryEngine:
    """Executes one query against session-shared infrastructure.

    ``registry``/``handler`` default to private instances (standalone
    use); a session passes its shared ones so the result cache and the
    worker code are shared across queries.
    """

    def __init__(self, store: ObjectStore, catalog: Catalog, *,
                 platform: FaasPlatform | None = None,
                 config: CoordinatorConfig | None = None,
                 cost_model: CostModel | None = None,
                 registry: ResultRegistry | None = None,
                 handler=None,
                 observer: QueryObserver | None = None,
                 query_id: str = "query",
                 cancel_check: Callable[[], None] | None = None,
                 priority: int = 0,
                 tenant: str | None = None,
                 deadline_s: float | None = None,
                 fleet_cap: int | None = None):
        self.store = store
        self.catalog = catalog
        self.platform = platform or FaasPlatform()
        self.config = config or CoordinatorConfig()
        self.cost_model = cost_model or CostModel()
        self.registry = registry or ResultRegistry(store)
        self.handler = handler or make_worker_handler(store)
        self.observer = observer or QueryObserver()
        self.query_id = query_id
        self.priority = priority
        # service tier (repro.service): tenant → fair-share admission
        # group; deadline_s → per-stage latency budgets (SLO-aware fleet
        # sizing); fleet_cap → degraded dispatch for over-budget tenants
        self.tenant = tenant
        self.deadline_s = deadline_s
        self.fleet_cap = fleet_cap
        # pipelines run on concurrent scheduler threads (pipelined mode),
        # each with its own stage budget; a failing pipeline poisons its
        # siblings through _sibling_abort so their waits unwind fast
        self._budget_local = threading.local()
        self._sibling_abort: BaseException | None = None
        # per-plan admission gates: sem_hash -> {"event", "floor"} —
        # a pipelined consumer may not consult the registry for a
        # source this plan itself produces until its producer thread
        # has committed (cache hit, or streams reset for execution)
        self._source_gates: dict[str, dict] = {}
        self._cancel_check = cancel_check
        self.admission: AdmissionController = self.platform.admission
        cfg = self.config
        self.calibration = SelectivityCalibration(store) \
            if cfg.calibrate_selectivity else None
        self.reoptimizer = Reoptimizer(
            self.cost_model,
            latency_budget_s=cfg.adaptive_latency_budget_s,
            broadcast_bytes=(cfg.broadcast_downgrade_bytes
                             if cfg.broadcast_downgrade_bytes is not None
                             else cfg.planner.broadcast_threshold_bytes),
            hot_shuffle_object_threshold=(
                cfg.planner.hot_shuffle_object_threshold),
            quota=self.admission.quota,
            forced_strategy=cfg.planner.exchange_strategy)
        # per-query transient-retry allowance shared by every retrying
        # layer (fragment re-invokes, query-level re-drives)
        self.retry_budget = RetryBudget(self.config.retry.budget)
        # fragments of one pipeline report concurrently
        self._metrics_lock = threading.Lock()

    # -- public API ----------------------------------------------------------
    def plan_sql(self, sql: str) -> PhysicalPlan:
        stmt = parse(sql)
        lqp, _ = Binder(self.catalog).bind(stmt)
        lqp = optimize(lqp)
        return compile_query(lqp, self.catalog, self.config.planner,
                             cost_model=self.cost_model,
                             calibration=self.calibration)

    def execute_sql(self, sql: str) -> QueryResult:
        return self.execute_plan(self.plan_sql(sql))

    def execute_plan(self, plan: PhysicalPlan) -> QueryResult:
        """Run the plan, re-driving it after coordinator-side transient
        infrastructure failures (registry/ledger/KV write lost
        mid-protocol, chaos kills). Re-driving is safe: completed
        pipelines are published checkpoints (cache hits on the re-drive)
        and abandoned claims are re-won or TTL-stolen. Retries draw from
        the per-query budget; exhaustion (or ``query_retries`` attempts)
        surfaces :class:`RetryBudgetExhausted` with the final transient
        cause chained."""
        policy = self.config.retry
        q_attempt = 0
        while True:
            try:
                return self._execute_plan_once(plan)
            except QueryCancelled:
                raise
            except TransientInfraError as e:
                if not is_transient(e):
                    raise
                q_attempt += 1
                if q_attempt > policy.query_retries \
                        or not self.retry_budget.try_spend():
                    raise RetryBudgetExhausted(
                        f"query {self.query_id}: transient infrastructure "
                        f"failures exhausted the retry budget "
                        f"(spent {self.retry_budget.spent}, last: {e})",
                        last_error=e,
                        spent=self.retry_budget.spent) from e
                time.sleep(policy.backoff_s(q_attempt))

    def _execute_plan_once(self, plan: PhysicalPlan) -> QueryResult:
        if self.config.pipelined:
            return self._execute_plan_pipelined(plan)
        t_wall = time.perf_counter()
        stats = QueryStats(query_id=self.query_id)
        stages = plan.stages()
        for si, stage in enumerate(stages):
            if self.deadline_s is not None:
                # remaining deadline split over the stages still to run:
                # a query running behind its SLO gets a shrinking budget
                # → optimal_fleet escalates toward the cap at the barrier
                self._stage_budget_s = self.cost_model.stage_latency_budget(
                    self.deadline_s, stats.sim_latency_s,
                    len(stages) - si)
            stage_sim = 0.0
            for pid in _stage_order(plan, stage):
                self._check_cancel()
                report = self._run_pipeline(plan.pipelines[pid], stats)
                stats.pipelines.append(report)
                stage_sim = max(stage_sim, report.sim_s)
            stats.sim_latency_s += stage_sim
        stats.wall_s = time.perf_counter() - t_wall
        stats.cost.merge(
            self.cost_model.coordinator_cost(stats.sim_latency_s))
        root = plan.pipelines[plan.root_pid]
        return QueryResult(self._result_locations(root),
                           plan.output_names, stats)

    def _execute_plan_pipelined(self, plan: PhysicalPlan) -> QueryResult:
        """Barrier-free schedule: every pipeline gets its own scheduler
        thread immediately; consumers block inside ``_resolve_sources``
        on their upstream partial manifests (the admission gate) instead
        of on a stage barrier, then top up as later partitions land.

        A failing pipeline poisons its own partial streams (in-flight
        consumer workers fail fast) and trips ``_sibling_abort`` so
        sibling threads unwind at their next cancel check; the first
        *root-cause* error (in pipeline order) is re-raised."""
        t_wall = time.perf_counter()
        stats = QueryStats(query_id=self.query_id)
        stages = plan.stages()
        self._sibling_abort = None
        self._source_gates = {
            p.sem_hash: {"event": threading.Event(), "floor": None}
            for p in plan.pipelines.values()}
        # deterministic per-pipeline budget: the deadline split evenly
        # over all stages up front — there is no barrier-elapsed feedback
        # to re-split on when every stage is in flight at once
        budget = None
        if self.deadline_s is not None:
            budget = self.cost_model.stage_latency_budget(
                self.deadline_s, 0.0, max(len(stages), 1))
        order = [pid for stage in stages for pid in stage]
        reports: dict[int, PipelineReport] = {}
        errors: dict[int, BaseException] = {}

        def run(pid: int) -> None:
            p = plan.pipelines[pid]
            try:
                self._stage_budget_s = budget
                reports[pid] = self._run_pipeline(p, stats)
            except BaseException as e:
                errors[pid] = e
                self._sibling_abort = e

        threads = [threading.Thread(target=run, args=(pid,), daemon=True,
                                    name=f"{self.query_id}-p{pid}")
                   for pid in order]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        self._sibling_abort = None
        if errors:
            for pid in order:    # prefer a root cause over induced aborts
                err = errors.get(pid)
                if err is not None and not isinstance(err, QueryCancelled):
                    raise err
            raise errors[next(pid for pid in order if pid in errors)]
        for pid in order:
            stats.pipelines.append(reports[pid])
        self._sim_timeline(plan, stages, reports, stats)
        stats.wall_s = time.perf_counter() - t_wall
        stats.cost.merge(
            self.cost_model.coordinator_cost(stats.sim_latency_s))
        root = plan.pipelines[plan.root_pid]
        return QueryResult(self._result_locations(root),
                           plan.output_names, stats)

    def _admission_fraction(self, completions_s: list[float]) -> float:
        """The consumer-admission fraction for one upstream fleet: the
        config's forced constant when set, else the cost model's pick
        from the fleet's observed completion skew."""
        f = self.config.pipeline_start_fraction
        if f is not None:
            return f
        return self.cost_model.pipeline_admission_fraction(completions_s)

    def _sim_timeline(self, plan: PhysicalPlan, stages: list[list[int]],
                      reports: dict[int, PipelineReport],
                      stats: QueryStats) -> None:
        """Simulated-makespan post-pass for the pipelined schedule: a
        consumer starts not at its slowest producer's finish (the
        barrier) but at the admission fraction's k-th order statistic of
        each upstream fleet's simulated completions — and cannot finish
        before the producers whose tail partitions it still reads."""
        end: dict[int, float] = {}
        sem_pid = {p.sem_hash: pid for pid, p in plan.pipelines.items()}
        for stage in stages:
            for pid in _stage_order(plan, stage):
                r = reports[pid]
                start = 0.0
                tail = 0.0
                for dep in plan.pipelines[pid].deps:
                    rr = reports[dep]
                    if rr.cache_hit:
                        continue
                    if r.pipelined:
                        frac = self._admission_fraction(
                            rr.producer_completions)
                        avail = (rr.sim_start_s + rr.dispatch_s
                                 + CostModel.pipeline_start_offset_s(
                                     rr.producer_completions, frac))
                        start = max(start, min(avail, end[dep]))
                    else:
                        start = max(start, end[dep])
                    tail = max(tail, end[dep])
                # a filtered probe waited for the build's sealed filter
                # — the build pipeline is not a dep, but it is on the
                # probe's critical path (stage order processes filter
                # producers first, so its end is already known)
                if r.semijoin is not None and r.semijoin.get("applied"):
                    bpid = sem_pid.get(r.semijoin.get("build"))
                    if bpid is not None and bpid in end \
                            and not reports[bpid].cache_hit:
                        start = max(start, end[bpid])
                r.sim_start_s = start
                r.sim_end_s = max(start + r.sim_s, tail) \
                    if not r.cache_hit else start
                end[pid] = r.sim_end_s
        stats.sim_latency_s = max(end.values()) if end else 0.0

    # -- result location ------------------------------------------------------
    def _result_locations(self, root: Pipeline) -> list[str]:
        """Resolve the root pipeline's objects from its registry entry.

        The registered layout is authoritative: a cache hit may have been
        produced under a *different* physical configuration (fragment
        count) than the current plan — semantic hashing guarantees only
        logical equivalence (section 3.4).
        """
        entry = self.registry.lookup(root.sem_hash)
        if entry is not None:
            prefix, n = entry["prefix"], entry["n_fragments"]
        else:  # cache disabled + nothing registered (defensive)
            prefix, n = f"results/{root.sem_hash}", root.n_fragments
        return [f"{prefix}/f{f:04d}/out.spax" for f in range(n)]

    # -- pipeline scheduling ----------------------------------------------------
    @property
    def _stage_budget_s(self) -> float | None:
        """Per-stage latency budget, thread-local: in pipelined mode
        concurrent pipeline threads carry different budgets."""
        return getattr(self._budget_local, "value", None)

    @_stage_budget_s.setter
    def _stage_budget_s(self, value: float | None) -> None:
        self._budget_local.value = value

    def _check_cancel(self) -> None:
        if self._cancel_check is not None:
            self._cancel_check()
        if self._sibling_abort is not None:
            raise QueryCancelled("sibling pipeline failed; aborting")

    def _run_pipeline(self, p: Pipeline, stats: QueryStats) -> PipelineReport:
        report = PipelineReport(p.pid, p.sem_hash, p.n_fragments,
                                kernel=p.kernel or "",
                                kernel_miss_reason=p.kernel_miss_reason
                                or "",
                                kernel_roofline=p.kernel_roofline,
                                n_planned=p.n_fragments,
                                est_rows=p.params.est_out_rows)
        claimed = False
        if self.config.use_result_cache:
            # claim/publish/await_complete: exactly one of N concurrent
            # queries wanting this sem_hash executes it; the rest block
            # on the in-flight entry and share the published result.
            while True:
                if self.registry.lookup(p.sem_hash):
                    report.cache_hit = True
                    self._open_source_gate(p.sem_hash)
                    self.observer.on_pipeline_complete(self.query_id,
                                                       report)
                    return report
                if self.registry.claim(p.sem_hash):
                    claimed = True
                    break
                entry = self.registry.await_complete(
                    p.sem_hash, cancel_check=self._check_cancel)
                if entry is not None:
                    report.cache_hit = True
                    report.deduped = True
                    self._open_source_gate(p.sem_hash)
                    self.observer.on_pipeline_complete(self.query_id,
                                                       report)
                    return report
                # the owner abandoned (failed/cancelled) → try to claim
        try:
            return self._execute_pipeline(p, stats, report)
        except BaseException:
            if self.config.pipelined \
                    and (claimed or not self.config.use_result_cache):
                # poison the partial streams *before* abandoning the
                # claim: a waiter that re-claims resets them fresh in
                # begin_partial, so abort-then-abandon cannot poison the
                # new owner's streams — the reverse order could
                self.registry.abort_partial(p.sem_hash)
            if claimed:
                self.registry.abandon(p.sem_hash)
            raise

    def _execute_pipeline(self, p: Pipeline, stats: QueryStats,
                          report: PipelineReport) -> PipelineReport:
        prefix = f"results/{p.sem_hash}"
        cfg = self.config
        pipelined = cfg.pipelined
        sources = self._resolve_sources(p.op, pipelined=pipelined)
        partials = [e for e in sources.values() if e.get("partial")]
        if partials:
            report.pipelined = True
            report.pilot_k = max(e["partial"]["pilot_k"] for e in partials)

        # Barrier hook: every physical decision downstream of this
        # barrier is re-evaluated against the observed statistics the
        # upstream manifests carry (fleet size, partition assignment,
        # join strategy, exchange tier). Mutates p.params only — the
        # semantic hash, and thus caching/dedup, is unaffected.
        if self.config.adaptive:
            adaptations = self.reoptimizer.adapt(
                p, sources, latency_budget_s=self._stage_budget_s,
                fleet_cap=self.fleet_cap)
            if adaptations:
                report.adaptations = adaptations
                report.n_fragments = p.n_fragments
                for a in adaptations:
                    self.observer.on_adaptation(self.query_id, p.pid, a)
        self._apply_slo_fleet(p, report)
        if pipelined:
            self._pilot_scan(p, report, stats)

        # Semi-join filter pushdown. Build side: instruct the fleet to
        # hash its exchange keys into per-fragment Bloom words (sized
        # here, after any pilot re-estimate, so every fragment — and
        # every straggler duplicate — agrees on the word count). Probe
        # side: resolve the build's sealed filter (bounded wait, runtime
        # adopt/revoke) and inject it into every fragment spec so rows
        # are killed before partitioning.
        bloom_spec = None
        if cfg.semijoin and p.params.bloom is not None:
            from repro.kernels import bloom as bloomlib
            capacity = max(int(p.params.bloom.get("est_distinct") or 1),
                           int(p.params.est_out_rows), 1)
            bloom_spec = {"bits": bloomlib.bloom_bits_for(capacity),
                          "k": bloomlib.BLOOM_K,
                          "mode": p.params.bloom["mode"]}
        semijoin_spec = self._semijoin_filter(p, report, stats) \
            if cfg.semijoin else None

        if p.partitioning.kind == "hash":
            report.exchange_strategy = p.partitioning.strategy
            report.est_exchange_requests = \
                p.params.est_exchange_requests
        self.observer.on_pipeline_start(self.query_id, p.pid, p.sem_hash,
                                        p.n_fragments)
        # broadcast-downgraded sources rewrite the op tree on one copy
        # (the pipeline's logical core stays untouched); the rewritten
        # join probe re-enters kernel dispatch and, when the chain ends
        # in an aggregate, runs the fused join-probe kernel
        eff_op = apply_broadcast(p.op, p.params.broadcast_sources)
        specs = {
            f: self._fragment_spec(p, f, p.n_fragments, prefix, sources,
                                   eff_op, bloom=bloom_spec,
                                   semijoin=semijoin_spec)
            for f in range(p.n_fragments)
        }

        two_level = p.n_fragments >= cfg.two_level_threshold
        dispatch = self.platform.dispatch_time_s(p.n_fragments,
                                                 two_level=two_level)
        report.dispatch_s = dispatch
        extra_fragments: list[dict] = []

        part_dict = p.partitioning.to_dict()
        strat = None
        if p.partitioning.kind == "hash":
            strat = exchange.get_strategy(p.partitioning.strategy)
            # consumers dispatch on the *materialized* layout
            part_dict["layout"] = strat.layout
        # incremental manifests: open the stream consumers gate on
        # before any producer runs, so a consumer admitted mid-fleet
        # already sees the layout metadata
        wave = (pipelined and strat is not None
                and bool(strat.merge_workers(p.n_fragments)))
        merge_thread = None
        merge_box: dict = {}
        on_all_submitted = None
        if pipelined:
            floor = time.time()
            if wave:
                # multilevel producers stream into the l0 manifest; the
                # consumer-facing main stream is re-opened (with the
                # real group count) at wave launch — reset it here too
                # so a stale sealed manifest from an earlier run of
                # this sem cannot admit consumers in the meantime
                self.registry.begin_partial(
                    p.sem_hash, stream="l0", n_producers=p.n_fragments,
                    prefix=f"{prefix}/l0")
                self.registry.begin_partial(
                    p.sem_hash,
                    n_producers=exchange.merge_group_count(
                        p.n_fragments),
                    prefix=prefix, partitioning=part_dict,
                    schema=p.output_schema)
                merge_thread = threading.Thread(
                    target=self._merge_wave_pipelined,
                    args=(p, prefix, report, stats, merge_box),
                    daemon=True, name=f"{self.query_id}-p{p.pid}-merge")
            else:
                self.registry.begin_partial(
                    p.sem_hash, n_producers=p.n_fragments, prefix=prefix,
                    partitioning=part_dict, schema=p.output_schema)
            # producer committed to executing: admit consumers, but only
            # to entries published from here on (anything older is a
            # different run's layout)
            self._open_source_gate(p.sem_hash, floor)
            producer_stream = "l0" if wave else "partial"

            def on_all_submitted() -> None:
                # every producer now sits in the FIFO executor queue:
                # admit consumers (they then only ever wait on work
                # scheduled ahead of them — the deadlock-freedom gate)
                # and only now launch the merge wave, so its workers
                # also queue strictly behind the producers they drain
                self.registry.mark_all_submitted(
                    p.sem_hash, p.n_fragments, stream=producer_stream)
                if merge_thread is not None:
                    merge_thread.start()

        try:
            # The whole fleet runs concurrently in wall-clock; each
            # fragment holds one admission slot for exactly its own
            # lifetime (retries included), released on completion — so
            # concurrent queries interleave at fragment granularity, not
            # wave granularity. ``completions`` holds per-fragment
            # *runtimes*.
            results = self.platform.invoke_many(
                self.handler, list(specs.values()), pipeline=p.pid,
                cancel_check=self._check_cancel, priority=self.priority,
                group=self.tenant,
                run=lambda spec: self._run_fragment(p, spec, report,
                                                    stats,
                                                    extra_fragments),
                on_all_submitted=on_all_submitted)
            completions: dict[int, float] = {
                f: res.sim_runtime_s for f, res in zip(specs, results)}

            # Straggler mitigation: detect on per-fragment *runtimes*
            # (never on quota-wave-offset completion times — a later
            # wave's normal fragment is not a straggler) against the
            # fleet's fast quartile (the median is already contaminated
            # in small or straggler-heavy fleets), then re-trigger; the
            # effective runtime races the original against the duplicate
            # — safe because workers are idempotent single-object
            # writers.
            if len(completions) >= 2:
                runtimes = np.array(list(completions.values()))
                fast = float(np.percentile(runtimes, 25, method="lower"))
                threshold = max(cfg.straggler_detect_factor * fast,
                                cfg.straggler_min_timeout_s)
                for f, t in list(completions.items()):
                    if t > threshold:
                        self.observer.on_straggler(self.query_id, p.pid,
                                                   f)
                        self.admission.acquire(1, priority=self.priority,
                                               group=self.tenant)
                        try:
                            # the duplicate's rows/bytes repeat the
                            # original worker's output — bill its cost,
                            # don't double-count its payload
                            dup = self._invoke(
                                p, specs[f], report, stats,
                                attempt=100 + report.attempts,
                                count_payload=False)
                        finally:
                            self.admission.release(1)
                        report.stragglers_retriggered += 1
                        if dup.error is None:
                            completions[f] = min(t, threshold
                                                 + dup.sim_runtime_s)
        except BaseException:
            if pipelined:
                # fail fast: poison the streams so in-flight consumers
                # and the merge wave unwind instead of sitting out their
                # top-up timeout, then collect the wave thread
                self.registry.abort_partial(p.sem_hash)
                if merge_thread is not None:
                    merge_thread.join()
            raise

        report.sim_s += (dispatch
                         + self._sim_makespan(list(completions.values()))
                         + cfg.response_poll_overhead_s)
        report.producer_completions = self._sim_schedule(
            list(completions.values()))

        n_total = p.n_fragments + len(extra_fragments)
        publish_n = n_total
        if not pipelined and strat is not None \
                and strat.merge_workers(n_total):
            # multi-level (barrier): inject the merge wave as an extra
            # stage of this pipeline's schedule; the published exchange
            # is the wave's G×m grid, so downstream readers see G
            # producers
            publish_n = self._run_merge_wave(p, n_total, prefix,
                                             report, stats)
        if pipelined:
            try:
                if wave:
                    # seal l0 with the final producer count (splits
                    # included) so merge workers drain the tail and stop
                    # watching, then collect the concurrent wave
                    self.registry.finish_partial(
                        p.sem_hash, stream="l0", n_producers=n_total)
                    merge_thread.join()
                    err = merge_box.get("error")
                    if err is not None:
                        raise err
                    publish_n = merge_box["publish_n"]
                self.registry.finish_partial(p.sem_hash,
                                             n_producers=publish_n)
            except BaseException:
                self.registry.abort_partial(p.sem_hash)
                raise
        self._record_calibration(p, report)
        self.registry.publish(
            p.sem_hash, prefix=prefix, n_fragments=publish_n,
            partitioning=part_dict, schema=p.output_schema,
            stats=self._manifest_stats(report),
            cost_cents=report.cost_cents)
        self.observer.on_pipeline_complete(self.query_id, report)
        return report

    def _merge_wave_pipelined(self, p: Pipeline, prefix: str,
                              report: PipelineReport, stats: QueryStats,
                              box: dict) -> None:
        """Merge-wave launcher thread (pipelined multilevel exchange):
        waits until the admission fraction of l0 partitions has landed,
        then runs the wave *concurrently* with the producer tail — its
        workers top up straight from the l0 manifest."""
        try:
            gate = self._source_gates.get(p.sem_hash) or {}
            self.registry.await_source_ready(
                p.sem_hash, fraction=self.config.pipeline_start_fraction,
                cost_model=self.cost_model,
                stream="l0", cancel_check=self._check_cancel,
                timeout_s=self.config.pipelined_wait_timeout_s,
                min_published_at=gate.get("floor"))
            box["publish_n"] = self._run_merge_wave(
                p, p.n_fragments, prefix, report, stats, pipelined=True)
        except BaseException as e:      # surfaced after join
            box["error"] = e

    # -- SLO-aware scan-fleet sizing (service tier) ---------------------------
    def _apply_slo_fleet(self, p: Pipeline,
                         report: PipelineReport) -> None:
        """Re-size a *scan* pipeline's fleet against the query's
        per-stage deadline budget (scan pipelines have no upstream
        manifests, so the barrier reoptimizer skips them): a tight
        budget escalates toward one worker per scan unit, a loose one
        shrinks to the dollar-minimal fleet. ``fleet_cap`` (degraded
        tenant dispatch) clamps unconditionally."""
        if not p.scan_units:
            return
        if self._stage_budget_s is None and self.fleet_cap is None:
            return
        f0 = p.params.n_fragments
        cap = min(len(p.scan_units), self.admission.quota)
        if self.fleet_cap is not None:
            cap = min(cap, max(self.fleet_cap, 1))
        if self._stage_budget_s is not None:
            n = self.cost_model.optimal_fleet(
                int(p.input_bytes),
                latency_budget_s=self._stage_budget_s, max_workers=cap)
        else:
            n = min(f0, cap)
        if n == f0:
            return
        p.params.n_fragments = n
        report.n_fragments = n
        a = {"kind": "deadline_fleet", "from": f0, "to": n,
             "latency_budget_s": self._stage_budget_s,
             "fleet_cap": self.fleet_cap}
        report.adaptations = list(report.adaptations) + [a]
        self.observer.on_adaptation(self.query_id, p.pid, a)

    # -- multi-level exchange: injected merge wave ----------------------------
    COMBINE_GATE_FRACTION = 0.9

    def _combine_gate(self, report: PipelineReport) -> bool:
        """Per-worker partial aggregation in the merge wave pays off only
        when keys repeat: gate on the KMV sketches' estimated group/key
        cardinality vs the observed row count."""
        ps = report.partition_stats
        if not ps:
            return False
        rows = sum(s["rows"] for s in ps)
        if rows <= 0:
            return False
        distinct = kmv_estimate(kmv_merge([s["kmv"] for s in ps]))
        return distinct <= self.COMBINE_GATE_FRACTION * rows

    def _run_merge_wave(self, p: Pipeline, producers: int, prefix: str,
                        report: PipelineReport, stats: QueryStats, *,
                        pipelined: bool = False) -> int:
        """Run the multi-level exchange's merge wave: G = ⌈√producers⌉
        workers re-partition the producers' combined l0 intermediates
        into the final G×n_dest grid, re-combining mergeable
        partial-aggregate states when the KMV gate passes. Returns G
        (the published producer count).

        Barrier mode runs the wave serially after the producer fleet on
        the *barrier-drained* l0. Pipelined mode runs it concurrently
        with the producer tail: wave specs carry the l0 manifest key, so
        each merge worker starts on its group's available l0 objects and
        tops up until the stream seals."""
        cfg = self.config
        G = exchange.merge_group_count(producers)
        op = p.op["child"] if p.op.get("t") == "final" else p.op
        combine = exchange.combine_spec(op)
        if combine is not None:
            # pipelined: gated on whatever producer stats have landed so
            # far — a pilot estimate of key repetition (rows-identical
            # either way; combine only changes intermediate bytes)
            with self._metrics_lock:
                gate = self._combine_gate(report)
            if not gate:
                combine = None
        part = p.partitioning
        grid = {"kind": "hash", "keys": list(part.keys),
                "n_dest": part.n_dest, "tier": part.tier,
                "strategy": "direct"}
        mop_extra = {}
        on_all = None
        if pipelined:
            # the consumer-facing main stream: downstream admission
            # gates on the wave's G partitions, not the l0 producers
            self.registry.begin_partial(
                p.sem_hash, n_producers=G, prefix=prefix,
                partitioning=dict(p.partitioning.to_dict(),
                                  layout=exchange.get_strategy(
                                      part.strategy).layout),
                schema=p.output_schema)
            mop_extra = {
                "manifest_key": self.registry.partial_key(p.sem_hash,
                                                          "l0"),
                "wait_timeout_s": cfg.pipelined_wait_timeout_s}

            def on_all() -> None:
                self.registry.mark_all_submitted(p.sem_hash, G)
        specs = [{
            "query_id": p.sem_hash, "pipeline": p.pid, "fragment": j,
            "n_fragments": G,
            "op": {"t": "merge_exchange", "l0_prefix": f"{prefix}/l0",
                   "producers": producers, "group": j, "n_groups": G,
                   "keys": list(part.keys), "n_dest": part.n_dest,
                   "combine": combine, "schema": p.output_schema,
                   "tier": part.tier, "l0_tier": part.l0_tier,
                   **mop_extra},
            "scan_units": [],
            "output": {"prefix": prefix, "partitioning": grid,
                       "schema": p.output_schema},
            "sources": {},
        } for j in range(G)]
        mreport = PipelineReport(p.pid, p.sem_hash, G)
        dispatch = self.platform.dispatch_time_s(
            G, two_level=G >= cfg.two_level_threshold)
        extra: list[dict] = []
        results = self.platform.invoke_many(
            self.handler, specs, pipeline=p.pid,
            cancel_check=self._check_cancel, priority=self.priority,
            group=self.tenant,
            run=lambda spec: self._run_fragment(p, spec, mreport, stats,
                                                extra),
            on_all_submitted=on_all)
        if pipelined:
            # the wave overlapped the producer tail: fold it into the
            # pipeline's sim window as a concurrent phase starting at
            # the l0 admission fraction, not a serial one. Safe to read
            # the producer figures here — wave workers only finish after
            # the l0 seal, which follows the producer accounting.
            start = CostModel.pipeline_start_offset_s(
                report.producer_completions,
                self._admission_fraction(report.producer_completions))
            sched = self._sim_schedule([r.sim_runtime_s
                                        for r in results])
            with self._metrics_lock:
                report.sim_s = max(
                    report.sim_s,
                    report.dispatch_s + start + dispatch + max(sched)
                    + cfg.response_poll_overhead_s)
                # downstream admission keys on the wave's completions
                report.producer_completions = [start + dispatch + t
                                               for t in sched]
        else:
            report.sim_s += (dispatch
                             + self._sim_makespan([r.sim_runtime_s
                                                   for r in results])
                             + cfg.response_poll_overhead_s)
        report.merge_fragments = G
        report.attempts += mreport.attempts
        report.transient_failures += mreport.transient_failures
        report.requests += mreport.requests
        report.bytes_read += mreport.bytes_read
        report.bytes_written += mreport.bytes_written
        report.exchange_requests += mreport.exchange_requests
        report.footer_cache_hits += mreport.footer_cache_hits
        report.cost_cents += mreport.cost_cents
        if mreport.pipelined:   # wave workers topped up from partial l0
            report.pipelined = True
            report.topups += mreport.topups
            report.overlap_saved_s += mreport.overlap_saved_s
        # the wave's grid is what consumers read: its observations
        # supersede the producers' l0 intermediates in the manifest
        report.rows_out = mreport.rows_out
        report.partition_stats = mreport.partition_stats
        if part.l0_tier:
            # express-tier l0 intermediates are billed at-rest until
            # deleted: the wave has drained them, so enforce the TTL
            # now (object DELETEs are unbilled)
            self.store.delete_prefix(f"{prefix}/l0/")
        return G

    def _record_calibration(self, p: Pipeline,
                            report: PipelineReport) -> None:
        """Persist the observed selectivity of a pure scan→filter chain
        (cross-query calibration; see repro.sql.calibration)."""
        if self.calibration is None or not p.scan_units:
            return
        if report.semijoin is not None and report.semijoin.get("applied"):
            # a pushed semi-join filter killed rows below the scan —
            # rows_out no longer reflects the predicate's selectivity
            return
        sig = scan_filter_signature(
            p.op["child"] if p.op.get("t") == "final" else p.op)
        if sig is None:
            return
        table, pred_key = sig
        base = self.catalog.table(table).rows
        if base > 0:
            self.calibration.record(table, pred_key,
                                    report.rows_out / base)

    def _publish_partial(self, p: Pipeline, spec: dict,
                         res: InvocationResult) -> None:
        """Stream one successful fragment's landed output (stats +
        layout) into the pipeline's partial manifest — the
        per-partition publish event that replaces the stage barrier.
        Multilevel producers stream into the l0 manifest (the merge
        wave's input); merge-wave fragments and everything else into the
        consumer-facing main stream."""
        if not self.config.pipelined or res.payload is None:
            return
        part = spec["output"]["partitioning"]
        stream = "partial"
        if spec["op"].get("t") != "merge_exchange" \
                and part.get("kind") == "hash" \
                and exchange.get_strategy(part["strategy"]).merge_workers(
                    spec["n_fragments"]):
            stream = "l0"
        s = res.payload["stats"]
        ps = res.payload.get("partition_stats") or []
        info = {"rows": s["rows_out"], "bytes": s["bytes_written"],
                # producer wall time: the admission gate's cost model
                # reads the landed walls as a pilot of the fleet's skew
                "wall_s": float(res.sim_runtime_s),
                "partition_rows": [d["rows"] for d in ps],
                "partition_bytes": [d["bytes"] for d in ps],
                "partition_write_s": [float(d.get("write_s", 0.0))
                                      for d in ps]}
        if res.payload.get("bloom") is not None:
            # semi-join filter shard: this producer's Bloom words plus
            # its distinct-key sketch, streamed through the partial
            # manifest so a probe can merge the sealed filter (and pilot
            # the cost gate) without waiting for the complete entry
            info["bloom"] = res.payload["bloom"]
            info["distinct_kmv"] = [int(x) for x in kmv_merge(
                [d["kmv"] for d in ps])] if ps else []
        n = None
        if spec["fragment"] >= spec["n_fragments"]:
            n = spec["fragment"] + 1    # reassignment split grew the fleet
        self.registry.publish_partial(p.sem_hash, spec["fragment"], info,
                                      stream=stream, n_producers=n)

    def _pilot_scan(self, p: Pipeline, report: PipelineReport,
                    stats: QueryStats) -> None:
        """Scan-selectivity pilot (pipelined mode): before an
        *uncalibrated* scan→filter fleet launches, probe one scan unit
        into a scratch prefix, record the observed selectivity in the
        cross-query calibration store, and correct the row estimate the
        stage was planned on. The probe is throwaway — its rows are not
        counted (the fleet re-reads its unit), only its cost and sim
        time are billed — and best-effort: on failure the fleet simply
        runs on the static estimate."""
        cfg = self.config
        if self.calibration is None or not cfg.adaptive \
                or not p.scan_units \
                or len(p.scan_units) < cfg.pilot_scan_min_units \
                or p.n_fragments < 2:
            return
        op = p.op["child"] if p.op.get("t") == "final" else p.op
        sig = scan_filter_signature(op)
        if sig is None:
            return
        table, pred_key = sig
        if self.calibration.lookup(table, pred_key) is not None:
            return                      # already calibrated: no probe
        spec = {
            "query_id": p.sem_hash, "pipeline": p.pid, "fragment": 0,
            "n_fragments": 1, "op": op,
            "scan_units": p.scan_units[:1],
            "output": {"prefix": f"results/{p.sem_hash}/pilot",
                       "partitioning": {"kind": "single"},
                       "schema": p.output_schema},
            "sources": {},
        }
        self.admission.acquire(1, priority=self.priority,
                               group=self.tenant)
        try:
            # attempt=300: outside the fleet's retry (0..2) and
            # straggler-duplicate (100+) attempt ranges, so deterministic
            # fault plans target the probe and the fleet independently
            res = self._invoke(p, spec, report, stats, attempt=300,
                               count_payload=False)
        finally:
            self.admission.release(1)
        if res.error is not None or res.payload is None:
            return
        s = res.payload["stats"]
        if s["rows_in"] <= 0:
            return
        sel = s["rows_out"] / s["rows_in"]
        self.calibration.record(table, pred_key, sel)
        base = self.catalog.table(table).rows
        est0 = p.params.est_out_rows
        p.params.est_out_rows = int(sel * base)
        report.est_rows = p.params.est_out_rows
        # the probe runs serially before the fleet: bill its sim time
        # (report.sim_s is accumulated, not assigned, downstream)
        report.sim_s += (self.platform.dispatch_time_s(1,
                                                       two_level=False)
                         + res.sim_runtime_s)
        a = {"kind": "pilot_scan", "unit_rows": int(s["rows_in"]),
             "selectivity": round(sel, 6),
             "est_rows_from": est0, "est_rows_to": p.params.est_out_rows}
        report.adaptations = list(report.adaptations) + [a]
        self.observer.on_adaptation(self.query_id, p.pid, a)

    # -- semi-join filter pushdown (probe side) -------------------------------
    def _semijoin_filter(self, p: Pipeline, report: PipelineReport,
                         stats: QueryStats) -> dict | None:
        """Resolve an annotated probe pipeline's build-side Bloom filter
        into the fragment-spec payload, or None to launch unfiltered.

        Three gates run in order: a pilot peek at the build's *partial*
        manifest re-decides the plan-time verdict from extrapolated
        observed cardinality (an early revoke skips the wait entirely);
        a bounded wait for the *sealed* filter — a partial filter is
        never applied, missing producers would mean false negatives; and
        a final re-gate on the sealed manifest's exact build figures.
        Every verdict only mutates ``p.params.semijoin`` — the sem hash
        folded the build side at plan time, so filtered and unfiltered
        runs share one cache entry."""
        sj = p.params.semijoin
        if not sj:
            return None
        cfg = self.config
        build_sem = sj["build"]

        def record(a: dict | None) -> None:
            if a:
                report.adaptations = list(report.adaptations) + [a]
                self.observer.on_adaptation(self.query_id, p.pid, a)

        if cfg.pipelined and cfg.adaptive:
            # pilot-K peek: the first landed build producers,
            # extrapolated ×(n/k) — cheap enough to revoke a filter
            # before paying the sealed-filter wait
            for stream in ("l0", "partial"):
                man = self.registry.partial_manifest(build_sem,
                                                     stream=stream)
                infos = list((man or {}).get("done", {}).values())
                if not infos:
                    continue
                n = max(int(man.get("n_producers") or 0), len(infos), 1)
                scale = n / len(infos)
                rows = sum(i.get("rows", 0) for i in infos) * scale
                sketches = [i["distinct_kmv"] for i in infos
                            if i.get("distinct_kmv")]
                distinct = int(kmv_estimate(kmv_merge(sketches)) * scale) \
                    if sketches else None
                record(self.reoptimizer.semijoin_decision(
                    p, build_rows=rows, build_distinct=distinct))
                break
        if not sj["enabled"]:
            report.semijoin = dict(sj, applied=False)
            return None

        words, build_rows, build_distinct = \
            self._await_build_filter(build_sem)
        if words is None:
            report.semijoin = dict(sj, applied=False,
                                   reason="filter unavailable")
            return None
        if cfg.adaptive and build_rows is not None:
            record(self.reoptimizer.semijoin_decision(
                p, build_rows=float(build_rows),
                build_distinct=build_distinct))
            if not sj["enabled"]:
                report.semijoin = dict(sj, applied=False)
                return None

        from repro.kernels import bloom as bloomlib
        n_words = len(words) // 4
        wire = {"bits": 32 * n_words, "k": bloomlib.BLOOM_K,
                "mode": sj["mode"], "words": words}
        kept = sj["est_match"] + sj["fpr"] * (1.0 - sj["est_match"])
        report.semijoin = dict(
            sj, applied=True,
            est_killed=int(sj["est_rows"] * max(0.0, 1.0 - kept)))
        if not cfg.pipelined:
            # barrier mode ran the build first in this same stage (see
            # _stage_order), but the stage's sim window is max over its
            # members — waiting for the filter made this probe serial
            # behind the build, so charge the build's window here
            b = next((r for r in stats.pipelines
                      if r.sem_hash == build_sem and not r.cache_hit),
                     None)
            if b is not None:
                report.sim_s += b.sim_s
        return {"key": list(sj["key"]), "bits": wire["bits"],
                "k": wire["k"], "mode": wire["mode"], "words": words}

    def _await_build_filter(self, build_sem: str
                            ) -> tuple[bytes | None, int | None,
                                       int | None]:
        """Merged Bloom words of a *sealed* build exchange, with the
        exact observed build rows/distinct for the final re-gate.

        Resolution order: the complete registry entry's published
        ``semijoin_bloom`` (barrier mode; cached builds), else a sealed
        partial stream every one of whose producer records carries a
        filter shard (pipelined mode — the probe may assemble the
        filter the moment the stream seals, slightly before the entry
        publishes). Returns ``(None, None, None)`` on the bounded-wait
        timeout or an aborted build — the probe then launches
        unfiltered, which is always correct."""
        deadline = time.time() + self.config.semijoin_wait_timeout_s
        while True:
            entry = self.registry.lookup(build_sem)
            if entry is not None:
                st = entry.get("stats") or {}
                wire = st.get("semijoin_bloom")
                if wire is None:
                    return None, None, None
                pd = st.get("partition_distinct")
                return (wire["words"], st.get("rows_out"),
                        int(sum(pd)) if pd else None)
            for stream in ("l0", "partial"):
                man = self.registry.partial_manifest(build_sem,
                                                     stream=stream)
                if man is None:
                    continue
                if man.get("aborted"):
                    return None, None, None
                if not man.get("complete"):
                    continue
                infos = list((man.get("done") or {}).values())
                if not infos or not all(i.get("bloom") for i in infos):
                    break       # sealed but unfiltered build
                words = None
                for i in infos:
                    w = np.frombuffer(i["bloom"], np.uint32)
                    words = w if words is None else words | w
                sketches = [i["distinct_kmv"] for i in infos
                            if i.get("distinct_kmv")]
                distinct = int(kmv_estimate(kmv_merge(sketches))) \
                    if sketches else None
                rows = int(sum(i.get("rows", 0) for i in infos))
                return words.tobytes(), rows, distinct
            if time.time() >= deadline:
                return None, None, None
            self._check_cancel()
            time.sleep(0.02)

    def _manifest_stats(self, report: PipelineReport) -> dict:
        """The exchange-manifest statistics published with a pipeline's
        registry entry: totals plus the per-partition (rows, bytes,
        distinct-key estimate) observations the adaptive re-optimizer
        feeds on at the next stage barrier."""
        stats = {"rows_out": report.rows_out,
                 "bytes_out": report.bytes_written}
        ps = report.partition_stats
        if ps is not None:
            stats["partition_rows"] = [s["rows"] for s in ps]
            stats["partition_bytes"] = [s["bytes"] for s in ps]
            stats["partition_distinct"] = [kmv_estimate(s["kmv"])
                                           for s in ps]
            # observed per-partition write latencies: the straggler-aware
            # LPT weights (slow storage partitions get dedicated workers)
            stats["partition_write_s"] = [float(s.get("write_s", 0.0))
                                          for s in ps]
            # bytes_out is what a consumer reads — the materialized
            # partitions, not (for multi-level) l0 intermediates too
            stats["bytes_out"] = int(sum(s["bytes"] for s in ps))
        if report.semijoin_bloom is not None:
            # the sealed merged filter: probes of cached builds (and
            # barrier-mode probes) pick it up from the complete entry
            stats["semijoin_bloom"] = report.semijoin_bloom
        return stats

    def _sim_schedule(self, runtimes: list[float]) -> list[float]:
        """Per-fragment simulated completion offsets under per-slot
        admission: list scheduling over ``quota`` slots — each fragment
        starts the moment a slot frees (never on a wave boundary). The
        k-th order statistic of this list is what pipelined downstream
        admission gates on."""
        if not runtimes:
            return []
        slots = [0.0] * min(self.admission.quota, len(runtimes))
        heapq.heapify(slots)
        ends = []
        for r in runtimes:
            t = heapq.heappop(slots) + r
            ends.append(t)
            heapq.heappush(slots, t)
        return ends

    def _sim_makespan(self, runtimes: list[float]) -> float:
        """Simulated completion of a whole fleet (see _sim_schedule).
        With quota ≥ fleet size this is simply ``max(runtimes)``."""
        ends = self._sim_schedule(runtimes)
        return max(ends) if ends else 0.0

    # -- fragment execution with retries/reassignment -----------------------------
    def _run_fragment(self, p: Pipeline, spec: dict,
                      report: PipelineReport, stats: QueryStats,
                      extra_fragments: list[dict]) -> InvocationResult:
        """Run one fragment to success (bounded retries, reassignment).

        Runs inside the platform executor, holding exactly one admission
        slot for its whole lifetime — retries and the reassignment's
        extra worker reuse that slot, so no new admission is requested.
        Thread-safe: many fragments of one pipeline run this
        concurrently.
        """
        attempt = 0
        failed_runtime = 0.0    # failed attempts serialize before success
        extra_runtime = 0.0     # reassigned worker, parallel to the retry
        while True:
            res = self._invoke(p, spec, report, stats, attempt=attempt)
            if res.error is None:
                # the reassigned extra worker races the retry in
                # parallel; the slower of the two is the critical path
                res.sim_runtime_s = failed_runtime + max(
                    res.sim_runtime_s, extra_runtime)
                # per-partition publish: stream this fragment's landed
                # output into the pipeline's partial manifest so gated
                # consumers start/top up before the fleet finishes
                self._publish_partial(p, spec, res)
                return res
            failed_runtime += res.sim_runtime_s
            with self._metrics_lock:
                report.transient_failures += 1
            attempt += 1
            if attempt >= self.config.max_attempts:
                raise QueryAborted(
                    f"pipeline {p.pid} fragment {spec['fragment']} failed "
                    f"{attempt} times",
                    post_mortem={"pipeline": p.pid,
                                 "fragment": spec["fragment"],
                                 "attempts": attempt,
                                 "last_error": res.error})
            # every retry draws from the one per-query budget; a fleet
            # burning through it proves the infrastructure is down, not
            # hiccuping — surface a permanent, typed failure with the
            # last transient cause chained
            if not self.retry_budget.try_spend():
                cause = TransientInfraError(
                    res.error or "transient worker failure")
                raise RetryBudgetExhausted(
                    f"pipeline {p.pid} fragment {spec['fragment']}: "
                    f"per-query retry budget exhausted "
                    f"({self.retry_budget.budget} retries spent)",
                    last_error=cause,
                    spent=self.retry_budget.spent) from cause
            self.observer.on_retry(self.query_id, p.pid, spec["fragment"],
                                   attempt)
            # bounded exponential backoff with full jitter before the
            # re-invoke (decorrelates a fleet retrying one throttled
            # prefix); delays are wall-clock and deliberately tiny
            time.sleep(self.config.retry.backoff_s(attempt))
            # Reassignment: after two failures, split a multi-unit
            # fragment's inputs across an additional fresh worker that
            # runs in parallel with the (now half-sized) retry.
            if attempt >= 2 and len(spec["scan_units"]) > 1:
                with self._metrics_lock:
                    n_extra = len(extra_fragments)
                    spec, extra = self._split_fragment(p, spec, n_extra)
                    extra_fragments.append(extra)
                    report.reassignments += 1
                eres = self._invoke(p, extra, report, stats,
                                    attempt=attempt)
                if eres.error is not None:
                    raise QueryAborted(
                        "reassigned fragment failed",
                        post_mortem={"pipeline": p.pid,
                                     "fragment": extra["fragment"]})
                self._publish_partial(p, extra, eres)
                extra_runtime = max(extra_runtime, eres.sim_runtime_s)

    def _split_fragment(self, p: Pipeline, spec: dict, n_extra: int):
        units = spec["scan_units"]
        half = len(units) // 2
        new_frag = p.n_fragments + n_extra
        second = dict(spec, scan_units=units[half:], fragment=new_frag)
        # narrow the original dict in place: the pipeline's shared specs
        # map must reflect the split, or a later straggler re-trigger of
        # this fragment would re-run the full pre-split input and
        # overwrite its output object with rows the extra fragment also
        # produced (duplicated rows on fetch)
        spec["scan_units"] = units[:half]
        return spec, second

    def _invoke(self, p: Pipeline, spec: dict, report: PipelineReport,
                stats: QueryStats, *, attempt: int,
                count_payload: bool = True) -> InvocationResult:
        res = self.platform.invoke(self.handler, spec, pipeline=p.pid,
                                   fragment=spec["fragment"],
                                   attempt=attempt)
        tier_ops = {}
        with self._metrics_lock:
            report.attempts += 1
            if res.payload is not None:
                s = res.payload["stats"]
                tier_ops = s["tier_ops"]    # real storage ops: billed
                if count_payload:           # …but a duplicate's output
                    report.rows_out += s["rows_out"]    # repeats rows
                    report.bytes_read += s["bytes_read"]
                    report.bytes_written += s["bytes_written"]
                    report.requests += s["requests"]
                    report.exchange_requests += _exchange_requests(
                        spec, tier_ops)
                    report.footer_cache_hits += s.get(
                        "footer_cache_hits", 0)
                    report.semijoin_killed += s.get("semijoin_killed", 0)
                    bw = res.payload.get("bloom")
                    if bw is not None and spec.get("bloom"):
                        self._accumulate_bloom(report, bw, spec["bloom"])
                    if s.get("kernel"):
                        report.kernel_fragments += 1
                    if s.get("pipelined"):
                        # consumer-side pipelined read observations:
                        # first byte = earliest fragment's first batch
                        report.pipelined = True
                        report.topups += s.get("topups", 0)
                        report.overlap_saved_s += s.get(
                            "overlap_saved_s", 0.0)
                        fi = float(s.get("first_input_s", 0.0))
                        if report.first_input_s == 0.0 \
                                or fi < report.first_input_s:
                            report.first_input_s = fi
                    self._merge_partition_stats(
                        report, res.payload.get("partition_stats"))
            cost = self.cost_model.worker_cost(res.sim_runtime_s,
                                               tier_ops)
            report.cost_cents += cost.total_cents
            stats.cost.merge(cost)
        return res

    def _accumulate_bloom(self, report: PipelineReport, words: bytes,
                          bloom_spec: dict) -> None:
        """OR one build fragment's Bloom words into the pipeline's
        merged filter (caller holds the metrics lock). Fragments share
        one spec-time sizing, so a word-count mismatch can only come
        from a foreign stale response — dropped defensively."""
        cur = report.semijoin_bloom
        if cur is None:
            report.semijoin_bloom = {
                "bits": 8 * len(words), "k": bloom_spec["k"],
                "mode": bloom_spec["mode"], "words": words}
        elif len(cur["words"]) == len(words):
            merged = (np.frombuffer(cur["words"], np.uint32)
                      | np.frombuffer(words, np.uint32))
            cur["words"] = merged.tobytes()

    def _merge_partition_stats(self, report: PipelineReport,
                               ps: list | None) -> None:
        """Fold one worker's per-destination stats into the pipeline's
        manifest accumulator (caller holds the metrics lock)."""
        if not ps:
            return
        if report.partition_stats is None:
            report.partition_stats = [
                {"rows": 0, "bytes": 0, "kmv": [], "write_s": 0.0}
                for _ in ps]
        if len(ps) != len(report.partition_stats):  # defensive
            return
        for acc, s in zip(report.partition_stats, ps):
            acc["rows"] += s["rows"]
            acc["bytes"] += s["bytes"]
            acc["kmv"] = kmv_merge([acc["kmv"], s["kmv"]])
            acc["write_s"] += float(s.get("write_s", 0.0))

    # -- plumbing -------------------------------------------------------------
    def _resolve_sources(self, op: dict, *,
                         pipelined: bool = False) -> dict:
        sources: dict[str, dict] = {}

        def collect(o: dict):
            if o["t"] == "scan_exchange":
                sem = o["source"]
                if sem not in sources:
                    if pipelined:
                        sources[sem] = self._await_source(sem)
                    else:
                        entry = self.registry.lookup(sem)
                        if entry is None:
                            raise QueryAborted(
                                f"upstream result {sem} missing",
                                post_mortem={"source": sem})
                        sources[sem] = entry
            for k in ("child", "probe", "build"):
                if k in o:
                    collect(o[k])
        collect(op)
        return sources

    def _await_source(self, sem: str) -> dict:
        """Pipelined consumer admission: block until the upstream
        pipeline is barrier-complete (returns its registry entry) or
        past the partial-admission gate (returns a pilot-K
        pseudo-entry). An aborted upstream stream is waited out — a
        peer that re-claims the failed execution resets it — until our
        own cancel check (sibling abort) or the wait deadline fires."""
        cfg = self.config
        deadline = time.time() + cfg.pipelined_wait_timeout_s
        floor = self._await_source_gate(sem, deadline)
        while True:
            try:
                entry = self.registry.await_source_ready(
                    sem, fraction=cfg.pipeline_start_fraction,
                    cost_model=self.cost_model,
                    cancel_check=self._check_cancel,
                    timeout_s=max(deadline - time.time(), 0.01),
                    min_published_at=floor)
            except QueryCancelled:
                raise
            except TimeoutError as e:
                raise QueryAborted(
                    f"upstream result {sem} not ready: {e}",
                    post_mortem={"source": sem}) from e
            except RuntimeError:
                self._check_cancel()
                if time.time() >= deadline:
                    raise QueryAborted(
                        f"upstream producer of {sem} aborted",
                        post_mortem={"source": sem})
                time.sleep(0.05)
                continue
            if entry is not None:
                return entry
            man = self.registry.partial_manifest(sem)
            if man is None:
                # sealed and retired between the gate and this read —
                # the barrier-complete entry must exist now
                entry = self.registry.lookup(sem)
                if entry is not None and (
                        floor is None
                        or entry.get("published_at", 0.0) >= floor):
                    return entry
                raise QueryAborted(f"upstream result {sem} missing",
                                   post_mortem={"source": sem})
            return self._partial_source_entry(sem, man)

    def _await_source_gate(self, sem: str,
                           deadline: float) -> float | None:
        """Block until this plan's producer of ``sem`` has committed to
        a path — a cache hit (any published entry is valid) or a fresh
        execution (only entries published after its stream reset are).
        Consulting the registry earlier races the producer thread: a
        stale complete entry or sealed partial manifest left by an
        earlier query on the same store describes a *different*
        physical layout, and reading through it duplicates or drops
        rows. Returns the freshness floor (``None`` = any entry)."""
        gate = self._source_gates.get(sem)
        if gate is None:
            # not produced by this plan (pre-registered external
            # source): whatever the registry holds is authoritative
            return None
        while not gate["event"].wait(0.05):
            self._check_cancel()
            if time.time() >= deadline:
                raise QueryAborted(
                    f"upstream producer of {sem} never started",
                    post_mortem={"source": sem})
        return gate["floor"]

    def _open_source_gate(self, sem: str,
                          floor: float | None = None) -> None:
        gate = self._source_gates.get(sem)
        if gate is not None:
            gate["floor"] = floor
            gate["event"].set()

    def _partial_source_entry(self, sem: str, man: dict) -> dict:
        """Pseudo registry entry for a partially available source: the
        pilot-K estimate — the first K landed producers' stats summed
        and extrapolated ×(n/K) — plus the manifest key consumer
        fragments top up from. Flagged ``partial`` so the re-optimizer
        skips decisions that need the full fleet's observations (e.g.
        empty-partition pruning: a partition empty in the pilot subset
        may still receive rows from later producers)."""
        cfg = self.config
        done = sorted(int(f) for f in (man.get("done") or {}))
        n = max(int(man.get("n_producers") or 0), len(done), 1)
        k = min(len(done), max(cfg.pilot_k, 1))
        est: dict = {}
        if k > 0:
            infos = [man["done"][str(f)] for f in done[:k]]
            scale = n / k
            est = {"rows_out": int(sum(i.get("rows", 0)
                                       for i in infos) * scale),
                   "bytes_out": int(sum(i.get("bytes", 0)
                                        for i in infos) * scale)}
            plists = [i.get("partition_rows") or [] for i in infos]
            D = len(plists[0]) if plists else 0
            if D and all(len(x) == D for x in plists):
                blists = [i.get("partition_bytes") or [] for i in infos]
                wlists = [i.get("partition_write_s") or []
                          for i in infos]
                if all(len(x) == D for x in blists) \
                        and all(len(x) == D for x in wlists):
                    est["partition_rows"] = [
                        int(sum(x[d] for x in plists) * scale)
                        for d in range(D)]
                    est["partition_bytes"] = [
                        int(sum(x[d] for x in blists) * scale)
                        for d in range(D)]
                    # per-byte skew ratios survive the uniform
                    # extrapolation factor, so plain sums suffice here
                    est["partition_write_s"] = [
                        float(sum(x[d] for x in wlists))
                        for d in range(D)]
        return {
            "complete": False, "pipelined": True,
            "partial": {"done": len(done), "of": n, "pilot_k": k},
            "prefix": man.get("prefix") or f"results/{sem}",
            "n_fragments": n,
            "partitioning": man.get("partitioning") or {},
            "schema": man.get("schema"),
            "stats": est,
            "manifest_key": self.registry.partial_key(sem),
            "wait_timeout_s": cfg.pipelined_wait_timeout_s,
        }

    def _fragment_spec(self, p: Pipeline, f: int, n: int, prefix: str,
                       sources: dict, op: dict | None = None, *,
                       bloom: dict | None = None,
                       semijoin: dict | None = None) -> dict:
        spec = {
            "query_id": p.sem_hash,
            "pipeline": p.pid,
            "fragment": f,
            "n_fragments": n,
            "op": op if op is not None else p.op,
            "scan_units": p.scan_units[f::n],
            "output": {"prefix": prefix,
                       "partitioning": p.partitioning.to_dict(),
                       "schema": p.output_schema},
            "sources": sources,
        }
        if bloom is not None:
            spec["bloom"] = bloom
        if semijoin is not None:
            spec["semijoin"] = semijoin
        if p.params.partition_assignment is not None:
            spec["read_partitions"] = p.params.partition_assignment[f]
        if p.params.source_partitions:
            spec["source_partitions"] = dict(p.params.source_partitions)
        return spec


def _stage_order(plan: PhysicalPlan, stage: list[int]) -> list[int]:
    """Same-stage execution order: pipelines that emit a semi-join
    filter (build sides) run before their same-stage probes, so a
    barrier-mode probe finds the sealed filter instead of waiting out
    its timeout. Same-stage pipelines are mutually independent, so the
    reorder never violates a dependency."""
    return sorted(stage, key=lambda pid: (
        plan.pipelines[pid].params.bloom is None, pid))


def _exchange_requests(spec: dict, tier_ops: dict) -> int:
    """Observed producer-side exchange requests of one worker response:
    PUTs on the exchange tier (and, multilevel, the l0 tier the combined
    intermediates were routed to), plus (merge-wave fragments) the l0
    reads — the figure EXPLAIN ANALYZE compares against the strategy's
    estimate."""
    part = spec["output"]["partitioning"]
    if part.get("kind") != "hash":
        return 0
    tier = part.get("tier", "s3-standard")
    l0_tier = part.get("l0_tier") or spec["op"].get("l0_tier") or tier
    n = (tier_ops.get(tier) or {}).get("put", 0)
    if l0_tier != tier:
        n += (tier_ops.get(l0_tier) or {}).get("put", 0)
    if spec["op"].get("t") == "merge_exchange":
        n += (tier_ops.get(l0_tier) or {}).get("get", 0)
    return n


def _op_kinds(op: dict) -> list[str]:
    kinds = [op["t"]]
    for k in ("child", "probe", "build"):
        if k in op:
            kinds.extend(_op_kinds(op[k]))
    return kinds


def _rows(n: int) -> str:
    return "?" if n < 0 else str(n)


def explain_plan(plan: PhysicalPlan) -> str:
    """Human-readable physical plan: stages, pipelines, fragment fleets."""
    lines = [f"physical plan · {len(plan.pipelines)} pipelines · "
             f"output {plan.output_names}"]
    for si, stage in enumerate(plan.stages()):
        lines.append(f"stage {si}:")
        for pid in stage:
            p = plan.pipelines[pid]
            role = " (root)" if pid == plan.root_pid else ""
            part = p.partitioning
            dest = (f"hash[{','.join(part.keys)}]×{part.n_dest} "
                    f"@{part.tier} ·{part.strategy}"
                    if part.kind == "hash" else "single")
            kern = ""
            if p.kernel:
                rl = p.kernel_roofline or {}
                tile = (f" block={rl['block_rows']}"
                        f" resident={rl['resident_rows']}"
                        f" ({rl['dominant']}-bound)" if rl else "")
                kern = f" · kernel={p.kernel}{tile}"
            lines.append(
                f"  pipeline {pid}{role} · sem={p.sem_hash[:10]} · "
                f"{p.n_fragments} workers · "
                f"in≈{p.input_bytes / 1e6:.1f}MB · "
                f"rows≈{_rows(p.params.est_out_rows)} · "
                f"out={dest}{kern}")
            lines.append("    ops: " + " → ".join(_op_kinds(p.op)[::-1]))
    return "\n".join(lines)


def _describe_adaptation(a: dict) -> str:
    kind = a.get("kind", "?")
    if kind == "fleet_resize":
        return (f"fleet_resize {a['from']}→{a['to']} workers "
                f"(observed {a['observed_bytes'] / 1e6:.2f}MB)")
    if kind == "broadcast_downgrade":
        return (f"broadcast_downgrade build={a['source'][:10]} "
                f"({a['observed_bytes'] / 1e6:.2f}MB ≤ "
                f"{a['budget_bytes'] / 1e6:.2f}MB)")
    if kind == "partition_prune":
        return (f"partition_prune {a['pruned']}/{a['of']} empty "
                f"(source {a['source'][:10]})")
    if kind == "exchange_retier":
        return f"exchange_retier {a['from']}→{a['to']}"
    if kind == "pilot_scan":
        return (f"pilot_scan sel={a['selectivity']:.4f} "
                f"(rows est {a['est_rows_from']}→{a['est_rows_to']})")
    if kind == "exchange_restrategy":
        return (f"exchange_restrategy {a['from']}→{a['to']} "
                f"(est {a['est_requests_from']}→{a['est_requests_to']} "
                f"reqs, {a['cents_from']:.4f}→{a['cents_to']:.4f}¢)")
    if kind in ("semijoin_adopt", "semijoin_revoke"):
        return (f"{kind} build_rows={a['build_rows']} "
                f"match={a['match_fraction']:.4f} "
                f"benefit={a['benefit_cents']:.4f}¢")
    return str(a)


def explain_analyze(plan: PhysicalPlan, stats: QueryStats) -> str:
    """EXPLAIN ANALYZE: the physical plan annotated with observed
    execution — est vs actual rows per pipeline, planned vs invoked
    fleets, and every barrier adaptation applied."""
    reports = {r.pid: r for r in stats.pipelines}
    lines = [f"explain analyze · {len(plan.pipelines)} pipelines · "
             f"sim {stats.sim_latency_s:.3f}s · "
             f"cost {stats.cost.total_cents:.4f}¢"]
    for si, stage in enumerate(plan.stages()):
        lines.append(f"stage {si}:")
        for pid in stage:
            p = plan.pipelines[pid]
            r = reports.get(pid)
            role = " (root)" if pid == plan.root_pid else ""
            if r is None:
                lines.append(f"  pipeline {pid}{role} · not executed")
                continue
            if r.cache_hit:
                tag = "dedup (shared in-flight execution)" if r.deduped \
                    else "cache hit"
                lines.append(
                    f"  pipeline {pid}{role} · {tag} · "
                    f"rows est≈{_rows(r.est_rows)}")
                continue
            workers = (f"{r.n_planned}→{r.n_fragments}"
                       if r.n_fragments != r.n_planned
                       else f"{r.n_fragments}")
            lines.append(
                f"  pipeline {pid}{role} · workers {workers} · "
                f"rows est≈{_rows(r.est_rows)} actual={r.rows_out} · "
                f"{r.requests} reqs · sim {r.sim_s:.3f}s")
            if r.exchange_strategy:
                wave = (f" · merge wave ×{r.merge_fragments}"
                        if r.merge_fragments else "")
                lines.append(
                    f"    exchange: {r.exchange_strategy} · reqs "
                    f"est≈{r.est_exchange_requests} "
                    f"actual={r.exchange_requests}{wave}")
            if r.semijoin is not None:
                sj = r.semijoin
                if sj.get("applied"):
                    lines.append(
                        f"    semijoin: pushed "
                        f"est≈{sj.get('est_killed', 0)} "
                        f"actual={r.semijoin_killed} rows killed · "
                        f"build={sj['build'][:10]} · "
                        f"fpr≈{sj.get('fpr', 0.0):.4f}")
                else:
                    lines.append(
                        f"    semijoin: not pushed "
                        f"({sj.get('reason', 'cost gate')}) · "
                        f"build={sj['build'][:10]}")
            if r.pipelined:
                pilot = f" · pilot-K={r.pilot_k}" if r.pilot_k else ""
                lines.append(
                    f"    pipelined: window "
                    f"{r.sim_start_s:.3f}→{r.sim_end_s:.3f}s · "
                    f"first input {r.first_input_s:.3f}s · "
                    f"{r.topups} top-ups · overlap saved "
                    f"{r.overlap_saved_s:.3f}s{pilot}")
            if r.kernel:
                rl = r.kernel_roofline or {}
                tile = (f" · block={rl['block_rows']} "
                        f"resident={rl['resident_rows']} "
                        f"AI={rl['arithmetic_intensity']} "
                        f"({rl['dominant']}-bound)" if rl else "")
                lines.append(
                    f"    kernel: {r.kernel} × "
                    f"{r.kernel_fragments} fragments{tile}")
            elif r.kernel_miss_reason:
                lines.append(
                    f"    kernel: generic jnp — {r.kernel_miss_reason}")
            lines.append("    ops: " + " → ".join(_op_kinds(p.op)[::-1]))
            for a in r.adaptations:
                lines.append("    adapted: " + _describe_adaptation(a))
    return "\n".join(lines)
