"""Runtime re-optimization at pipeline barriers (paper sections 3.2/3.3).

The paper's core claim is that a serverless query processor stays
competitive only through *adaptive and cost-aware* techniques: compile-time
estimates decide how a pipeline would run, but every decision downstream of
a stage barrier can be re-made once upstream pipelines have actually run.
Workers emit per-partition output statistics (rows, bytes, distinct-key KMV
sketches) into the exchange manifest (the registry entry published per
pipeline); before the engine launches a downstream pipeline, the
:class:`Reoptimizer` replaces the planner's guesses with those observations:

  * **fleet re-sizing** — the fragment count is re-derived by minimizing
    ``CostModel`` dollars subject to a latency budget
    (``CostModel.optimal_fleet``) over the *observed* exchange bytes,
    instead of the static ``-(-est_bytes // bytes_per_worker)``; upstream
    partitions are re-assigned to the smaller fleet LPT-balanced by bytes;
  * **empty-partition pruning** — partitions the manifest proves empty are
    dropped from every fragment's read set (and from the fleet-size cap);
  * **broadcast-join downgrade** — a repartition join whose *observed*
    build side fits a worker's memory budget switches the build source to
    a broadcast (mode=all) read, freeing the fleet size from build-side
    partition alignment;
  * **exchange re-tiering** — the pipeline's own output exchange tier is
    re-picked from the adapted producer count (object-request-rate
    reasoning of section 3.4).

All re-decisions mutate only ``Pipeline.params`` (the mutable execution
half of the plan); the logical core — and therefore the semantic hash —
is untouched, so adapted pipelines still cache and dedup against their
statically planned twins. Partition re-assignment is only applied when
every aligned (partition-mode) source shares one hash layout, and
assigning whole upstream partitions to fragments preserves co-location of
join keys and group keys, so results stay identical to the static plan.
"""

from __future__ import annotations

import dataclasses

from repro.core.cost import CostModel
from repro.exec.operators import kmv_estimate, kmv_merge  # noqa: F401
from repro.sql.physical import Pipeline


@dataclasses.dataclass
class _Leaf:
    op: dict
    under_build: bool


def _collect_leaves(op: dict, under_build: bool = False) -> list[_Leaf]:
    """All scan_exchange leaves of a fragment op tree, flagged when they
    sit on the build side of a join (build rows never *drive* output:
    a partition with zero probe rows produces nothing)."""
    out: list[_Leaf] = []
    if op.get("t") == "scan_exchange":
        out.append(_Leaf(op, under_build))
        return out
    for k in ("child", "probe"):
        if k in op:
            out.extend(_collect_leaves(op[k], under_build))
    if "build" in op:
        out.extend(_collect_leaves(op["build"], True))
    return out


def apply_broadcast(op: dict, sources: list[str]) -> dict:
    """Copy of ``op`` with the given exchange sources read broadcast
    (mode=all) instead of partition-aligned — the shuffle→broadcast join
    downgrade. The original op tree (the immutable logical core) is
    never mutated."""
    if not sources:
        return op
    out = dict(op)
    if out.get("t") == "scan_exchange" and out.get("source") in sources \
            and out.get("mode") == "partition":
        out["mode"] = "all"
    for k in ("child", "probe", "build"):
        if k in out:
            out[k] = apply_broadcast(out[k], sources)
    return out


def _lpt_assignment(parts: list[int], weights: dict[int, float],
                    n_fragments: int) -> list[list[int]]:
    """Assign upstream partitions to fragments, longest-processing-time
    first (balance observed weights); each fragment's list stays sorted
    so read/concat order is deterministic."""
    buckets: list[list[int]] = [[] for _ in range(n_fragments)]
    loads = [0.0] * n_fragments
    for d in sorted(parts, key=lambda d: (-weights.get(d, 0.0), d)):
        i = loads.index(min(loads))
        buckets[i].append(d)
        loads[i] += weights.get(d, 0.0)
    return [sorted(b) for b in buckets]


def straggler_skew_weights(bytes_per_part: dict[int, float],
                           write_s_per_part: dict[int, float],
                           cap: float = 4.0) -> dict[int, float]:
    """LPT weights inflated by observed runtime skew.

    The manifest carries each partition's observed write latency; a
    partition that took disproportionately long *per byte* sits on slow
    storage (hot key, throttled prefix) and will likely read slowly too.
    Its weight is inflated by the latency-per-byte ratio against the
    fleet median (clipped to ``cap``), so the LPT assignment gives slow
    partitions dedicated workers instead of byte-balanced bundles.
    """
    rates = {d: write_s_per_part.get(d, 0.0) / max(b, 1.0)
             for d, b in bytes_per_part.items() if b > 0}
    positive = sorted(r for r in rates.values() if r > 0)
    if not positive:
        return dict(bytes_per_part)
    med = positive[len(positive) // 2]
    if med <= 0:
        return dict(bytes_per_part)
    out = {}
    for d, b in bytes_per_part.items():
        skew = min(max(rates.get(d, 0.0) / med, 1.0), cap)
        out[d] = b * skew
    return out


class Reoptimizer:
    """Re-derives a pipeline's execution parameters from the observed
    statistics of its upstream exchange manifests."""

    def __init__(self, cost_model: CostModel, *,
                 latency_budget_s: float = 2.0,
                 broadcast_bytes: int = 16 << 20,
                 hot_shuffle_object_threshold: int = 64,
                 quota: int = 2500,
                 forced_strategy: str | None = None,
                 straggler_skew_cap: float = 4.0):
        self.cost_model = cost_model
        self.latency_budget_s = latency_budget_s
        self.broadcast_bytes = broadcast_bytes
        self.hot_shuffle_object_threshold = hot_shuffle_object_threshold
        self.quota = quota
        # a planner-forced exchange strategy is never re-picked
        self.forced_strategy = forced_strategy
        self.straggler_skew_cap = straggler_skew_cap

    # -- entry point --------------------------------------------------------
    def adapt(self, p: Pipeline, sources: dict[str, dict], *,
              latency_budget_s: float | None = None,
              fleet_cap: int | None = None) -> list[dict]:
        """Re-optimize ``p`` in place (mutating ``p.params`` only) before
        launch; returns the list of adaptation records applied.

        ``sources`` maps source semantic hashes to their registry
        entries (the exchange manifests). Pipelines that scan base
        tables directly have no runtime observations to exploit and are
        left untouched; so is any pipeline whose manifests predate stat
        emission (graceful fallback to the static plan).

        ``latency_budget_s`` overrides the configured budget for this
        call — the service tier passes the query's *remaining deadline
        share* here, so a query running behind its SLO escalates to a
        bigger fleet at the next barrier. ``fleet_cap`` clamps the fleet
        (budget-exhausted tenants degrade to their minimum fleet).
        """
        if p.scan_units or not sources:
            return []
        adaptations: list[dict] = []
        leaves = _collect_leaves(p.op)
        budget = self.latency_budget_s if latency_budget_s is None \
            else latency_budget_s

        self._downgrade_broadcast_joins(p, sources, adaptations)
        self._prune_empty_partitions(p, sources, leaves, adaptations)
        self._resize_fleet(p, sources, leaves, adaptations,
                           latency_budget_s=budget, fleet_cap=fleet_cap)
        self._replan_exchange(p, sources, adaptations,
                              latency_budget_s=budget)
        return adaptations

    # -- (c) shuffle → broadcast join downgrade ------------------------------
    def _downgrade_broadcast_joins(self, p: Pipeline, sources: dict,
                                   adaptations: list[dict]) -> None:
        def walk(op: dict) -> None:
            if op.get("t") == "join":
                build = op.get("build", {})
                if build.get("t") == "scan_exchange" \
                        and build.get("mode") == "partition":
                    sem = build["source"]
                    st = (sources.get(sem) or {}).get("stats") or {}
                    nbytes = st.get("bytes_out")
                    if nbytes is not None \
                            and nbytes <= self.broadcast_bytes:
                        p.params.broadcast_sources.append(sem)
                        adaptations.append({
                            "kind": "broadcast_downgrade", "source": sem,
                            "observed_bytes": int(nbytes),
                            "budget_bytes": int(self.broadcast_bytes)})
            for k in ("child", "probe", "build"):
                if k in op:
                    walk(op[k])
        walk(p.op)

    # -- (b) empty-partition pruning ----------------------------------------
    def _prune_empty_partitions(self, p: Pipeline, sources: dict,
                                leaves: list[_Leaf],
                                adaptations: list[dict]) -> None:
        for leaf in leaves:
            sem = leaf.op["source"]
            entry = sources.get(sem) or {}
            part = entry.get("partitioning") or {}
            rows = (entry.get("stats") or {}).get("partition_rows")
            if part.get("kind") != "hash" or rows is None \
                    or sem in p.params.source_partitions \
                    or entry.get("partial"):
                # partial (pilot-K) manifests cannot prove a partition
                # empty — producers still in flight may yet fill it
                continue
            nonempty = [d for d, r in enumerate(rows) if r > 0]
            if len(nonempty) < len(rows):
                p.params.source_partitions[sem] = nonempty
                adaptations.append({
                    "kind": "partition_prune", "source": sem,
                    "pruned": len(rows) - len(nonempty),
                    "of": len(rows)})

    # -- (a) cost-optimal fleet re-sizing -------------------------------------
    def _resize_fleet(self, p: Pipeline, sources: dict,
                      leaves: list[_Leaf],
                      adaptations: list[dict], *,
                      latency_budget_s: float | None = None,
                      fleet_cap: int | None = None) -> None:
        budget = self.latency_budget_s if latency_budget_s is None \
            else latency_budget_s
        aligned = [l for l in leaves
                   if l.op.get("mode") == "partition"
                   and l.op["source"] not in p.params.broadcast_sources]
        if not aligned:
            return
        entries = []
        for leaf in aligned:
            entry = sources.get(leaf.op["source"])
            part = (entry or {}).get("partitioning") or {}
            st = (entry or {}).get("stats") or {}
            if part.get("kind") != "hash" \
                    or st.get("partition_rows") is None \
                    or st.get("partition_bytes") is None:
                return          # manifest without stats: stay static
            entries.append((leaf, part, st))
        n_dests = {part["n_dest"] for _, part, _ in entries}
        if len(n_dests) != 1:
            return              # cached foreign layouts cannot align
        D = n_dests.pop()
        # a partition drives output when any non-build source has rows
        driving_rows = [0] * D
        bytes_per_part: dict[int, float] = {d: 0.0 for d in range(D)}
        write_s_per_part: dict[int, float] = {d: 0.0 for d in range(D)}
        for leaf, part, st in entries:
            write_s = st.get("partition_write_s") or [0.0] * D
            for d in range(D):
                bytes_per_part[d] += st["partition_bytes"][d]
                write_s_per_part[d] += write_s[d]
                if not leaf.under_build:
                    driving_rows[d] += st["partition_rows"][d]
        if not any(not leaf.under_build for leaf, _, _ in entries):
            driving_rows = [1] * D      # defensive: no driving source
        if any(entry.get("partial")
               for leaf, _, _ in entries
               for entry in [sources.get(leaf.op["source"]) or {}]):
            # pilot-K estimates: a partition with no rows in the pilot
            # subset may still be filled by in-flight producers — every
            # partition must stay assigned or its rows would be dropped
            driving_rows = [max(r, 1) for r in driving_rows]
        nonempty = [d for d in range(D) if driving_rows[d] > 0]
        total_bytes = int(sum(bytes_per_part[d] for d in nonempty))

        f0 = p.params.n_fragments
        cap = min(f0, max(len(nonempty), 1), self.quota)
        if fleet_cap is not None:
            cap = min(cap, max(fleet_cap, 1))
        w = self.cost_model.optimal_fleet(
            total_bytes, latency_budget_s=budget,
            max_workers=cap)
        static_map = (w == f0 == D and len(nonempty) == D
                      and not p.params.broadcast_sources)
        if static_map:
            return              # the 1:1 fragment↔partition map stands
        # straggler-aware assignment: inflate LPT weights of partitions
        # whose observed write latency per byte is far above the median,
        # so slow storage partitions get dedicated workers
        weights = straggler_skew_weights(bytes_per_part, write_s_per_part,
                                         cap=self.straggler_skew_cap)
        p.params.partition_assignment = _lpt_assignment(
            nonempty, weights, w)
        p.params.n_fragments = w
        if w != f0:
            adaptations.append({
                "kind": "fleet_resize", "from": f0, "to": w,
                "observed_bytes": total_bytes,
                "est_bytes": int(p.params.est_in_bytes),
                "cost_cents": self.cost_model.fleet_cost_cents(
                    w, total_bytes),
                "latency_budget_s": budget})

    # -- (d) exchange re-plan: strategy + tier --------------------------------
    def _observed_out_bytes(self, p: Pipeline, sources: dict) -> float:
        """Best runtime estimate of this pipeline's own exchange payload:
        the planner's figure, rescaled by how far the observed input
        bytes diverged from the estimated input bytes."""
        est = float(max(p.params.est_out_bytes, 0))
        est_in = float(p.params.est_in_bytes)
        obs_in = sum(float((e.get("stats") or {}).get("bytes_out", 0))
                     for e in sources.values())
        if est_in > 0 and obs_in > 0:
            # rescale downward only: growing the figure could talk the
            # re-pick into a costlier strategy on a noisy observation
            est = min(est, est * obs_in / est_in)
        return est

    def _replan_exchange(self, p: Pipeline, sources: dict,
                         adaptations: list[dict], *,
                         latency_budget_s: float | None = None) -> None:
        """Re-pick this pipeline's output shuffle strategy and tier from
        the adapted producer count and recalibrated payload estimate —
        including injecting (or cancelling) the multi-level merge wave
        the engine schedules after the producer fleet."""
        from repro.exec.exchange import get_strategy
        budget = self.latency_budget_s if latency_budget_s is None \
            else latency_budget_s
        part = p.params.partitioning
        if part.kind != "hash":
            return
        producers = p.params.n_fragments
        if self.forced_strategy is None:
            nbytes = self._observed_out_bytes(p, sources)
            cost, costs = self.cost_model.choose_exchange_strategy(
                producers, part.n_dest, nbytes,
                tier_for=self._tier_for_objects,
                latency_budget_s=budget,
            )
            cur = costs.get(part.strategy)
            switch = cost.strategy != part.strategy
            if switch and cur is not None \
                    and cur.makespan_s <= budget:
                # hysteresis against churn: keep the planner's strategy
                # unless the re-pick saves real money (or the current
                # one blows the latency budget)
                from repro.core.cost import (EXCHANGE_HYSTERESIS,
                                             EXCHANGE_MIN_SAVING_CENTS)
                saving = cur.cents - cost.cents
                if saving < max(EXCHANGE_MIN_SAVING_CENTS,
                                EXCHANGE_HYSTERESIS * cur.cents):
                    switch = False
            if switch:
                old = part.strategy
                old_est = p.params.est_exchange_requests
                part.strategy = cost.strategy
                adaptations.append({
                    "kind": "exchange_restrategy",
                    "from": old, "to": cost.strategy,
                    "est_requests_from": old_est,
                    "est_requests_to": get_strategy(
                        cost.strategy).producer_requests(producers,
                                                         part.n_dest),
                    "cents_from": cur.cents if cur else -1.0,
                    "cents_to": cost.cents})
        strat = get_strategy(part.strategy)
        # refresh the request estimate for the (possibly resized) fleet
        p.params.est_exchange_requests = strat.producer_requests(
            producers, part.n_dest)
        objects = strat.written_objects(producers, part.n_dest)
        tier = self._tier_for_objects(objects)
        if tier != part.tier:
            adaptations.append({"kind": "exchange_retier",
                                "from": part.tier, "to": tier,
                                "shuffle_objects": objects})
            part.tier = tier
        # multilevel l0 intermediates are short-lived (deleted after the
        # merge wave) — re-route them to the express tier when cheaper
        if part.strategy == "multilevel":
            part.l0_tier = self.cost_model.l0_tier_choice(
                producers, self._observed_out_bytes(p, sources),
                base_tier=part.tier)
        else:
            part.l0_tier = None

    def _tier_for_objects(self, objects: int) -> str:
        return "s3-express" if objects > self.hot_shuffle_object_threshold \
            else "s3-standard"

    # -- (e) semi-join filter adopt/revoke ------------------------------------
    def semijoin_decision(self, p: Pipeline, *, build_rows: float,
                          build_distinct: int | None = None
                          ) -> dict | None:
        """Re-gate a probe pipeline's semi-join filter from the observed
        build-side cardinality (a pilot-K extrapolation or the sealed
        manifest's exact figures).

        Called by the engine outside :meth:`adapt` — the probe is a scan
        pipeline, which ``adapt`` leaves untouched. Mutates only
        ``params.semijoin``; the probe's sem hash already folds the build
        side, so flipping the verdict never splits the result cache.
        Returns the adaptation record (``semijoin_adopt`` /
        ``semijoin_revoke``) or None if the plan-time verdict stands.
        """
        from repro.core.cost import EXCHANGE_MIN_SAVING_CENTS
        sj = p.params.semijoin
        if not sj:
            return None
        base = float(sj.get("base_rows") or 0.0)
        match = min(1.0, build_rows / base) if base > 0 \
            else float(sj["est_match"])
        distinct = int(build_distinct) if build_distinct \
            else max(int(build_rows), 1)
        part = p.params.partitioning
        ben = self.cost_model.semijoin_benefit(
            producers=p.params.n_fragments, n_dest=part.n_dest,
            probe_bytes=float(max(p.params.est_out_bytes, 0)),
            match_fraction=match, build_distinct=distinct,
            strategy=part.strategy, tier=part.tier)
        # adopting mid-flight must clear the same churn guard as an
        # exchange re-pick; revoking only needs the benefit to vanish
        want = ben["benefit_cents"] > 0 if sj["enabled"] \
            else ben["benefit_cents"] > EXCHANGE_MIN_SAVING_CENTS
        if want == sj["enabled"]:
            return None
        sj["enabled"] = want
        sj["est_match"] = match
        sj["est_distinct"] = distinct
        return {"kind": "semijoin_adopt" if want else "semijoin_revoke",
                "build_rows": int(build_rows),
                "build_distinct": distinct,
                "match_fraction": round(match, 4),
                "benefit_cents": ben["benefit_cents"]}
