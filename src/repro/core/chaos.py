"""Seeded, deterministic full-stack fault injection (the chaos engine).

The paper's core claim is that a fully serverless query processor stays
*robust* on unreliable, fine-grained infrastructure. ``FaultPlan``
(core.platform) already exercises the worker path — transient invoke
failures and stragglers — but the layers whose correctness the runtime
actually depends on (storage writes, the registry's claim/partial-
manifest protocol, the service ledger's state machine) were never run
under adversarial schedules. This module injects faults at *every*
layer through one shared, seeded schedule:

  ===================  =====================================================
  site                 fault
  ===================  =====================================================
  ``storage.get``      transient GET error / 503 throttle / latency spike
  ``storage.put``      transient PUT error / sandbox death mid-PUT leaving a
                       *torn partial object* (prefix of the bytes)
  ``platform.cold``    cold-start storm (warm sandboxes unavailable)
  ``platform.kill``    worker killed mid-fragment (beyond ``FaultPlan``)
  ``registry.claim``   owner dies right after writing its claim (orphan)
  ``registry.begin_partial``    owner dies after opening the stream
  ``registry.publish_partial``  owner dies after landing one partition
  ``registry.finish_partial``   owner dies before sealing the stream
  ``ledger.<STATUS>``  service instance dies right after the CAS landing
                       the ``<STATUS>`` transition (ADMITTED, RUNNING, …)
  ===================  =====================================================

Two injection shapes:

  * **probabilistic** rolls (storage faults, cold storms, worker kills) —
    each decision is an independent draw from an rng seeded by
    ``(seed, site, call-counter)``, so a given seed produces the same
    fault schedule on every run: a red CI run is reproduced locally from
    its seed alone;
  * **one-shot kill points** (``kill_points``) — named protocol steps
    that raise :class:`ChaosKill` exactly once per site, modeling the
    owner process dying at that exact step. The recovery machinery
    (claim TTL steal, partial-stream reset, ledger lease expiry) must
    then finish the work — with byte-identical results and no duplicate
    fleet work.

``ChaosKill`` subclasses :class:`TransientInfraError`: to the rest of
the stack a chaos death is indistinguishable from a real one, so the
handling exercised is exactly the production path. The KV tier
(``dynamodb``) is exempt from *storage* faults — conditional writes
there are atomic in the modeled backend — its failure modes are the
explicit protocol kill points instead.
"""

from __future__ import annotations

import dataclasses
import threading
import zlib

import numpy as np

from repro.core.retry import TransientInfraError


class ChaosKill(TransientInfraError):
    """The process was killed at a named protocol step."""

    def __init__(self, site: str):
        super().__init__(f"chaos: killed at {site}")
        self.site = site


@dataclasses.dataclass
class ChaosConfig:
    """Knobs of one chaos schedule. All probabilities default to zero so
    an empty config injects nothing; ``seed`` makes any non-zero
    schedule reproducible."""

    seed: int = 0
    # -- storage (S3 analog) faults -----------------------------------------
    get_error_prob: float = 0.0       # transient GET failure
    put_error_prob: float = 0.0       # transient PUT failure (no bytes land)
    throttle_prob: float = 0.0        # 503 SlowDown: fails AND bills latency
    throttle_latency_s: float = 0.05  # per-503 latency charged to the caller
    latency_spike_prob: float = 0.0   # the heavy first-byte tail
    latency_spike_factor: float = 20.0
    torn_put_prob: float = 0.0        # sandbox death mid-PUT: prefix lands
    # -- platform faults ----------------------------------------------------
    cold_storm_prob: float = 0.0      # invocation cold-starts despite pool
    worker_kill_prob: float = 0.0     # sandbox killed mid-fragment
    # -- one-shot protocol kill points --------------------------------------
    # site names from the table above; each fires exactly once
    kill_points: tuple = ()


class ChaosEngine:
    """Deterministic fault scheduler shared by every layer of one
    session/service. Thread-safe; disable with ``pause()`` (e.g. while
    fetching results for a parity check — the verification read path is
    not the system under test)."""

    def __init__(self, config: ChaosConfig | None = None):
        self.config = config or ChaosConfig()
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._fired_kills: set[str] = set()
        self.enabled = True
        # observability: injected-fault counts per site/kind, asserted on
        # by the chaos harness ("this run actually injected faults")
        self.injected: dict[str, int] = {}

    # -- bookkeeping ---------------------------------------------------------
    def _rng(self, site: str) -> np.random.Generator:
        """Per-(seed, site, call-counter) rng: the n-th decision at a
        site is the same for a given seed on every run."""
        with self._lock:
            n = self._counters.get(site, 0)
            self._counters[site] = n + 1
        return np.random.default_rng(
            (self.config.seed, zlib.crc32(site.encode()), n))

    def _record(self, kind: str) -> None:
        with self._lock:
            self.injected[kind] = self.injected.get(kind, 0) + 1

    def pause(self) -> "_Paused":
        """Context manager suspending all injection (parity fetches,
        reference reads)."""
        return _Paused(self)

    # -- one-shot protocol kill points ---------------------------------------
    def kill_once(self, site: str) -> None:
        """Raise :class:`ChaosKill` the first time ``site`` is reached
        (when listed in ``kill_points``); later calls pass."""
        if not self.enabled or site not in self.config.kill_points:
            return
        with self._lock:
            if site in self._fired_kills:
                return
            self._fired_kills.add(site)
            self.injected[f"kill:{site}"] = 1
        raise ChaosKill(site)

    # -- storage faults ------------------------------------------------------
    def storage_fault(self, op: str, key: str) -> str | None:
        """Roll the fault (if any) for one storage request. Returns
        ``None`` | ``"transient"`` | ``"throttle"`` | ``"torn"`` (PUTs
        only); latency spikes are reported via :meth:`latency_scale`
        separately so they compose with error-free requests."""
        c = self.config
        if not self.enabled:
            return None
        rng = self._rng(f"storage.{op}")
        err = c.get_error_prob if op == "get" else c.put_error_prob
        if rng.random() < err:
            self._record(f"storage.{op}.transient")
            return "transient"
        if rng.random() < c.throttle_prob:
            self._record(f"storage.{op}.throttle")
            return "throttle"
        if op == "put" and rng.random() < c.torn_put_prob:
            self._record("storage.put.torn")
            return "torn"
        return None

    def latency_scale(self, op: str) -> float:
        """Multiplier on one request's simulated latency draw (the
        first-byte tail the hedged-read path races against)."""
        c = self.config
        if not self.enabled or c.latency_spike_prob <= 0.0:
            return 1.0
        rng = self._rng(f"storage.{op}.latency")
        if rng.random() < c.latency_spike_prob:
            self._record(f"storage.{op}.spike")
            return c.latency_spike_factor
        return 1.0

    # -- platform faults -----------------------------------------------------
    def cold_storm(self) -> bool:
        """True → this invocation cold-starts even with warm sandboxes
        available (the pool itself is untouched — a storm is an
        availability blip, not a pool reset)."""
        c = self.config
        if not self.enabled or c.cold_storm_prob <= 0.0:
            return False
        if self._rng("platform.cold").random() < c.cold_storm_prob:
            self._record("platform.cold_storm")
            return True
        return False

    def worker_kill(self) -> bool:
        """True → the sandbox dies mid-fragment (generalizes
        ``FaultPlan.kill_fragments`` into the shared schedule)."""
        c = self.config
        if not self.enabled or c.worker_kill_prob <= 0.0:
            return False
        if self._rng("platform.kill").random() < c.worker_kill_prob:
            self._record("platform.worker_kill")
            return True
        return False


class _Paused:
    def __init__(self, chaos: ChaosEngine):
        self._chaos = chaos

    def __enter__(self) -> ChaosEngine:
        self._chaos.enabled = False
        return self._chaos

    def __exit__(self, *exc) -> None:
        self._chaos.enabled = True
