"""Cost model for serverless compute and storage (paper Tables 1 and 3).

Skyrise is cost-aware end-to-end: the optimizer sizes worker fleets and
picks shuffle tiers against these prices, and the evaluation (Fig. 6)
reports per-query dollars. Prices are AWS us-east-1, ARM Lambda, as used in
the paper's experiments (Aug 2024 – Jan 2025).
"""

from __future__ import annotations

import dataclasses
import math

from repro.storage.tiers import TIERS

# -- Table 1: compute -----------------------------------------------------------

# Lambda (ARM): 4.8 ¢/GiB-h at the largest sizes → ¢ per GiB-second.
LAMBDA_CENTS_PER_GIB_S = 4.8 / 3600.0
LAMBDA_CENTS_PER_REQUEST = 0.2 / 10_000.0       # $0.20 per 1M invocations
SQS_CENTS_PER_REQUEST = 0.4 / 10_000.0          # $0.40 per 1M requests

# EC2 (C6g) for comparison benchmarks: 1.7 ¢/GiB-h.
EC2_CENTS_PER_GIB_S = 1.7 / 3600.0

# -- Table 2: startup latency [seconds] -------------------------------------------

LAMBDA_COLD_START = {"min": 0.122, "max": 0.451, "avg": 0.185}
LAMBDA_WARM_START = {"min": 0.005, "max": 0.009, "avg": 0.006}
EC2_COLD_START = {"min": 12.795, "max": 22.817, "avg": 15.226}
EC2_WARM_START = {"min": 9.810, "max": 19.288, "avg": 11.512}


@dataclasses.dataclass
class CostBreakdown:
    compute_cents: float = 0.0
    invoke_cents: float = 0.0
    messaging_cents: float = 0.0
    storage_request_cents: float = 0.0
    storage_transfer_cents: float = 0.0

    @property
    def total_cents(self) -> float:
        return (self.compute_cents + self.invoke_cents
                + self.messaging_cents + self.storage_request_cents
                + self.storage_transfer_cents)

    def merge(self, other: "CostBreakdown") -> None:
        self.compute_cents += other.compute_cents
        self.invoke_cents += other.invoke_cents
        self.messaging_cents += other.messaging_cents
        self.storage_request_cents += other.storage_request_cents
        self.storage_transfer_cents += other.storage_transfer_cents


class CostModel:
    """Charges workers (GiB-s + invocations + queue messages) and storage
    requests/transfers per tier."""

    def __init__(self, worker_memory_gib: float = 2.0):
        self.worker_memory_gib = worker_memory_gib

    def worker_cost(self, runtime_s: float,
                    tier_ops: dict) -> CostBreakdown:
        out = CostBreakdown()
        out.compute_cents = (runtime_s * self.worker_memory_gib
                             * LAMBDA_CENTS_PER_GIB_S)
        out.invoke_cents = LAMBDA_CENTS_PER_REQUEST
        # one response message to the coordinator's queue (send+receive)
        out.messaging_cents = 2 * SQS_CENTS_PER_REQUEST
        for tier_name, ops in tier_ops.items():
            tier = TIERS.get(tier_name, TIERS["s3-standard"])
            out.storage_request_cents += (
                ops["get"] * tier.read_request_cents_per_1m / 1e6
                + ops["put"] * tier.write_request_cents_per_1m / 1e6)
            out.storage_transfer_cents += (
                ops["bytes_read"] / 2**30 * tier.read_transfer_cents_per_gib
                + ops["bytes_written"] / 2**30
                * tier.write_transfer_cents_per_gib)
        return out

    def coordinator_cost(self, runtime_s: float) -> CostBreakdown:
        out = CostBreakdown()
        out.compute_cents = (runtime_s * self.worker_memory_gib
                             * LAMBDA_CENTS_PER_GIB_S)
        out.invoke_cents = LAMBDA_CENTS_PER_REQUEST
        return out

    # -- cost-optimal fleet sizing (adaptive re-optimization) -------------------
    def fleet_latency_s(self, n_workers: int, nbytes: int, *,
                        bandwidth_bytes_per_s: float = 90e6,
                        fixed_s: float = 0.05) -> float:
        """Projected pipeline latency with ``n_workers`` sharing
        ``nbytes`` of input: per-worker startup/dispatch overhead plus
        its byte share over one storage connection."""
        share = nbytes / max(n_workers, 1)
        return fixed_s + share / bandwidth_bytes_per_s

    def fleet_cost_cents(self, n_workers: int, nbytes: int, *,
                         bandwidth_bytes_per_s: float = 90e6,
                         fixed_s: float = 0.05) -> float:
        """Projected fleet dollars: per-worker fixed charges (invoke +
        response messages + startup compute) plus the byte-proportional
        scan compute, which is invariant in the fleet size. Strictly
        increasing in ``n_workers`` — parallelism buys latency, never
        dollars."""
        per_worker = (LAMBDA_CENTS_PER_REQUEST + 2 * SQS_CENTS_PER_REQUEST
                      + fixed_s * self.worker_memory_gib
                      * LAMBDA_CENTS_PER_GIB_S)
        scan_s = nbytes / bandwidth_bytes_per_s
        return (n_workers * per_worker
                + scan_s * self.worker_memory_gib * LAMBDA_CENTS_PER_GIB_S)

    def optimal_fleet(self, nbytes: int, *, latency_budget_s: float,
                      max_workers: int,
                      bandwidth_bytes_per_s: float = 90e6,
                      fixed_s: float = 0.05,
                      memory_fill_fraction: float = 0.5) -> int:
        """Dollar-minimal fleet size subject to a latency budget.

        ``fleet_cost_cents`` is strictly increasing and
        ``fleet_latency_s`` strictly decreasing in the worker count, so
        the cost-optimal feasible fleet is the *smallest* one whose
        projected latency fits the budget — computed in closed form —
        with two floors: every worker's input share must fit the
        function's memory budget, and the fleet never exceeds
        ``max_workers`` (quota / partition granularity); if the budget
        is unreachable even at ``max_workers``, latency wins and the cap
        is returned.
        """
        max_workers = max(1, max_workers)
        span = latency_budget_s - fixed_s
        if span <= 0:
            w = max_workers
        else:
            w = math.ceil(nbytes / (span * bandwidth_bytes_per_s))
        mem_budget = self.worker_memory_gib * 2**30 * memory_fill_fraction
        w = max(w, math.ceil(nbytes / max(mem_budget, 1)), 1)
        return min(w, max_workers)
