"""Cost model for serverless compute and storage (paper Tables 1 and 3).

Skyrise is cost-aware end-to-end: the optimizer sizes worker fleets and
picks shuffle tiers against these prices, and the evaluation (Fig. 6)
reports per-query dollars. Prices are AWS us-east-1, ARM Lambda, as used in
the paper's experiments (Aug 2024 – Jan 2025).
"""

from __future__ import annotations

import dataclasses
import math

from repro.storage.tiers import TIERS

# -- Table 1: compute -----------------------------------------------------------

# Lambda (ARM): 4.8 ¢/GiB-h at the largest sizes → ¢ per GiB-second.
LAMBDA_CENTS_PER_GIB_S = 4.8 / 3600.0
LAMBDA_CENTS_PER_REQUEST = 0.2 / 10_000.0       # $0.20 per 1M invocations
SQS_CENTS_PER_REQUEST = 0.4 / 10_000.0          # $0.40 per 1M requests

# EC2 (C6g) for comparison benchmarks: 1.7 ¢/GiB-h.
EC2_CENTS_PER_GIB_S = 1.7 / 3600.0

# Exchange-strategy switch hysteresis, shared by the planner's pick and
# the Reoptimizer's barrier re-pick: a non-default strategy must save at
# least this many cents AND this fraction of the baseline's cents.
EXCHANGE_MIN_SAVING_CENTS = 0.002
EXCHANGE_HYSTERESIS = 0.15

# Pipelined execution: fraction of post-first-batch read time a worker
# hides behind kernel compute by double-buffering (prefetch the next
# top-up batch while the kernel chews the previous one). 1.0 would be
# perfect overlap; the residue models prefetch ramp + final-batch drain.
PIPELINE_OVERLAP_EFFICIENCY = 0.9

# -- Table 2: startup latency [seconds] -------------------------------------------

LAMBDA_COLD_START = {"min": 0.122, "max": 0.451, "avg": 0.185}
LAMBDA_WARM_START = {"min": 0.005, "max": 0.009, "avg": 0.006}
EC2_COLD_START = {"min": 12.795, "max": 22.817, "avg": 15.226}
EC2_WARM_START = {"min": 9.810, "max": 19.288, "avg": 11.512}


@dataclasses.dataclass
class CostBreakdown:
    compute_cents: float = 0.0
    invoke_cents: float = 0.0
    messaging_cents: float = 0.0
    storage_request_cents: float = 0.0
    storage_transfer_cents: float = 0.0

    @property
    def total_cents(self) -> float:
        return (self.compute_cents + self.invoke_cents
                + self.messaging_cents + self.storage_request_cents
                + self.storage_transfer_cents)

    def merge(self, other: "CostBreakdown") -> None:
        self.compute_cents += other.compute_cents
        self.invoke_cents += other.invoke_cents
        self.messaging_cents += other.messaging_cents
        self.storage_request_cents += other.storage_request_cents
        self.storage_transfer_cents += other.storage_transfer_cents


@dataclasses.dataclass(frozen=True)
class ExchangeCost:
    """Projected cost/latency of one hash exchange under a strategy."""

    strategy: str
    tier: str
    puts: int                 # objects written (exchange + merge wave)
    gets: int                 # data + footer reads to consume it once
    merge_workers: int        # extra wave injected by the strategy
    request_cents: float
    transfer_cents: float
    worker_cents: float       # merge invocations + fleets' wait GiB-s
    makespan_s: float         # request-pool makespan across the barrier

    @property
    def cents(self) -> float:
        return self.request_cents + self.transfer_cents + self.worker_cents

    @property
    def requests(self) -> int:
        return self.puts + self.gets


class CostModel:
    """Charges workers (GiB-s + invocations + queue messages) and storage
    requests/transfers per tier."""

    def __init__(self, worker_memory_gib: float = 2.0):
        self.worker_memory_gib = worker_memory_gib

    def worker_cost(self, runtime_s: float,
                    tier_ops: dict) -> CostBreakdown:
        out = CostBreakdown()
        out.compute_cents = (runtime_s * self.worker_memory_gib
                             * LAMBDA_CENTS_PER_GIB_S)
        out.invoke_cents = LAMBDA_CENTS_PER_REQUEST
        # one response message to the coordinator's queue (send+receive)
        out.messaging_cents = 2 * SQS_CENTS_PER_REQUEST
        for tier_name, ops in tier_ops.items():
            tier = TIERS.get(tier_name, TIERS["s3-standard"])
            out.storage_request_cents += (
                ops["get"] * tier.read_request_cents_per_1m / 1e6
                + ops["put"] * tier.write_request_cents_per_1m / 1e6)
            out.storage_transfer_cents += (
                ops["bytes_read"] / 2**30 * tier.read_transfer_cents_per_gib
                + ops["bytes_written"] / 2**30
                * tier.write_transfer_cents_per_gib)
        return out

    def hedge_timeout_s(self, tier) -> float:
        """Cost-optimal hedged-read timeout for one storage tier.

        A duplicate GET costs one read request; waiting costs the
        worker's GiB-seconds. The break-even wait — where the dollars
        burned waiting equal the dollars a hedge would cost — is
        ``read_request_cents / (memory_gib · LAMBDA_CENTS_PER_GIB_S)``.
        Hedging any earlier pays more in requests than the wait costs;
        any later burns compute on the first-byte tail the measurement
        study documents. Offset from the tier's *median* read latency so
        a typical request never hedges (≈ 42 ms for s3-standard).
        """
        t = TIERS[tier] if isinstance(tier, str) else tier
        break_even_s = (t.read_request_cents_per_1m / 1e6) / (
            self.worker_memory_gib * LAMBDA_CENTS_PER_GIB_S)
        return t.read_median_s + break_even_s

    def coordinator_cost(self, runtime_s: float) -> CostBreakdown:
        out = CostBreakdown()
        out.compute_cents = (runtime_s * self.worker_memory_gib
                             * LAMBDA_CENTS_PER_GIB_S)
        out.invoke_cents = LAMBDA_CENTS_PER_REQUEST
        return out

    # -- exchange strategy costing (exec.exchange) -----------------------------
    def exchange_cost(self, producers: int, n_dest: int, nbytes: float, *,
                      strategy: str = "direct",
                      tier: str = "s3-standard",
                      pool_size: int = 16) -> "ExchangeCost":
        """Projected cost of materializing + reading one hash exchange
        under a shuffle strategy: per-request and transfer cents, the
        merge wave's worker charges, the GiB-seconds every fleet spends
        waiting on the exchange's request-pool makespans, and that
        makespan itself (the latency the strategy adds to the query).
        """
        from repro.exec.exchange import get_strategy
        strat = get_strategy(strategy)
        t = TIERS.get(tier, TIERS["s3-standard"])
        P, D = max(producers, 1), max(n_dest, 1)
        G = strat.merge_workers(P)
        puts = strat.written_objects(P, D)
        # each object's footer is fetched once (2 requests) through the
        # shared cache; every object is read in full exactly once
        data_gets = strat.consumer_requests(P, D) + (P if G else 0)
        gets = data_gets + 2 * puts
        request_cents = (puts * t.write_request_cents_per_1m / 1e6
                         + gets * t.read_request_cents_per_1m / 1e6)
        hops = 2 if G else 1          # multi-level moves the bytes twice
        transfer_cents = hops * nbytes / 2**30 * (
            t.read_transfer_cents_per_gib + t.write_transfer_cents_per_gib)

        def wave(reqs_per_worker: float, latency_s: float,
                 bytes_per_worker: float) -> float:
            if reqs_per_worker <= 0:
                return 0.0
            return (math.ceil(reqs_per_worker / pool_size) * latency_s
                    + bytes_per_worker / t.bandwidth_bytes_per_s)

        write_wave = wave(strat.producer_puts(D), t.write_median_s,
                          nbytes / P)
        merge_wave = 0.0
        if G:
            merge_wave = (wave(3 * math.ceil(P / G), t.read_median_s,
                               nbytes / G)
                          + wave(D, t.write_median_s, nbytes / G))
        read_wave = wave(data_gets / D, t.read_median_s, nbytes / D)
        makespan_s = write_wave + merge_wave + read_wave
        wait_s = P * write_wave + G * merge_wave + D * read_wave
        worker_cents = (G * (LAMBDA_CENTS_PER_REQUEST
                             + 2 * SQS_CENTS_PER_REQUEST)
                        + wait_s * self.worker_memory_gib
                        * LAMBDA_CENTS_PER_GIB_S)
        return ExchangeCost(strategy, tier, puts, gets, G, request_cents,
                            transfer_cents, worker_cents, makespan_s)

    def choose_exchange_strategy(
            self, producers: int, n_dest: int, nbytes: float, *,
            tier_for, latency_budget_s: float | None = None,
            allowed: tuple[str, ...] | None = None,
            min_saving_cents: float = EXCHANGE_MIN_SAVING_CENTS,
            hysteresis: float = EXCHANGE_HYSTERESIS,
    ) -> tuple["ExchangeCost", dict[str, "ExchangeCost"]]:
        """Pick the dollar-minimal strategy whose request-pool makespan
        fits the latency budget (no budget → cents only). ``tier_for``
        maps a written-object count to a storage tier (the planner's
        hot-shuffle rule) or is a fixed tier name. The bit-compatible
        ``direct`` grid keeps ties: another strategy must save at least
        ``min_saving_cents`` *and* ``hysteresis`` of direct's cents.
        """
        from repro.exec.exchange import get_strategy
        names = allowed or ("direct", "combining", "multilevel")
        costs: dict[str, ExchangeCost] = {}
        for name in names:
            strat = get_strategy(name)
            g = strat.merge_workers(producers)
            if g and g >= max(producers, 1):
                continue              # degenerate merge wave (√P ≥ P)
            tier = tier_for(strat.written_objects(producers, n_dest)) \
                if callable(tier_for) else tier_for
            costs[name] = self.exchange_cost(
                producers, n_dest, nbytes, strategy=name, tier=tier)
        pool = [c for c in costs.values()
                if latency_budget_s is None
                or c.makespan_s <= latency_budget_s] or list(costs.values())
        best = min(pool, key=lambda c: (c.cents, c.makespan_s))
        direct = costs.get("direct")
        if best.strategy != "direct" and direct is not None \
                and direct in pool:
            saving = direct.cents - best.cents
            if saving < max(min_saving_cents, hysteresis * direct.cents):
                best = direct
        return best, costs

    # -- semi-join filter pushdown (kernels.bloom) -------------------------------
    def semijoin_benefit(self, *, producers: int, n_dest: int,
                         probe_bytes: float, match_fraction: float,
                         build_distinct: int,
                         strategy: str = "direct",
                         tier: str = "s3-standard") -> dict:
        """Projected saving of pushing a build-side Bloom filter below a
        probe-side exchange, in cents.

        A filter kills the probe rows that cannot find a join partner
        *before* they are partitioned, so the exchange moves only the
        kept fraction ``match + fpr·(1 − match)`` of the payload (the
        false-positive residue is still shuffled and then dropped by the
        exact join). Against that saving stands the filter overhead: the
        build fleet's hash+publish work, one KV round-trip of the merged
        words per probe producer, and the probe fleet's k-hash membership
        test over its scan output. Monotone by construction: the benefit
        never decreases when ``match_fraction`` drops or ``probe_bytes``
        grows, so calibrated selectivities move the gate predictably.

        Returns ``{"benefit_cents", "kept_fraction", "fpr", "bits",
        "saved_cents", "overhead_cents"}``; the caller gates on
        ``benefit_cents > 0``.
        """
        from repro.kernels.bloom import bloom_bits_for, bloom_fpr
        match = min(max(float(match_fraction), 0.0), 1.0)
        nbytes = max(float(probe_bytes), 0.0)
        bits = bloom_bits_for(max(int(build_distinct), 1))
        fpr = bloom_fpr(max(int(build_distinct), 1), bits)
        kept = min(1.0, match + fpr * (1.0 - match))
        full = self.exchange_cost(producers, n_dest, nbytes,
                                  strategy=strategy, tier=tier)
        filtered = self.exchange_cost(producers, n_dest, nbytes * kept,
                                      strategy=strategy, tier=tier)
        saved = full.cents - filtered.cents

        kv = TIERS["dynamodb"]
        words_bytes = bits / 8.0
        P = max(producers, 1)
        # publish: the build coordinator lands the merged words once in
        # the KV manifest; fetch: every probe producer's spec carries the
        # words (one KV read's worth of request + transfer each)
        publish_cents = (kv.write_request_cents_per_1m / 1e6
                         + kv.storage_cost_cents(int(words_bytes), 60.0))
        fetch_cents = P * (kv.read_request_cents_per_1m / 1e6
                           + words_bytes / kv.bandwidth_bytes_per_s
                           * self.worker_memory_gib
                           * LAMBDA_CENTS_PER_GIB_S)
        # probe-side membership test: k gathers over the VMEM-resident
        # words, memory-bound at roughly the scan bandwidth
        hash_s = nbytes / 1e9
        hash_cents = (hash_s * self.worker_memory_gib
                      * LAMBDA_CENTS_PER_GIB_S)
        overhead = publish_cents + fetch_cents + hash_cents
        return {"benefit_cents": saved - overhead,
                "kept_fraction": kept, "fpr": fpr, "bits": bits,
                "saved_cents": saved, "overhead_cents": overhead}

    # -- express-tier l0 intermediates (exec.exchange multilevel) ----------------
    def l0_tier_choice(self, producers: int, nbytes: float, *,
                       ttl_s: float = 60.0,
                       base_tier: str = "s3-standard") -> str:
        """Storage tier for a multilevel exchange's l0 intermediates.

        l0 objects live only from the producer write to the merge wave's
        read — the engine deletes the prefix once the wave lands, so the
        at-rest charge is prorated over ``ttl_s``, not a month. Each l0
        object is written once and read once (plus two footer reads), so
        the express tier's cheaper request halves and doubled bandwidth
        usually beat its 7× at-rest price for these short-lived objects;
        the comparison below keeps that honest when the intermediates
        are large or the wave is slow.
        """
        P = max(producers, 1)
        nbytes = max(float(nbytes), 0.0)

        def leg_cents(tier_name: str) -> float:
            t = TIERS.get(tier_name, TIERS["s3-standard"])
            reqs = (P * t.write_request_cents_per_1m
                    + 3 * P * t.read_request_cents_per_1m) / 1e6
            transfer = nbytes / 2**30 * (t.read_transfer_cents_per_gib
                                         + t.write_transfer_cents_per_gib)
            wait_s = 2 * nbytes / t.bandwidth_bytes_per_s
            compute = (wait_s * self.worker_memory_gib
                       * LAMBDA_CENTS_PER_GIB_S)
            return (reqs + transfer + compute
                    + t.storage_cost_cents(int(nbytes), ttl_s))

        express = leg_cents("s3-express")
        base = leg_cents(base_tier)
        return "s3-express" if express < base else base_tier

    # -- cost-optimal fleet sizing (adaptive re-optimization) -------------------
    def fleet_latency_s(self, n_workers: int, nbytes: int, *,
                        bandwidth_bytes_per_s: float = 90e6,
                        fixed_s: float = 0.05) -> float:
        """Projected pipeline latency with ``n_workers`` sharing
        ``nbytes`` of input: per-worker startup/dispatch overhead plus
        its byte share over one storage connection."""
        share = nbytes / max(n_workers, 1)
        return fixed_s + share / bandwidth_bytes_per_s

    def fleet_cost_cents(self, n_workers: int, nbytes: int, *,
                         bandwidth_bytes_per_s: float = 90e6,
                         fixed_s: float = 0.05) -> float:
        """Projected fleet dollars: per-worker fixed charges (invoke +
        response messages + startup compute) plus the byte-proportional
        scan compute, which is invariant in the fleet size. Strictly
        increasing in ``n_workers`` — parallelism buys latency, never
        dollars."""
        per_worker = (LAMBDA_CENTS_PER_REQUEST + 2 * SQS_CENTS_PER_REQUEST
                      + fixed_s * self.worker_memory_gib
                      * LAMBDA_CENTS_PER_GIB_S)
        scan_s = nbytes / bandwidth_bytes_per_s
        return (n_workers * per_worker
                + scan_s * self.worker_memory_gib * LAMBDA_CENTS_PER_GIB_S)

    def optimal_fleet(self, nbytes: int, *, latency_budget_s: float,
                      max_workers: int,
                      bandwidth_bytes_per_s: float = 90e6,
                      fixed_s: float = 0.05,
                      memory_fill_fraction: float = 0.5) -> int:
        """Dollar-minimal fleet size subject to a latency budget.

        ``fleet_cost_cents`` is strictly increasing and
        ``fleet_latency_s`` strictly decreasing in the worker count, so
        the cost-optimal feasible fleet is the *smallest* one whose
        projected latency fits the budget — computed in closed form —
        with two floors: every worker's input share must fit the
        function's memory budget, and the fleet never exceeds
        ``max_workers`` (quota / partition granularity); if the budget
        is unreachable even at ``max_workers``, latency wins and the cap
        is returned.
        """
        max_workers = max(1, max_workers)
        span = latency_budget_s - fixed_s
        if span <= 0:
            w = max_workers
        else:
            w = math.ceil(nbytes / (span * bandwidth_bytes_per_s))
        mem_budget = self.worker_memory_gib * 2**30 * memory_fill_fraction
        w = max(w, math.ceil(nbytes / max(mem_budget, 1)), 1)
        return min(w, max_workers)

    # -- pipelined overlap accounting --------------------------------------------
    @staticmethod
    def overlapped_io_s(total_io_s: float, first_batch_s: float,
                        efficiency: float = PIPELINE_OVERLAP_EFFICIENCY
                        ) -> tuple[float, float]:
        """Effective I/O wall time for a double-buffered consumer, and
        the simulated seconds the overlap saved.

        The first batch is always exposed (nothing to overlap against);
        of the remaining ``total_io_s - first_batch_s`` read time, the
        overlap efficiency's share hides behind kernel compute. Returns
        ``(effective_io_s, saved_s)``.
        """
        first = min(max(first_batch_s, 0.0), max(total_io_s, 0.0))
        rest = max(total_io_s - first, 0.0)
        saved = efficiency * rest
        return total_io_s - saved, saved

    @staticmethod
    def pipeline_admission_fraction(completions_s: list[float], *,
                                    topup_overhead_s: float = 0.01,
                                    efficiency: float =
                                    PIPELINE_OVERLAP_EFFICIENCY) -> float:
        """Cost-model-chosen consumer admission fraction k/n.

        For each candidate k, the expected consumer finish is the k-th
        producer completion (the admission wait), plus the overlap
        residue of the producer tail it still has to read — the
        ``1 - efficiency`` share of the spread ``c[n-1] - c[k-1]`` a
        double-buffered consumer cannot hide — plus a per-top-up
        overhead for the ``n - k`` partitions drained after launch
        (one mostly-hidden ranged GET: about a tier first-byte
        latency, so ~0.01 s). Skewed fleets (stragglers) admit early
        to hide the tail; exactly-uniform fleets admit late, where the
        k-statistic is the same instant anyway and top-ups are pure
        overhead. An empty completion list (no observations yet)
        falls back to 0.5, the pre-cost-model constant.
        """
        c = sorted(float(x) for x in completions_s or [])
        n = len(c)
        if n == 0:
            return 0.5
        best_k, best_cost = n, None
        for k in range(1, n + 1):
            cost = (c[k - 1]
                    + (1.0 - efficiency) * (c[-1] - c[k - 1])
                    + topup_overhead_s * (n - k))
            if best_cost is None or cost < best_cost - 1e-12:
                best_k, best_cost = k, cost
        return best_k / n

    @staticmethod
    def pipeline_start_offset_s(completions_s: list[float],
                                fraction: float) -> float:
        """When a consumer pipeline may start: the k-th order statistic
        of its producers' completion times, k = ⌈fraction · n⌉ — i.e.
        the moment the admission gate's partition fraction is met. An
        empty producer list (cache hits) starts immediately."""
        if not completions_s:
            return 0.0
        k = max(1, math.ceil(fraction * len(completions_s)))
        return sorted(completions_s)[k - 1]

    @staticmethod
    def stage_latency_budget(deadline_s: float, elapsed_s: float,
                             stages_left: int,
                             floor_s: float = 1e-3) -> float:
        """Per-stage latency budget from a query-level SLO deadline.

        The remaining deadline (simulated seconds) is split evenly over
        the stages still to run. A query running *behind* its deadline
        gets the floor — a near-zero budget that drives
        ``optimal_fleet`` to the cap, i.e. a missed deadline escalates
        the fleet instead of giving up.
        """
        remaining = deadline_s - elapsed_s
        return max(remaining, floor_s) / max(stages_left, 1)
