"""Cost model for serverless compute and storage (paper Tables 1 and 3).

Skyrise is cost-aware end-to-end: the optimizer sizes worker fleets and
picks shuffle tiers against these prices, and the evaluation (Fig. 6)
reports per-query dollars. Prices are AWS us-east-1, ARM Lambda, as used in
the paper's experiments (Aug 2024 – Jan 2025).
"""

from __future__ import annotations

import dataclasses

from repro.storage.tiers import TIERS

# -- Table 1: compute -----------------------------------------------------------

# Lambda (ARM): 4.8 ¢/GiB-h at the largest sizes → ¢ per GiB-second.
LAMBDA_CENTS_PER_GIB_S = 4.8 / 3600.0
LAMBDA_CENTS_PER_REQUEST = 0.2 / 10_000.0       # $0.20 per 1M invocations
SQS_CENTS_PER_REQUEST = 0.4 / 10_000.0          # $0.40 per 1M requests

# EC2 (C6g) for comparison benchmarks: 1.7 ¢/GiB-h.
EC2_CENTS_PER_GIB_S = 1.7 / 3600.0

# -- Table 2: startup latency [seconds] -------------------------------------------

LAMBDA_COLD_START = {"min": 0.122, "max": 0.451, "avg": 0.185}
LAMBDA_WARM_START = {"min": 0.005, "max": 0.009, "avg": 0.006}
EC2_COLD_START = {"min": 12.795, "max": 22.817, "avg": 15.226}
EC2_WARM_START = {"min": 9.810, "max": 19.288, "avg": 11.512}


@dataclasses.dataclass
class CostBreakdown:
    compute_cents: float = 0.0
    invoke_cents: float = 0.0
    messaging_cents: float = 0.0
    storage_request_cents: float = 0.0
    storage_transfer_cents: float = 0.0

    @property
    def total_cents(self) -> float:
        return (self.compute_cents + self.invoke_cents
                + self.messaging_cents + self.storage_request_cents
                + self.storage_transfer_cents)

    def merge(self, other: "CostBreakdown") -> None:
        self.compute_cents += other.compute_cents
        self.invoke_cents += other.invoke_cents
        self.messaging_cents += other.messaging_cents
        self.storage_request_cents += other.storage_request_cents
        self.storage_transfer_cents += other.storage_transfer_cents


class CostModel:
    """Charges workers (GiB-s + invocations + queue messages) and storage
    requests/transfers per tier."""

    def __init__(self, worker_memory_gib: float = 2.0):
        self.worker_memory_gib = worker_memory_gib

    def worker_cost(self, runtime_s: float,
                    tier_ops: dict) -> CostBreakdown:
        out = CostBreakdown()
        out.compute_cents = (runtime_s * self.worker_memory_gib
                             * LAMBDA_CENTS_PER_GIB_S)
        out.invoke_cents = LAMBDA_CENTS_PER_REQUEST
        # one response message to the coordinator's queue (send+receive)
        out.messaging_cents = 2 * SQS_CENTS_PER_REQUEST
        for tier_name, ops in tier_ops.items():
            tier = TIERS.get(tier_name, TIERS["s3-standard"])
            out.storage_request_cents += (
                ops["get"] * tier.read_request_cents_per_1m / 1e6
                + ops["put"] * tier.write_request_cents_per_1m / 1e6)
            out.storage_transfer_cents += (
                ops["bytes_read"] / 2**30 * tier.read_transfer_cents_per_gib
                + ops["bytes_written"] / 2**30
                * tier.write_transfer_cents_per_gib)
        return out

    def coordinator_cost(self, runtime_s: float) -> CostBreakdown:
        out = CostBreakdown()
        out.compute_cents = (runtime_s * self.worker_memory_gib
                             * LAMBDA_CENTS_PER_GIB_S)
        out.invoke_cents = LAMBDA_CENTS_PER_REQUEST
        return out
