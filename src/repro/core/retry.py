"""Unified retry policy and typed failure taxonomy (paper section 3.3).

Serverless infrastructure fails *routinely*: the companion measurement
study (arXiv 2501.07771) documents transient function failures, S3
throttling, and heavy first-byte tails as structural properties of FaaS
— not rare events. Skyrise's answer is a single classification every
layer shares:

  * :class:`TransientInfraError` — the infrastructure hiccuped (sandbox
    died, storage 503'd, a coordination write was lost mid-protocol).
    Retrying the *same* work is safe and expected to succeed: workers
    are idempotent single-object writers, registry/ledger protocols are
    re-entrant. Every layer retries these under one
    :class:`RetryPolicy` — bounded exponential backoff with full
    jitter — spending from one per-query :class:`RetryBudget`.
  * :class:`QueryFailedError` — the query itself is broken (bad plan,
    deterministic worker failure, exhausted retries). Never retried;
    surfaced through ``QueryHandle.result()`` with the causal chain
    from the failing fragment intact.
  * :class:`RetryBudgetExhausted` — the transient classification was
    right but the infrastructure stayed down past the budget. A
    *permanent* failure (subclass of ``QueryFailedError``) that still
    records the last transient cause.

This module is a leaf — no repro imports — so the storage, platform,
registry, ledger, and engine layers can all share it without cycles.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np


class QueryFailedError(RuntimeError):
    """Permanent query failure: retrying the same work cannot help."""


class TransientInfraError(RuntimeError):
    """Retryable infrastructure failure (sandbox death, storage 503,
    throttling, a coordination write lost mid-protocol)."""


class RetryBudgetExhausted(QueryFailedError):
    """The per-query transient-retry budget ran out: the failures were
    individually retryable, but the infrastructure stayed down.
    ``last_error`` (also chained via ``__cause__``) is the final
    transient cause."""

    def __init__(self, msg: str, *, last_error: BaseException | None = None,
                 spent: int = 0):
        super().__init__(msg)
        self.last_error = last_error
        self.spent = spent


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with *full jitter*.

    The delay before retry ``attempt`` (1-based) is drawn uniformly from
    ``[0, min(max_delay_s, base_delay_s * multiplier**(attempt-1))]`` —
    full jitter decorrelates the retry storms of a whole fleet hitting
    one throttled prefix (synchronized backoff re-creates the very
    contention it is escaping). Delays are *wall-clock* sleeps of the
    coordinator thread; they are deliberately tiny because a simulated
    platform fails instantly — against a real backend the base would be
    tens of milliseconds.

    ``budget`` bounds transient retries *per query* across every layer
    (fragment re-invokes, query-level protocol retries); ``query_retries``
    bounds how often a whole plan execution is re-driven after a
    coordinator-side transient (registry/ledger/KV chaos).
    """

    base_delay_s: float = 0.002
    max_delay_s: float = 0.05
    multiplier: float = 2.0
    budget: int = 32
    query_retries: int = 5

    def backoff_s(self, attempt: int,
                  rng: np.random.Generator | None = None) -> float:
        cap = min(self.max_delay_s,
                  self.base_delay_s * self.multiplier ** max(attempt - 1, 0))
        if rng is None:
            rng = np.random.default_rng()
        return float(rng.uniform(0.0, cap))


class RetryBudget:
    """Thread-safe per-query retry allowance, spent by every layer that
    retries a transient failure (fragment re-invocation, query-level
    re-drive). Exhaustion turns the *next* transient into a permanent
    :class:`RetryBudgetExhausted`."""

    def __init__(self, budget: int):
        self.budget = max(int(budget), 0)
        self._spent = 0
        self._lock = threading.Lock()

    @property
    def spent(self) -> int:
        with self._lock:
            return self._spent

    def remaining(self) -> int:
        with self._lock:
            return self.budget - self._spent

    def try_spend(self, n: int = 1) -> bool:
        """Reserve ``n`` retries; False (nothing spent) if that would
        overdraw the budget."""
        with self._lock:
            if self._spent + n > self.budget:
                return False
            self._spent += n
            return True


def is_transient(exc: BaseException) -> bool:
    """Shared transient-vs-permanent classification: a typed transient
    that is *not* also a typed permanent failure. (``QueryFailedError``
    wins when a subclass inherits both — permanence is sticky.)"""
    return isinstance(exc, TransientInfraError) \
        and not isinstance(exc, QueryFailedError)
