"""Central (intermediate) result registry (paper section 3.4).

Pipeline results are registered under their *semantic hash* — computed from
the logical plan after logical optimization, before physical properties —
so semantically equivalent results match independent of the number/size of
the workers that produced them. Before scheduling a pipeline, the
coordinator consults the registry and skips cache hits.

Backed by the low-latency KV tier (DynamoDB analog) of the object store.

In-flight dedup (cross-query plan sharing): concurrent queries wanting
the same ``sem_hash`` share one execution instead of racing idempotently.
The protocol is ``claim`` / ``publish`` / ``await_complete``:

  * ``claim(h)`` — conditional-put analog: writes an *incomplete* entry
    and returns True iff no complete or in-flight entry existed; exactly
    one of N concurrent claimants wins and executes the pipeline;
  * losers call ``await_complete(h)`` and block until the owner
    ``publish``-es the finished entry (they then treat it as a cache
    hit) or ``abandon``-s the claim (owner failed/cancelled — a waiter
    re-claims and executes itself);
  * claims live in the same KV tier as results, so dedup spans *all*
    sessions sharing one store, not just queries inside one session.
"""

from __future__ import annotations

import threading
import time
import uuid

import msgpack

from repro.storage.object_store import ObjectStore

# One process-wide lock serializes claim writes across every registry
# instance sharing this interpreter (the conditional-put analog needs a
# read-check-write critical section). Waiter wake-ups go through the
# store's ``watch`` primitive instead: publish/abandon are ordinary
# puts/deletes, which the KV backend turns into notifications (memory)
# or version-poll wake-ups with exponential backoff (filesystem) — no
# billed KV reads happen while waiting.
_CLAIM_LOCK = threading.Lock()


class ResultRegistry:
    def __init__(self, store: ObjectStore, namespace: str = "registry",
                 claim_ttl_s: float = 60.0):
        self.store = store.with_tier("dynamodb")
        self.namespace = namespace
        # A claim whose owner died without abandoning (process killed)
        # must not hang waiters forever: past the TTL it counts as
        # abandoned and the next claimant steals it. Stealing a claim
        # whose owner is merely slow is safe — workers are idempotent
        # single-object writers, so a racing duplicate execution only
        # wastes invocations, never corrupts results.
        self.claim_ttl_s = claim_ttl_s
        self.claims = 0         # executions this registry won via claim()
        self.dedup_hits = 0     # await_complete() calls resolved by a peer
        self._owned: dict[str, str] = {}    # sem_hash → our claim token

    def _key(self, sem_hash: str) -> str:
        return f"{self.namespace}/{sem_hash}"

    def _read(self, sem_hash: str) -> dict | None:
        key = self._key(sem_hash)
        if not self.store.exists(key):
            return None
        return msgpack.unpackb(self.store.get(key).data)

    def lookup(self, sem_hash: str) -> dict | None:
        """Returns the result's physical layout metadata, or None (absent
        entries and in-flight claims both miss)."""
        entry = self._read(sem_hash)
        return entry if entry and entry.get("complete") else None

    # -- in-flight dedup -----------------------------------------------------
    def _stale(self, entry: dict) -> bool:
        return (not entry.get("complete")
                and time.time() - entry.get("claimed_at", 0.0)
                > self.claim_ttl_s)

    def claim(self, sem_hash: str) -> bool:
        """Atomically claim execution of ``sem_hash``.

        True → the caller owns the (single) execution and must finish
        with ``publish`` or ``abandon``. False → the result is already
        complete or another query is executing it (``await_complete``).
        A claim older than ``claim_ttl_s`` is stolen (orphaned owner).
        """
        with _CLAIM_LOCK:
            entry = self._read(sem_hash)
            if entry is not None and not self._stale(entry):
                return False
            token = uuid.uuid4().hex
            self.store.put(self._key(sem_hash), msgpack.packb(
                {"complete": False, "claimed_at": time.time(),
                 "owner": token}))
            self._owned[sem_hash] = token
            self.claims += 1
            return True

    def publish(self, sem_hash: str, *, prefix: str, n_fragments: int,
                partitioning: dict, schema: list[dict],
                stats: dict | None = None) -> None:
        """Register the finished result and wake every waiter."""
        self.register(sem_hash, prefix=prefix, n_fragments=n_fragments,
                      partitioning=partitioning, schema=schema,
                      stats=stats)
        # the put itself is the notification: store watchers wake
        self._owned.pop(sem_hash, None)

    def abandon(self, sem_hash: str) -> None:
        """Drop an unfinished claim (owner failed or was cancelled) so a
        waiter can re-claim and run the pipeline itself. Only the claim
        this registry wrote is deleted — if the claim was TTL-stolen in
        the meantime, the stealer's live claim stays untouched."""
        with _CLAIM_LOCK:
            token = self._owned.pop(sem_hash, None)
            entry = self._read(sem_hash)
            if (entry is not None and not entry.get("complete")
                    and entry.get("owner") == token):
                # the delete is the notification: store watchers wake
                self.store.delete(self._key(sem_hash))

    def await_complete(self, sem_hash: str,
                       cancel_check=None) -> dict | None:
        """Block until the in-flight execution of ``sem_hash`` resolves.

        Returns the complete entry if the owner published it (treat as a
        cache hit), or None if the claim was abandoned — explicitly, or
        implicitly by exceeding ``claim_ttl_s`` (orphaned owner) —
        after which the caller should try to ``claim`` again.
        ``cancel_check`` is polled while waiting and may raise to abort
        the wait.

        Waiting is *event-driven*: the claim key's version token is
        captured before each read, then ``store.watch`` blocks until a
        writer changes the key (publish overwrites it, abandon deletes
        it) or the claim's TTL runs out. Version observation is a HEAD
        analog, so no billed KV requests are issued while waiting — the
        billed re-read happens once per actual change.
        """
        key = self._key(sem_hash)
        while True:
            # token BEFORE read: a publish that lands between the two is
            # caught by watch() returning immediately on the stale token
            token = self.store.version(key)
            entry = self._read(sem_hash)
            if entry is None or self._stale(entry):
                return None
            if entry.get("complete"):
                self.dedup_hits += 1
                return entry
            if cancel_check is not None:
                cancel_check()
            # wake on publish/abandon, or when the TTL can have expired
            # (orphaned owner) — whichever comes first
            ttl_left = self.claim_ttl_s - (time.time()
                                           - entry.get("claimed_at", 0.0))
            self.store.watch(key, token, timeout_s=max(ttl_left, 0.0) + 0.01,
                             cancel_check=cancel_check)

    # -- completed entries ---------------------------------------------------
    def register(self, sem_hash: str, *, prefix: str, n_fragments: int,
                 partitioning: dict, schema: list[dict],
                 stats: dict | None = None) -> None:
        self.store.put(self._key(sem_hash), msgpack.packb({
            "complete": True,
            "prefix": prefix,
            "n_fragments": n_fragments,
            "partitioning": partitioning,
            "schema": schema,
            "stats": stats or {},
        }))

    def invalidate(self, sem_hash: str) -> None:
        self.store.delete(self._key(sem_hash))
