"""Central (intermediate) result registry (paper section 3.4).

Pipeline results are registered under their *semantic hash* — computed from
the logical plan after logical optimization, before physical properties —
so semantically equivalent results match independent of the number/size of
the workers that produced them. Before scheduling a pipeline, the
coordinator consults the registry and skips cache hits.

Backed by the low-latency KV tier (DynamoDB analog) of the object store.

In-flight dedup (cross-query plan sharing): concurrent queries wanting
the same ``sem_hash`` share one execution instead of racing idempotently.
The protocol is ``claim`` / ``publish`` / ``await_complete``:

  * ``claim(h)`` — conditional-put analog: writes an *incomplete* entry
    and returns True iff no complete or in-flight entry existed; exactly
    one of N concurrent claimants wins and executes the pipeline;
  * losers call ``await_complete(h)`` and block until the owner
    ``publish``-es the finished entry (they then treat it as a cache
    hit) or ``abandon``-s the claim (owner failed/cancelled — a waiter
    re-claims and executes itself);
  * claims live in the same KV tier as results, so dedup spans *all*
    sessions sharing one store, not just queries inside one session.

Incremental (pipelined) manifests: alongside the all-or-nothing entry at
``{ns}/{h}``, a producing pipeline streams per-fragment completion into a
*partial manifest* at ``{ns}/{h}.partial`` (and ``{ns}/{h}.l0`` for a
multilevel exchange's level-0 objects). Each ``publish_partial`` is a
versioned read-modify-write whose put doubles as the notification — the
same ``ObjectStore.watch`` wake-up that backs claim waiting — so
consumers block on *manifest versions*, not polling loops. A consumer is
released by ``await_source_ready`` once (a) the producer fleet is fully
submitted to the platform (``all_submitted`` — the deadlock-freedom gate:
waiters then only ever wait on already-running workers) and (b) a
configurable fraction of producer partitions has landed. Streams are
*sealed* (flagged complete) by ``finish_partial`` when the producer fleet
is done — deleting them would race consumers mid-top-up; they are only
deleted with the main entry by ``invalidate``. A dying producer flags its
streams ``aborted`` so in-flight consumer workers fail fast instead of
timing out.
"""

from __future__ import annotations

import math
import threading
import time
import uuid

import msgpack

from repro.storage.object_store import ObjectStore

# One process-wide lock serializes claim writes across every registry
# instance sharing this interpreter (the conditional-put analog needs a
# read-check-write critical section). Waiter wake-ups go through the
# store's ``watch`` primitive instead: publish/abandon are ordinary
# puts/deletes, which the KV backend turns into notifications (memory)
# or version-poll wake-ups with exponential backoff (filesystem) — no
# billed KV reads happen while waiting.
_CLAIM_LOCK = threading.Lock()

# Suffixes of the incremental-manifest side keys riding next to a result
# entry. "partial" is the pipeline's main output stream; "l0" is the
# multilevel exchange's level-0 stream (merge wave input).
PARTIAL_STREAMS = ("partial", "l0")


def read_manifest(store: ObjectStore, key: str) -> dict | None:
    """Worker-side manifest read: ``store`` must already be on the KV
    tier and ``key`` fully namespace-resolved (the fragment spec carries
    it verbatim). Fragments use this with ``store.watch`` for their
    top-up loop without constructing a registry."""
    if not store.exists(key):
        return None
    return msgpack.unpackb(store.get(key).data)


def partitions_ready(manifest: dict, fraction: float | None,
                     *, cost_model=None) -> bool:
    """The consumer-admission gate: a configurable fraction of producer
    partitions landed AND every producer invocation has been submitted
    to the platform's FIFO executor. The second condition is what keeps
    pipelined waiting deadlock-free — an admitted consumer only ever
    waits on producers that are already running or queued ahead of it.

    ``fraction=None`` delegates the choice to the cost model: the
    landed partitions' producer wall times (``wall_s`` in the manifest
    infos) are a pilot sample of the fleet's runtime skew, and
    ``cost_model.pipeline_admission_fraction`` picks the fraction that
    minimizes the expected consumer finish under them. No observations
    (or no cost model) fall back to the 0.5 constant."""
    if manifest.get("complete"):
        return True
    if not manifest.get("all_submitted"):
        return False
    done = manifest.get("done") or {}
    if fraction is None:
        walls = [i["wall_s"] for i in done.values()
                 if isinstance(i, dict) and i.get("wall_s") is not None]
        fraction = (cost_model.pipeline_admission_fraction(walls)
                    if cost_model is not None and walls else 0.5)
    n = max(1, int(manifest.get("n_producers") or 1))
    need = max(1, math.ceil(fraction * n))
    return len(done) >= need


class ResultRegistry:
    def __init__(self, store: ObjectStore, namespace: str = "registry",
                 claim_ttl_s: float = 60.0, *,
                 result_ttl_s: float | None = None,
                 max_entries: int | None = None):
        self.store = store.with_tier("dynamodb")
        self.namespace = namespace
        # A claim whose owner died without abandoning (process killed)
        # must not hang waiters forever: past the TTL it counts as
        # abandoned and the next claimant steals it. Stealing a claim
        # whose owner is merely slow is safe — workers are idempotent
        # single-object writers, so a racing duplicate execution only
        # wastes invocations, never corrupts results.
        self.claim_ttl_s = claim_ttl_s
        # Bounded-cache policy (the registry otherwise grows without
        # bound): entries older than ``result_ttl_s`` expire lazily at
        # lookup; past ``max_entries`` complete entries, the lowest
        # keep-score — recompute cost divided by age, so old *and*
        # cheap-to-recompute results go first — is evicted.
        self.result_ttl_s = result_ttl_s
        self.max_entries = max_entries
        self.claims = 0         # executions this registry won via claim()
        self.dedup_hits = 0     # await_complete() calls resolved by a peer
        self.evictions = 0      # TTL expirations + capacity evictions
        self._owned: dict[str, str] = {}    # sem_hash → our claim token

    def _key(self, sem_hash: str) -> str:
        return f"{self.namespace}/{sem_hash}"

    def partial_key(self, sem_hash: str, stream: str = "partial") -> str:
        return f"{self.namespace}/{sem_hash}.{stream}"

    def _read(self, sem_hash: str) -> dict | None:
        key = self._key(sem_hash)
        if not self.store.exists(key):
            return None
        return msgpack.unpackb(self.store.get(key).data)

    def lookup(self, sem_hash: str) -> dict | None:
        """Returns the result's physical layout metadata, or None (absent
        entries and in-flight claims both miss). Entries older than
        ``result_ttl_s`` expire lazily here — the expired entry is
        deleted and the lookup misses, so the caller recomputes."""
        entry = self._read(sem_hash)
        if not (entry and entry.get("complete")):
            return None
        if self._expired(entry):
            self.invalidate(sem_hash)
            self.evictions += 1
            return None
        return entry

    def _expired(self, entry: dict) -> bool:
        return (self.result_ttl_s is not None
                and time.time() - entry.get("published_at", time.time())
                > self.result_ttl_s)

    # -- in-flight dedup -----------------------------------------------------
    def _stale(self, entry: dict) -> bool:
        return (not entry.get("complete")
                and time.time() - entry.get("claimed_at", 0.0)
                > self.claim_ttl_s)

    def _chaos(self):
        return getattr(self.store, "chaos", None)

    def _kill_once(self, site: str) -> None:
        chaos = self._chaos()
        if chaos is not None:
            chaos.kill_once(site)

    def claim(self, sem_hash: str) -> bool:
        """Atomically claim execution of ``sem_hash``.

        True → the caller owns the (single) execution and must finish
        with ``publish`` or ``abandon``. False → the result is already
        complete or another query is executing it (``await_complete``).
        A claim older than ``claim_ttl_s`` is stolen (orphaned owner).

        The claim write is a *versioned CAS*: the claimant captures the
        key's version token before deciding and the put lands only if
        the key is still at that version. Two waiters observing the same
        TTL-expired claim both decide to steal — exactly one conditional
        put wins; the loser sees the version move and backs off to
        ``await_complete``. (The in-process lock only serializes local
        claimants; cross-process exclusion comes from the CAS.)
        """
        key = self._key(sem_hash)
        with _CLAIM_LOCK:
            token0 = self.store.version(key)
            entry = self._read(sem_hash)
            if entry is not None and not self._stale(entry):
                return False
            token = uuid.uuid4().hex
            blob = msgpack.packb({"complete": False,
                                  "claimed_at": time.time(),
                                  "owner": token})
            if not self.store.put_if_version(key, blob, token0):
                return False    # lost the steal race to another claimant
            # chaos: owner dies right after writing its claim and before
            # recording ownership — the claim is orphaned (no abandon
            # path) and must be TTL-stolen by a waiter
            self._kill_once("registry.claim")
            self._owned[sem_hash] = token
            self.claims += 1
            return True

    def publish(self, sem_hash: str, *, prefix: str, n_fragments: int,
                partitioning: dict, schema: list[dict],
                stats: dict | None = None,
                cost_cents: float = 0.0) -> None:
        """Register the finished result and wake every waiter."""
        self.register(sem_hash, prefix=prefix, n_fragments=n_fragments,
                      partitioning=partitioning, schema=schema,
                      stats=stats, cost_cents=cost_cents)
        # the put itself is the notification: store watchers wake
        self._owned.pop(sem_hash, None)

    def abandon(self, sem_hash: str) -> None:
        """Drop an unfinished claim (owner failed or was cancelled) so a
        waiter can re-claim and run the pipeline itself. Only the claim
        this registry wrote is deleted — if the claim was TTL-stolen in
        the meantime, the stealer's live claim stays untouched."""
        with _CLAIM_LOCK:
            token = self._owned.pop(sem_hash, None)
            entry = self._read(sem_hash)
            if (entry is not None and not entry.get("complete")
                    and entry.get("owner") == token):
                # the delete is the notification: store watchers wake
                self.store.delete(self._key(sem_hash))

    def await_complete(self, sem_hash: str,
                       cancel_check=None) -> dict | None:
        """Block until the in-flight execution of ``sem_hash`` resolves.

        Returns the complete entry if the owner published it (treat as a
        cache hit), or None if the claim was abandoned — explicitly, or
        implicitly by exceeding ``claim_ttl_s`` (orphaned owner) —
        after which the caller should try to ``claim`` again.
        ``cancel_check`` is polled while waiting and may raise to abort
        the wait.

        Waiting is *event-driven*: the claim key's version token is
        captured before each read, then ``store.watch`` blocks until a
        writer changes the key (publish overwrites it, abandon deletes
        it) or the claim's TTL runs out. Version observation is a HEAD
        analog, so no billed KV requests are issued while waiting — the
        billed re-read happens once per actual change.
        """
        key = self._key(sem_hash)
        while True:
            # token BEFORE read: a publish that lands between the two is
            # caught by watch() returning immediately on the stale token
            token = self.store.version(key)
            entry = self._read(sem_hash)
            if entry is None or self._stale(entry):
                return None
            if entry.get("complete"):
                self.dedup_hits += 1
                return entry
            if cancel_check is not None:
                cancel_check()
            # wake on publish/abandon, or when the TTL can have expired
            # (orphaned owner) — whichever comes first
            ttl_left = self.claim_ttl_s - (time.time()
                                           - entry.get("claimed_at", 0.0))
            self.store.watch(key, token, timeout_s=max(ttl_left, 0.0) + 0.01,
                             cancel_check=cancel_check)

    # -- incremental (pipelined) manifests -----------------------------------
    def begin_partial(self, sem_hash: str, *, stream: str = "partial",
                      n_producers: int, prefix: str,
                      partitioning: dict | None = None,
                      schema: list[dict] | None = None) -> str:
        """Open a partial manifest before any producer runs, so consumers
        admitted mid-fleet already see the layout metadata. Returns the
        manifest key (fragment specs carry it verbatim).

        The manifest is written *fresh*: any leftover state belongs to a
        dead prior owner (an ``aborted`` flag from an execution whose
        claim this caller just re-won must not poison the new run, and
        stale ``done`` entries will be republished idempotently)."""
        key = self.partial_key(sem_hash, stream)
        with _CLAIM_LOCK:
            old = read_manifest(self.store, key)
            man = {"done": {}, "all_submitted": False, "aborted": False,
                   "version": (old or {}).get("version", 0) + 1,
                   "n_producers": n_producers, "prefix": prefix,
                   "partitioning": partitioning, "schema": schema}
            self.store.put(key, msgpack.packb(man))
            # chaos: owner dies right after opening the stream — the
            # fresh manifest (no done entries) is orphaned; the re-won
            # claim rewrites it fresh
            self._kill_once("registry.begin_partial")
        return key

    def publish_partial(self, sem_hash: str, fragment: int, info: dict, *,
                        stream: str = "partial",
                        n_producers: int | None = None) -> None:
        """Record one producer fragment's completed output (its stats +
        written layout) in the stream's partial manifest. The put wakes
        every watcher — this is the per-partition publish event that
        replaces the stage barrier. ``n_producers`` may grow past the
        planned fleet when a failing fragment is reassigned (split)."""
        key = self.partial_key(sem_hash, stream)
        with _CLAIM_LOCK:
            man = read_manifest(self.store, key) or {
                "done": {}, "all_submitted": False, "aborted": False,
                "version": 0}
            man["done"][str(fragment)] = info
            if n_producers is not None:
                man["n_producers"] = max(n_producers,
                                         man.get("n_producers") or 0)
            man["version"] += 1
            self.store.put(key, msgpack.packb(man))
        # chaos: owner dies right after landing one partition — consumers
        # may already be topping up from it; the abort/abandon path must
        # poison the stream and let a waiter re-run the pipeline
        self._kill_once("registry.publish_partial")
        if "bloom" in info:
            # chaos: owner dies right after landing a semi-join filter
            # shard — a probe waiting on the sealed filter must see the
            # abort (or its wait timeout) and fall back to unfiltered
            self._kill_once("registry.publish_filter")

    def mark_all_submitted(self, sem_hash: str, n_producers: int, *,
                           stream: str = "partial") -> None:
        """Flag that every producer invocation sits in the platform's
        FIFO executor queue. Consumers are only admitted after this —
        they then wait exclusively on work scheduled ahead of them, so
        the wait-for graph stays acyclic at any quota."""
        key = self.partial_key(sem_hash, stream)
        with _CLAIM_LOCK:
            man = read_manifest(self.store, key)
            if man is None:
                return
            man["all_submitted"] = True
            man["n_producers"] = max(n_producers,
                                     man.get("n_producers") or 0)
            man["version"] += 1
            self.store.put(key, msgpack.packb(man))

    def abort_partial(self, sem_hash: str) -> None:
        """Poison every stream of a failed producer pipeline: waiters
        (engine gates and in-flight consumer workers) see ``aborted``
        and raise instead of blocking until their wait timeout."""
        for stream in PARTIAL_STREAMS:
            key = self.partial_key(sem_hash, stream)
            with _CLAIM_LOCK:
                man = read_manifest(self.store, key)
                if man is None or man.get("aborted"):
                    continue
                man["aborted"] = True
                man["version"] += 1
                self.store.put(key, msgpack.packb(man))

    def finish_partial(self, sem_hash: str, *,
                       n_producers: int | None = None,
                       stream: str = "partial") -> None:
        """Seal a stream: every producer (including reassignment splits)
        has published, so ``n_producers`` is final and in-flight top-up
        loops may drain and stop watching. The manifest stays until
        ``invalidate`` deletes it with the main entry — removing it here
        would race consumers still reading their last top-up batch."""
        # chaos: owner dies with every producer done but the stream not
        # yet sealed — the next owner re-runs and seals
        self._kill_once("registry.finish_partial")
        key = self.partial_key(sem_hash, stream)
        with _CLAIM_LOCK:
            man = read_manifest(self.store, key)
            if man is None:
                return
            man["complete"] = True
            man["all_submitted"] = True
            if n_producers is not None:
                man["n_producers"] = n_producers
            man["version"] += 1
            self.store.put(key, msgpack.packb(man))

    def partial_manifest(self, sem_hash: str,
                         stream: str = "partial") -> dict | None:
        return read_manifest(self.store,
                             self.partial_key(sem_hash, stream))

    def await_source_ready(self, sem_hash: str, *,
                           fraction: float | None,
                           cost_model=None,
                           stream: str = "partial", cancel_check=None,
                           timeout_s: float | None = None,
                           min_published_at: float | None = None
                           ) -> dict | None:
        """Block until ``sem_hash`` is readable as a consumer input:
        either barrier-complete (returns the complete entry) or
        partially available past the admission gate (returns ``None`` —
        the caller reads the partial manifest and tops up). Raises
        RuntimeError if the producer aborted, TimeoutError past
        ``timeout_s``.

        ``min_published_at`` is the consumer's freshness floor: a
        complete entry published before it is *stale* — left by an
        earlier query whose producer fleet (and therefore object
        layout) may differ from the one re-executing right now — and
        is ignored in favor of the live partial stream."""
        key = self.partial_key(sem_hash, stream)
        deadline = None if timeout_s is None else time.time() + timeout_s
        while True:
            token = self.store.version(key)
            entry = self._read(sem_hash)
            if entry is not None and entry.get("complete") \
                    and (min_published_at is None
                         or entry.get("published_at", 0.0)
                         >= min_published_at):
                return entry
            man = read_manifest(self.store, key)
            if man is not None:
                if man.get("aborted"):
                    raise RuntimeError(
                        f"producer pipeline {sem_hash[:12]} aborted")
                if partitions_ready(man, fraction, cost_model=cost_model):
                    return None
            if cancel_check is not None:
                cancel_check()
            if deadline is not None and time.time() >= deadline:
                raise TimeoutError(
                    f"source {sem_hash[:12]} not ready after {timeout_s}s")
            # Bounded watch: a peer session may barrier-publish the main
            # entry without ever touching this stream's partial key, so
            # re-check the complete entry at least every quarter second.
            self.store.watch(key, token, timeout_s=0.25,
                             cancel_check=cancel_check)

    # -- completed entries ---------------------------------------------------
    def register(self, sem_hash: str, *, prefix: str, n_fragments: int,
                 partitioning: dict, schema: list[dict],
                 stats: dict | None = None,
                 cost_cents: float = 0.0) -> None:
        self.store.put(self._key(sem_hash), msgpack.packb({
            "complete": True,
            "prefix": prefix,
            "n_fragments": n_fragments,
            "partitioning": partitioning,
            "schema": schema,
            "stats": stats or {},
            "published_at": time.time(),
            "cost_cents": cost_cents,
        }))
        if self.max_entries is not None:
            self._evict_over_capacity()

    def _evict_over_capacity(self) -> None:
        """Evict complete entries past ``max_entries``, lowest keep-score
        first. Keep-score = recompute cost / age: a result that was
        expensive to produce or was published recently is worth cache
        space; an old, cheap one is not (age × recompute-cost policy)."""
        names = [k for k in self.store.list(f"{self.namespace}/")
                 if "." not in k.rsplit("/", 1)[-1]]
        scored: list[tuple[float, str]] = []
        for key in names:
            sem = key.rsplit("/", 1)[-1]
            entry = self._read(sem)
            if not (entry and entry.get("complete")):
                continue    # in-flight claims are not cache entries
            age = max(time.time() - entry.get("published_at", 0.0), 1e-6)
            scored.append((entry.get("cost_cents", 0.0) / age, sem))
        excess = len(scored) - self.max_entries
        if excess <= 0:
            return
        scored.sort()
        for _, sem in scored[:excess]:
            self.invalidate(sem)
            self.evictions += 1

    def invalidate(self, sem_hash: str) -> None:
        self.store.delete(self._key(sem_hash))
        for stream in PARTIAL_STREAMS:
            self.store.delete(self.partial_key(sem_hash, stream))
