"""Central (intermediate) result registry (paper section 3.4).

Pipeline results are registered under their *semantic hash* — computed from
the logical plan after logical optimization, before physical properties —
so semantically equivalent results match independent of the number/size of
the workers that produced them. Before scheduling a pipeline, the
coordinator consults the registry and skips cache hits.

Backed by the low-latency KV tier (DynamoDB analog) of the object store.
"""

from __future__ import annotations

import msgpack

from repro.storage.object_store import ObjectStore


class ResultRegistry:
    def __init__(self, store: ObjectStore, namespace: str = "registry"):
        self.store = store.with_tier("dynamodb")
        self.namespace = namespace

    def _key(self, sem_hash: str) -> str:
        return f"{self.namespace}/{sem_hash}"

    def lookup(self, sem_hash: str) -> dict | None:
        """Returns the result's physical layout metadata, or None."""
        key = self._key(sem_hash)
        if not self.store.exists(key):
            return None
        entry = msgpack.unpackb(self.store.get(key).data)
        return entry if entry.get("complete") else None

    def register(self, sem_hash: str, *, prefix: str, n_fragments: int,
                 partitioning: dict, schema: list[dict],
                 stats: dict | None = None) -> None:
        self.store.put(self._key(sem_hash), msgpack.packb({
            "complete": True,
            "prefix": prefix,
            "n_fragments": n_fragments,
            "partitioning": partitioning,
            "schema": schema,
            "stats": stats or {},
        }))

    def invalidate(self, sem_hash: str) -> None:
        self.store.delete(self._key(sem_hash))
