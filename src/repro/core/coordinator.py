"""Deprecated single-query coordinator facade.

The execution machinery lives in :mod:`repro.core.engine`
(``QueryEngine``); multi-query sessions live in :mod:`repro.api`
(``connect`` / ``SkyriseSession``). ``QueryCoordinator`` remains as a
thin shim so pre-session call sites keep working:

    coord = QueryCoordinator(store, catalog, platform=platform)
    res = coord.execute_sql("select ...")

New code should use the session API instead::

    from repro.api import connect
    session = connect(store=store, catalog=catalog, platform=platform)
    res = session.sql("select ...")
"""

from __future__ import annotations

import warnings

# Re-exported for backward compatibility: these names historically lived
# in this module.
from repro.core.engine import (CoordinatorConfig, PipelineReport,  # noqa: F401
                               QueryAborted, QueryEngine, QueryResult,
                               QueryStats)


class QueryCoordinator(QueryEngine):
    """Deprecated alias for :class:`repro.core.engine.QueryEngine`.

    Each instance owns a private registry handle and worker handler bound
    to ``store`` (the historical behavior); the semantic result cache is
    still shared across coordinators through the store itself.
    """

    def __init__(self, store, catalog, *, platform=None, config=None,
                 cost_model=None):
        warnings.warn(
            "QueryCoordinator is deprecated; use repro.api.connect() — "
            "a SkyriseSession shares one platform quota, worker handler, "
            "and result cache across concurrent queries",
            DeprecationWarning, stacklevel=2)
        super().__init__(store, catalog, platform=platform, config=config,
                         cost_model=cost_model)
