"""Skyrise query coordinator (paper sections 3.1, 3.3).

A coordinator instance manages the lifecycle of exactly one query: it
compiles SQL to pipelines, schedules them stage-wise by dependency,
invokes one worker function per fragment (two-level √W fan-out for large
fleets), tracks worker progress, and adapts:

  * stragglers → re-triggered mid-query (safe: workers are idempotent and
    write deterministic single objects; racing duplicates overwrite
    identical results);
  * transient infrastructure failures → bounded retries; on repeated
    failure the fragment's input units are *reassigned to more workers*;
  * deterministic (code/data) failures → abort; completed pipelines stay
    registered, so a re-run restarts from the last complete stage
    (stage results are checkpoints);
  * completed pipelines are registered in the result cache under their
    semantic hash and skipped by later queries (section 3.4).

The coordinator is stateless between queries: everything it needs is in
the catalog, the registry, and the object store.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.cost import CostBreakdown, CostModel
from repro.core.platform import FaasPlatform, InvocationResult
from repro.core.registry import ResultRegistry
from repro.core.worker import make_worker_handler
from repro.data.catalog import Catalog
from repro.sql.logical import Binder
from repro.sql.parser import parse
from repro.sql.physical import (PhysicalPlan, Pipeline, PlannerConfig,
                                compile_query)
from repro.sql.rules import optimize
from repro.storage.io_handlers import InputHandler
from repro.storage.object_store import ObjectStore


class QueryAborted(RuntimeError):
    def __init__(self, msg: str, post_mortem: dict):
        super().__init__(msg)
        self.post_mortem = post_mortem


@dataclasses.dataclass
class PipelineReport:
    pid: int
    sem_hash: str
    n_fragments: int
    cache_hit: bool = False
    attempts: int = 0
    stragglers_retriggered: int = 0
    transient_failures: int = 0
    reassignments: int = 0
    sim_s: float = 0.0
    rows_out: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    requests: int = 0


@dataclasses.dataclass
class QueryStats:
    sim_latency_s: float = 0.0
    wall_s: float = 0.0
    pipelines: list[PipelineReport] = dataclasses.field(default_factory=list)
    cost: CostBreakdown = dataclasses.field(default_factory=CostBreakdown)

    @property
    def cache_hits(self) -> int:
        return sum(1 for p in self.pipelines if p.cache_hit)


@dataclasses.dataclass
class QueryResult:
    location: str
    output_names: list[str]
    stats: QueryStats

    def fetch(self, store: ObjectStore) -> dict[str, np.ndarray]:
        cols, _, _ = InputHandler(store).read_table(self.location)
        return cols


@dataclasses.dataclass
class CoordinatorConfig:
    planner: PlannerConfig = dataclasses.field(default_factory=PlannerConfig)
    straggler_detect_factor: float = 3.0
    straggler_min_timeout_s: float = 0.5
    max_attempts: int = 3
    two_level_threshold: int = 16
    response_poll_overhead_s: float = 0.01
    use_result_cache: bool = True


class QueryCoordinator:
    def __init__(self, store: ObjectStore, catalog: Catalog, *,
                 platform: FaasPlatform | None = None,
                 config: CoordinatorConfig | None = None,
                 cost_model: CostModel | None = None):
        self.store = store
        self.catalog = catalog
        self.platform = platform or FaasPlatform()
        self.config = config or CoordinatorConfig()
        self.cost_model = cost_model or CostModel()
        self.registry = ResultRegistry(store)
        self.handler = make_worker_handler(store)

    # -- public API ----------------------------------------------------------
    def execute_sql(self, sql: str) -> QueryResult:
        stmt = parse(sql)
        lqp, _ = Binder(self.catalog).bind(stmt)
        lqp = optimize(lqp)
        plan = compile_query(lqp, self.catalog, self.config.planner)
        return self.execute_plan(plan)

    def execute_plan(self, plan: PhysicalPlan) -> QueryResult:
        t_wall = time.perf_counter()
        stats = QueryStats()
        for stage in plan.stages():
            stage_sim = 0.0
            for pid in stage:
                report = self._run_pipeline(plan.pipelines[pid], stats)
                stats.pipelines.append(report)
                stage_sim = max(stage_sim, report.sim_s)
            stats.sim_latency_s += stage_sim
        stats.wall_s = time.perf_counter() - t_wall
        stats.cost.merge(
            self.cost_model.coordinator_cost(stats.sim_latency_s))
        root = plan.pipelines[plan.root_pid]
        location = f"results/{root.sem_hash}/f0000/out.spax"
        return QueryResult(location, plan.output_names, stats)

    # -- pipeline scheduling ----------------------------------------------------
    def _run_pipeline(self, p: Pipeline, stats: QueryStats) -> PipelineReport:
        report = PipelineReport(p.pid, p.sem_hash, p.n_fragments)
        if self.config.use_result_cache and self.registry.lookup(p.sem_hash):
            report.cache_hit = True
            return report

        prefix = f"results/{p.sem_hash}"
        sources = self._resolve_sources(p.op)
        specs = {
            f: self._fragment_spec(p, f, p.n_fragments, prefix, sources)
            for f in range(p.n_fragments)
        }

        cfg = self.config
        two_level = p.n_fragments >= cfg.two_level_threshold
        dispatch = self.platform.dispatch_time_s(p.n_fragments,
                                                 two_level=two_level)
        completions: dict[int, float] = {}
        results: dict[int, InvocationResult] = {}
        extra_fragments: list[dict] = []

        # Quota-bounded waves (admission control).
        order = list(specs)
        wave_start = 0.0
        for wave in self.platform.wave_sizes(len(order)):
            frags = order[:wave]
            order = order[wave:]
            for f in frags:
                res = self._run_fragment(p, specs[f], report, stats,
                                         extra_fragments)
                results[f] = res
                completions[f] = wave_start + res.sim_runtime_s
            wave_start = max((completions[f] for f in frags),
                             default=wave_start)

        # Straggler mitigation: detect against the fleet's fast quartile
        # (the median is already contaminated in small or straggler-heavy
        # fleets), then re-trigger; the effective completion races the
        # original against the duplicate — safe because workers are
        # idempotent single-object writers.
        if len(completions) >= 2:
            runtimes = np.array(list(completions.values()))
            fast = float(np.percentile(runtimes, 25, method="lower"))
            threshold = max(cfg.straggler_detect_factor * fast,
                            cfg.straggler_min_timeout_s)
            for f, t in list(completions.items()):
                if t > threshold:
                    dup = self._invoke(p, specs[f], report, stats,
                                       attempt=100 + report.attempts)
                    report.stragglers_retriggered += 1
                    if dup.error is None:
                        completions[f] = min(t, threshold
                                             + dup.sim_runtime_s)

        report.sim_s = (dispatch + max(completions.values(), default=0.0)
                        + cfg.response_poll_overhead_s)

        n_total = p.n_fragments + len(extra_fragments)
        self.registry.register(
            p.sem_hash, prefix=prefix, n_fragments=n_total,
            partitioning=p.partitioning.to_dict(), schema=p.output_schema,
            stats={"rows_out": report.rows_out})
        return report

    # -- fragment execution with retries/reassignment -----------------------------
    def _run_fragment(self, p: Pipeline, spec: dict,
                      report: PipelineReport, stats: QueryStats,
                      extra_fragments: list[dict]) -> InvocationResult:
        attempt = 0
        total_runtime = 0.0
        while True:
            res = self._invoke(p, spec, report, stats, attempt=attempt)
            total_runtime += res.sim_runtime_s
            if res.error is None:
                res.sim_runtime_s = total_runtime
                return res
            report.transient_failures += 1
            attempt += 1
            if attempt >= self.config.max_attempts:
                raise QueryAborted(
                    f"pipeline {p.pid} fragment {spec['fragment']} failed "
                    f"{attempt} times",
                    post_mortem={"pipeline": p.pid,
                                 "fragment": spec["fragment"],
                                 "attempts": attempt,
                                 "last_error": res.error})
            # Reassignment: after two failures, split a multi-unit
            # fragment's inputs across an additional fresh worker.
            if attempt >= 2 and len(spec["scan_units"]) > 1:
                spec, extra = self._split_fragment(p, spec,
                                                   len(extra_fragments))
                extra_fragments.append(extra)
                report.reassignments += 1
                eres = self._invoke(p, extra, report, stats,
                                    attempt=attempt)
                if eres.error is not None:
                    raise QueryAborted(
                        "reassigned fragment failed",
                        post_mortem={"pipeline": p.pid,
                                     "fragment": extra["fragment"]})
                total_runtime += 0.0  # runs in parallel with the retry

    def _split_fragment(self, p: Pipeline, spec: dict, n_extra: int):
        units = spec["scan_units"]
        half = len(units) // 2
        new_frag = p.n_fragments + n_extra
        first = dict(spec, scan_units=units[:half])
        second = dict(spec, scan_units=units[half:], fragment=new_frag)
        return first, second

    def _invoke(self, p: Pipeline, spec: dict, report: PipelineReport,
                stats: QueryStats, *, attempt: int) -> InvocationResult:
        report.attempts += 1
        res = self.platform.invoke(self.handler, spec, pipeline=p.pid,
                                   fragment=spec["fragment"],
                                   attempt=attempt)
        tier_ops = {}
        if res.payload is not None:
            s = res.payload["stats"]
            tier_ops = s["tier_ops"]
            report.rows_out += s["rows_out"]
            report.bytes_read += s["bytes_read"]
            report.bytes_written += s["bytes_written"]
            report.requests += s["requests"]
        stats.cost.merge(
            self.cost_model.worker_cost(res.sim_runtime_s, tier_ops))
        return res

    # -- plumbing -------------------------------------------------------------
    def _resolve_sources(self, op: dict) -> dict:
        sources: dict[str, dict] = {}

        def collect(o: dict):
            if o["t"] == "scan_exchange":
                entry = self.registry.lookup(o["source"])
                if entry is None:
                    raise QueryAborted(
                        f"upstream result {o['source']} missing",
                        post_mortem={"source": o["source"]})
                sources[o["source"]] = entry
            for k in ("child", "probe", "build"):
                if k in o:
                    collect(o[k])
        collect(op)
        return sources

    def _fragment_spec(self, p: Pipeline, f: int, n: int, prefix: str,
                       sources: dict) -> dict:
        return {
            "query_id": p.sem_hash,
            "pipeline": p.pid,
            "fragment": f,
            "n_fragments": n,
            "op": p.op,
            "scan_units": p.scan_units[f::n],
            "output": {"prefix": prefix,
                       "partitioning": p.partitioning.to_dict(),
                       "schema": p.output_schema},
            "sources": sources,
        }
