"""Execution observers: structured event hooks replacing ad-hoc prints.

The per-query engine emits lifecycle and adaptive-behavior events; a
session multiplexes them to any number of registered observers. Override
only the hooks you need — every method is a no-op by default, and
observer exceptions never fail a query.
"""

from __future__ import annotations


class QueryObserver:
    """Base class: subclass and override the events you care about.

    Concurrency contract: fragments execute wall-clock-parallel, so
    ``on_retry`` (and hooks of different queries in one session) may
    fire concurrently from worker threads — observers that mutate
    shared state must synchronize it themselves.
    """

    def on_query_state(self, query_id: str, state: str) -> None:
        """Lifecycle transition (QUEUED/PLANNING/RUNNING/...)."""

    def on_pipeline_start(self, query_id: str, pid: int, sem_hash: str,
                          n_fragments: int) -> None:
        """A pipeline was scheduled (not a cache hit)."""

    def on_pipeline_complete(self, query_id: str, report) -> None:
        """A pipeline finished; ``report`` is a PipelineReport
        (``report.cache_hit`` distinguishes cache skips)."""

    def on_straggler(self, query_id: str, pid: int, fragment: int) -> None:
        """A straggling worker was detected and re-triggered."""

    def on_retry(self, query_id: str, pid: int, fragment: int,
                 attempt: int) -> None:
        """A failed fragment is being retried (transient failure)."""

    def on_adaptation(self, query_id: str, pid: int,
                      adaptation: dict) -> None:
        """A barrier re-optimization was applied to a pipeline before
        launch (fleet_resize / partition_prune / broadcast_downgrade /
        exchange_retier — see ``repro.core.adaptive``)."""


class ObserverMux(QueryObserver):
    """Fans events out to many observers; isolates their failures."""

    def __init__(self, observers: list[QueryObserver] | None = None):
        self.observers: list[QueryObserver] = list(observers or [])

    def add(self, observer: QueryObserver) -> None:
        self.observers.append(observer)

    def _emit(self, method: str, *args) -> None:
        for obs in self.observers:
            try:
                getattr(obs, method)(*args)
            except Exception:  # noqa: BLE001 - observers must not kill queries
                pass

    def on_query_state(self, query_id, state):
        self._emit("on_query_state", query_id, state)

    def on_pipeline_start(self, query_id, pid, sem_hash, n_fragments):
        self._emit("on_pipeline_start", query_id, pid, sem_hash,
                   n_fragments)

    def on_pipeline_complete(self, query_id, report):
        self._emit("on_pipeline_complete", query_id, report)

    def on_straggler(self, query_id, pid, fragment):
        self._emit("on_straggler", query_id, pid, fragment)

    def on_retry(self, query_id, pid, fragment, attempt):
        self._emit("on_retry", query_id, pid, fragment, attempt)

    def on_adaptation(self, query_id, pid, adaptation):
        self._emit("on_adaptation", query_id, pid, adaptation)


class ConsoleObserver(QueryObserver):
    """Prints a compact execution trace (the old ad-hoc prints, unified)."""

    def __init__(self, out=None):
        import sys
        self.out = out or sys.stderr

    def _p(self, msg: str) -> None:
        print(msg, file=self.out, flush=True)

    def on_query_state(self, query_id, state):
        self._p(f"[{query_id}] {state}")

    def on_pipeline_start(self, query_id, pid, sem_hash, n_fragments):
        self._p(f"[{query_id}] pipeline {pid} ({sem_hash[:8]}) → "
                f"{n_fragments} workers")

    def on_pipeline_complete(self, query_id, report):
        if report.deduped:
            tag = "shared in-flight execution"
        elif report.cache_hit:
            tag = "cache hit"
        else:
            tag = f"{report.attempts} attempts, {report.sim_s:.2f}s sim"
        self._p(f"[{query_id}] pipeline {report.pid} done ({tag})")

    def on_straggler(self, query_id, pid, fragment):
        self._p(f"[{query_id}] straggler re-triggered: "
                f"pipeline {pid} fragment {fragment}")

    def on_retry(self, query_id, pid, fragment, attempt):
        self._p(f"[{query_id}] retry: pipeline {pid} fragment {fragment} "
                f"attempt {attempt}")

    def on_adaptation(self, query_id, pid, adaptation):
        self._p(f"[{query_id}] adapt: pipeline {pid} "
                f"{adaptation.get('kind')} {adaptation}")
