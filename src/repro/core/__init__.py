"""The paper's primary contribution: the fully serverless query-processing
runtime — per-query execution engine, FaaS platform model with cross-query
admission control, adaptive straggler re-triggering, failure taxonomy with
stage-checkpoint restart, semantic result cache, elastic worker sizing,
and the end-to-end cost model.

The public client entry point is :mod:`repro.api` (``connect()`` →
``SkyriseSession``); this package holds the engine underneath it.
"""

from repro.core.adaptive import Reoptimizer
from repro.core.chaos import ChaosConfig, ChaosEngine, ChaosKill
from repro.core.coordinator import QueryCoordinator
from repro.core.cost import CostBreakdown, CostModel
from repro.core.engine import (CoordinatorConfig, PipelineReport,
                               QueryAborted, QueryCancelled, QueryEngine,
                               QueryResult, QueryStats, explain_analyze,
                               explain_plan)
from repro.core.events import ConsoleObserver, ObserverMux, QueryObserver
from repro.core.platform import (AdmissionController, FaasPlatform,
                                 FaultPlan)
from repro.core.registry import ResultRegistry
from repro.core.retry import (QueryFailedError, RetryBudgetExhausted,
                              RetryPolicy, TransientInfraError)

__all__ = [
    "AdmissionController", "ChaosConfig", "ChaosEngine", "ChaosKill",
    "ConsoleObserver", "CoordinatorConfig", "CostBreakdown", "CostModel",
    "FaasPlatform", "FaultPlan", "ObserverMux", "PipelineReport",
    "QueryAborted", "QueryCancelled", "QueryCoordinator", "QueryEngine",
    "QueryFailedError", "QueryObserver", "QueryResult", "QueryStats",
    "Reoptimizer", "ResultRegistry", "RetryBudgetExhausted", "RetryPolicy",
    "TransientInfraError", "explain_analyze", "explain_plan",
]
