"""The paper's primary contribution: the fully serverless query-processing
runtime — per-query coordinator, FaaS platform model, adaptive straggler
re-triggering, failure taxonomy with stage-checkpoint restart, semantic
result cache, elastic worker sizing, and the end-to-end cost model."""

from repro.core.coordinator import (CoordinatorConfig, QueryAborted,
                                    QueryCoordinator, QueryResult,
                                    QueryStats)
from repro.core.cost import CostBreakdown, CostModel
from repro.core.platform import FaasPlatform, FaultPlan
from repro.core.registry import ResultRegistry

__all__ = [
    "CoordinatorConfig", "CostBreakdown", "CostModel", "FaasPlatform",
    "FaultPlan", "QueryAborted", "QueryCoordinator", "QueryResult",
    "QueryStats", "ResultRegistry",
]
