"""Serverless query-worker function (the Lambda handler, section 3.3).

Deserializes the invocation payload into a fragment plan, executes it, and
returns the response message the coordinator polls from its queue: result
location plus execution statistics used for adaptive behavior and billing.
"""

from __future__ import annotations

from repro.exec.fragment import execute_fragment
from repro.storage.object_store import ObjectStore


def make_worker_handler(store: ObjectStore):
    def handler(payload: dict) -> tuple[dict, float]:
        result = execute_fragment(store, payload)
        stats = result.stats
        sim_runtime = stats.sim_io_s + stats.compute_s
        response = {
            "fragment": payload["fragment"],
            "output_keys": result.output_keys,
            "stats": {
                "rows_in": stats.rows_in,
                "rows_out": stats.rows_out,
                "sim_io_s": stats.sim_io_s,
                "compute_s": stats.compute_s,
                "requests": stats.requests,
                "retriggers": stats.retriggers,
                "bytes_read": stats.bytes_read,
                "bytes_written": stats.bytes_written,
                "tier_ops": stats.tier_ops,
            },
        }
        return response, sim_runtime
    return handler
