"""Serverless query-worker function (the Lambda handler, section 3.3).

Deserializes the invocation payload into a fragment plan, executes it, and
returns the response message the coordinator polls from its queue: result
location plus execution statistics used for adaptive behavior and billing.

One handler is shared by every fragment of a session (the "code package"),
so the SPAX footer cache it owns is session-scoped: F fragments scanning G
partitions parse each footer once per object version.
"""

from __future__ import annotations

from repro.core.cost import PIPELINE_OVERLAP_EFFICIENCY, CostModel
from repro.exec.fragment import execute_fragment
from repro.storage.io_handlers import FooterCache
from repro.storage.object_store import ObjectStore


def make_worker_handler(store: ObjectStore,
                        footer_cache: FooterCache | None = None,
                        cost_model: CostModel | None = None):
    # cost_model (optional): enables hedged reads — workers re-trigger
    # tail-latency GETs at the tier's break-even timeout instead of the
    # constant straggler timeout
    cache = footer_cache if footer_cache is not None else FooterCache()

    def handler(payload: dict) -> tuple[dict, float]:
        result = execute_fragment(store, payload, footer_cache=cache,
                                  cost_model=cost_model)
        stats = result.stats
        if stats.pipelined:
            # Double-buffered consumption: only the first available
            # batch's read time is exposed; later top-up batches hide
            # behind kernel compute at the model's overlap efficiency.
            eff_io, saved = CostModel.overlapped_io_s(
                stats.sim_io_s, stats.first_input_s,
                PIPELINE_OVERLAP_EFFICIENCY)
            stats.overlap_saved_s = saved
            sim_runtime = eff_io + stats.compute_s
        else:
            sim_runtime = stats.sim_io_s + stats.compute_s
        response = {
            "fragment": payload["fragment"],
            "output_keys": result.output_keys,
            # per-destination (rows, bytes, distinct-key sketch) — the
            # exchange-manifest statistics the adaptive re-optimizer
            # consumes at the next stage barrier
            "partition_stats": result.partition_stats,
            # build-side semi-join filter shard (Bloom words) — the
            # coordinator OR-merges these across the fleet and publishes
            # the merged filter through the partial-manifest protocol
            "bloom": result.bloom,
            "stats": {
                "rows_in": stats.rows_in,
                "rows_out": stats.rows_out,
                "sim_io_s": stats.sim_io_s,
                "compute_s": stats.compute_s,
                "requests": stats.requests,
                "retriggers": stats.retriggers,
                "bytes_read": stats.bytes_read,
                "bytes_written": stats.bytes_written,
                "footer_cache_hits": stats.footer_cache_hits,
                "kernel": stats.kernel,
                "tier_ops": stats.tier_ops,
                "pipelined": stats.pipelined,
                "first_input_s": stats.first_input_s,
                "topups": stats.topups,
                "overlap_saved_s": stats.overlap_saved_s,
                "semijoin_killed": stats.semijoin_killed,
            },
        }
        return response, sim_runtime

    handler.footer_cache = cache
    return handler
