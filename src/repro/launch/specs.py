"""ShapeDtypeStruct input specs per (arch × shape) cell — the dry-run's
stand-ins for real tensors (no device allocation, weak-type-correct,
shardable). Also builds the step callable each cell lowers."""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import decode as decode_lib
from repro.models import steps as steps_lib
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.model import init_params
from repro.optim import AdamW


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: ShapeConfig
    kind: str                   # train | prefill | decode
    step_fn: object             # callable to lower
    arg_specs: tuple            # ShapeDtypeStruct pytrees
    donate_argnums: tuple = ()
    skip_reason: str | None = None


def shape_of(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def params_specs(cfg: ModelConfig, dtype=jnp.float32):
    fn = partial(init_params, cfg, jax.random.PRNGKey(0), dtype)
    return jax.eval_shape(fn)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.enc_dec:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    fn = partial(decode_lib.init_cache, cfg, shape.global_batch,
                 shape.seq_len, jnp.bfloat16)
    return jax.eval_shape(fn)


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention at 524k sequence — skipped per "
                "assignment; runs for SSM/hybrid archs only")
    return None


def build_cell(cfg: ModelConfig, arch: str, shape_name: str, *,
               mesh=None, optimizer: AdamW | None = None,
               remat: bool = True, scan_layers: bool = True,
               accum_steps: int = 1) -> CellSpec:
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return CellSpec(arch, shape, shape.kind, None, (), (), reason)

    if shape.kind == "train":
        opt = optimizer or AdamW()
        p_specs = params_specs(cfg, jnp.float32)
        o_specs = jax.eval_shape(opt.init, p_specs)
        b_specs = batch_specs(cfg, shape)
        step = steps_lib.make_train_step(cfg, opt, mesh=mesh, remat=remat,
                                         scan_layers=scan_layers,
                                         accum_steps=accum_steps)
        return CellSpec(arch, shape, "train", step,
                        (p_specs, o_specs, b_specs), donate_argnums=(0, 1))
    if shape.kind == "prefill":
        p_specs = params_specs(cfg, jnp.bfloat16)
        b_specs = batch_specs(cfg, shape)
        b_specs.pop("labels")
        step = steps_lib.make_prefill_step(cfg, mesh=mesh,
                                           scan_layers=scan_layers)
        return CellSpec(arch, shape, "prefill", step, (p_specs, b_specs))
    # decode
    p_specs = params_specs(cfg, jnp.bfloat16)
    c_specs = cache_specs(cfg, shape)
    t_spec = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    step = steps_lib.make_serve_step(cfg, mesh=mesh,
                                         scan_layers=scan_layers)
    return CellSpec(arch, shape, "decode", step,
                    (p_specs, c_specs, t_spec), donate_argnums=(1,))
