"""SQL query driver: the end-to-end Skyrise entry point.

  PYTHONPATH=src python -m repro.launch.sql --sf 0.05 --query q12
  PYTHONPATH=src python -m repro.launch.sql --sf 0.01 \
      --sql "select count(*) as n from lineitem where l_quantity < 10"
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import CoordinatorConfig, FaasPlatform, QueryCoordinator
from repro.data import generate_tpch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import FilesystemBackend, ObjectStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--query", default="q12", choices=list(QUERIES))
    ap.add_argument("--sql", default=None)
    ap.add_argument("--store-dir", default=None,
                    help="persist the store on disk (reused across runs)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--tier", default="s3-standard")
    args = ap.parse_args()

    backend = FilesystemBackend(args.store_dir) if args.store_dir else None
    store = ObjectStore(backend, tier=args.tier)
    catalog_key = f"tpch/sf{args.sf:g}/catalog"
    if store.exists(catalog_key):
        from repro.data.catalog import Catalog
        catalog = Catalog.load(store, catalog_key)
        print(f"[sql] reusing existing TPC-H sf={args.sf:g}")
    else:
        print(f"[sql] generating TPC-H sf={args.sf:g} …")
        catalog = generate_tpch(store, sf=args.sf)

    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=512 << 10),
        use_result_cache=not args.no_cache)
    coord = QueryCoordinator(store, catalog, platform=FaasPlatform(),
                             config=cfg)
    sql = args.sql or QUERIES[args.query]
    res = coord.execute_sql(sql)
    cols = res.fetch(store)
    s = res.stats

    print(f"\n[sql] result @ {res.location}")
    names = [n for n in res.output_names if n in cols]
    print(" | ".join(f"{n:>16s}" for n in names))
    n_rows = len(next(iter(cols.values()))) if cols else 0
    for i in range(min(n_rows, 20)):
        print(" | ".join(f"{cols[n][i]:>16.4f}"
                         if np.issubdtype(cols[n].dtype, np.floating)
                         else f"{cols[n][i]:>16}" for n in names))
    if n_rows > 20:
        print(f"… {n_rows - 20} more rows")
    print(f"\n[sql] sim latency {s.sim_latency_s:.2f}s · wall "
          f"{s.wall_s:.2f}s · cost {s.cost.total_cents:.4f}¢ · "
          f"workers {sum(p.n_fragments for p in s.pipelines)} · "
          f"cache hits {s.cache_hits}/{len(s.pipelines)}")


if __name__ == "__main__":
    main()
