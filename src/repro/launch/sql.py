"""SQL query driver: the end-to-end Skyrise entry point.

Built on the public client API (``repro.api``): one ``SkyriseSession``
owns the object store, the FaaS platform (with its concurrency quota),
and the semantic result cache; queries are *submitted* and run
concurrently against that shared infrastructure.

  PYTHONPATH=src python -m repro.launch.sql --sf 0.05 --query q12
  PYTHONPATH=src python -m repro.launch.sql --query q1,q6,q12   # concurrent
  PYTHONPATH=src python -m repro.launch.sql --query q3 --explain
  PYTHONPATH=src python -m repro.launch.sql --query q12 --analyze
  PYTHONPATH=src python -m repro.launch.sql --sf 0.01 \
      --sql "select count(*) as n from lineitem where l_quantity < 10"
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.api import ConsoleObserver, CoordinatorConfig, connect
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES


def _print_result(session, handle, analyze: bool = False) -> None:
    res = handle.result()
    cols = res.fetch(session.store)
    s = res.stats

    if analyze:
        print(f"\n[{handle.query_id}] {handle.explain_analyze()}")
    print(f"\n[{handle.query_id}] result @ {res.locations}")
    names = [n for n in res.output_names if n in cols]
    print(" | ".join(f"{n:>16s}" for n in names))
    n_rows = len(next(iter(cols.values()))) if cols else 0
    for i in range(min(n_rows, 20)):
        print(" | ".join(f"{cols[n][i]:>16.4f}"
                         if np.issubdtype(cols[n].dtype, np.floating)
                         else f"{cols[n][i]:>16}" for n in names))
    if n_rows > 20:
        print(f"… {n_rows - 20} more rows")
    n_adapt = sum(len(p.adaptations) for p in s.pipelines)
    print(f"[{handle.query_id}] sim latency {s.sim_latency_s:.2f}s · wall "
          f"{s.wall_s:.2f}s · cost {s.cost.total_cents:.4f}¢ · "
          f"workers {sum(p.n_fragments for p in s.pipelines)} · "
          f"cache hits {s.cache_hits}/{len(s.pipelines)} · "
          f"adaptations {n_adapt}")


def _print_service_result(session, handle) -> None:
    res = handle.result()
    entry = handle.entry()
    cols = res.fetch(session.store)
    names = [n for n in res.output_names if n in cols]
    print(f"\n[{handle.request_id}] {entry.status.value} "
          f"(tenant={entry.tenant}, attempt={entry.attempt})")
    print(" | ".join(f"{n:>16s}" for n in names))
    n_rows = len(next(iter(cols.values()))) if cols else 0
    for i in range(min(n_rows, 20)):
        print(" | ".join(f"{cols[n][i]:>16.4f}"
                         if np.issubdtype(cols[n].dtype, np.floating)
                         else f"{cols[n][i]:>16}" for n in names))
    if n_rows > 20:
        print(f"… {n_rows - 20} more rows")
    slo = ""
    if entry.deadline_s is not None:
        slo = (f" · deadline {entry.deadline_s:g}s "
               f"{'MISSED' if res.deadline_missed else 'met'}")
    print(f"[{handle.request_id}] sim latency {res.sim_latency_s:.2f}s · "
          f"cost {res.cost_cents:.4f}¢ · "
          f"cache hits {res.cache_hits}{slo}")


def _run_service(session, statements, args) -> None:
    """Route the queries through the durable service tier so the CLI
    exercises ledger + admission + SLO plumbing end-to-end."""
    from repro.service import QueryService, TenantConfig

    tenant = args.tenant or "cli"
    with QueryService(session, tenants=(TenantConfig(
            tenant, deadline_s=args.deadline,
            budget_cents=args.budget_cents),)) as svc:
        handles = [svc.submit(stmt, tenant=tenant) for stmt in statements]
        for handle in handles:
            _print_service_result(session, handle)
        st = svc.stats()
        t = st["tenants"][tenant]
        budget = ("unmetered" if t["budget_cents"] is None else
                  f"{t['window_spent_cents']:.4f}/{t['budget_cents']:g}¢")
        print(f"\n[sql] service {st['service_id']}: "
              f"{sum(st['requests_by_status'].values())} requests "
              f"{st['requests_by_status']} · tenant {tenant}: "
              f"budget {budget} · "
              f"throttled {t['throttled_admissions']} · "
              f"degraded {t['degraded_dispatches']} · "
              f"deadline misses {st['deadline_misses']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--query", default="q12",
                    help="named TPC-H queries, comma-separated "
                         f"(concurrent); choices: {list(QUERIES)}")
    ap.add_argument("--sql", default=None)
    ap.add_argument("--store-dir", default=None,
                    help="persist the store on disk (reused across runs)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--tier", default="s3-standard")
    ap.add_argument("--quota", type=int, default=1000,
                    help="shared function-concurrency quota")
    ap.add_argument("--explain", action="store_true",
                    help="print physical plans without executing")
    ap.add_argument("--analyze", action="store_true",
                    help="EXPLAIN ANALYZE: execute, then print est vs "
                         "actual rows and barrier adaptations")
    ap.add_argument("--static", action="store_true",
                    help="disable adaptive re-optimization at stage "
                         "barriers (compile-time plan runs as-is)")
    ap.add_argument("--verbose", action="store_true",
                    help="trace pipeline/straggler/retry events")
    ap.add_argument("--tenant", default=None,
                    help="run through the query service tier as this "
                         "tenant (durable ledger + fair-share admission)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="SLO deadline in simulated seconds — drives "
                         "per-stage latency budgets for fleet sizing "
                         "(implies the service tier)")
    ap.add_argument("--budget-cents", type=float, default=None,
                    help="tenant cost budget in cents per window — "
                         "over-budget runs degrade, then throttle "
                         "(implies the service tier)")
    args = ap.parse_args()

    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=512 << 10),
        use_result_cache=not args.no_cache,
        adaptive=not args.static)
    if args.sql:
        statements = [args.sql]
    else:
        names = [q.strip() for q in args.query.split(",") if q.strip()]
        unknown = [q for q in names if q not in QUERIES]
        if unknown:
            raise SystemExit(f"unknown queries {unknown}; "
                             f"choices: {list(QUERIES)}")
        statements = [QUERIES[q] for q in names]

    session = connect(store_dir=args.store_dir, tier=args.tier,
                      quota=args.quota, config=cfg,
                      observers=(ConsoleObserver(),) if args.verbose
                      else ())
    if session.store.exists(f"tpch/sf{args.sf:g}/catalog"):
        print(f"[sql] reusing existing TPC-H sf={args.sf:g}")
    else:
        print(f"[sql] generating TPC-H sf={args.sf:g} …")
    session.ensure_tpch(sf=args.sf)

    if args.explain:
        for stmt in statements:
            print(session.explain(stmt))
        return

    if args.tenant or args.deadline is not None \
            or args.budget_cents is not None:
        with session:
            _run_service(session, statements, args)
        return

    with session:
        handles = [session.submit(stmt) for stmt in statements]
        for handle in handles:
            _print_result(session, handle, analyze=args.analyze)
        if len(handles) > 1:
            st = session.stats()
            print(f"\n[sql] session: {st['queries_submitted']} queries · "
                  f"{st['platform_invocations']} invocations · peak "
                  f"{st['max_workers_in_flight']}/{st['quota']} workers "
                  f"in flight")


if __name__ == "__main__":
    main()
