import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: every cell
must partition over the production meshes — 16×16 (data, model) single pod
and 2×16×16 (pod, data, model) multi-pod — and fit per-device memory.
Emits the roofline terms per cell for EXPERIMENTS.md.

``--queries`` is the SQL analog: compile every TPC-H query to its
physical pipeline plan through the ``repro.api`` session (planning only —
zero workers invoked), proving planner coherence across scale factors.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --out bench/dryrun.jsonl
  python -m repro.launch.dryrun --queries [--sf 0.01]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.analysis import memtraffic
from repro.analysis import roofline as roofline_lib
from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.models.config import SHAPES
from repro.parallel import sharding as sh

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 2.0)


def _compile_one(cfg, arch, shape_name, mesh, plan, *, remat, donate,
                 scan_layers, optimizer=None, accum_steps=1):
    cell = build_cell(cfg, arch, shape_name, mesh=mesh, remat=remat,
                      scan_layers=scan_layers, optimizer=optimizer,
                      accum_steps=accum_steps)
    p_specs = cell.arg_specs[0]
    in_shardings: list = [sh.param_shardings(plan, p_specs)]
    if cell.kind == "train":
        in_shardings.append(
            sh.opt_state_shardings(plan, p_specs, cell.arg_specs[1]))
        in_shardings.append(sh.batch_shardings(plan, cell.arg_specs[2]))
        out_shardings = (in_shardings[0], in_shardings[1], None)
    elif cell.kind == "prefill":
        in_shardings.append(sh.batch_shardings(plan, cell.arg_specs[1]))
        out_shardings = (None, sh.cache_shardings(
            plan, jax.eval_shape(cell.step_fn, *cell.arg_specs)[1]))
    else:  # decode
        cache_sh = sh.cache_shardings(plan, cell.arg_specs[1])
        in_shardings.append(cache_sh)
        in_shardings.append(sh.batch_shardings(plan, cell.arg_specs[2]))
        out_shardings = (None, None, cache_sh)
    with mesh:
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=tuple(in_shardings),
            out_shardings=out_shardings,
            donate_argnums=cell.donate_argnums if donate else ())
        lowered = jitted.lower(*cell.arg_specs)
        compiled = lowered.compile()
    return compiled


def _with_layers(cfg, n: int):
    return dataclasses.replace(
        cfg, n_layers=n, enc_layers=(n if cfg.enc_dec else cfg.enc_layers))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               remat: bool = True, donate: bool = True,
               extra_config: dict | None = None,
               extrapolate: bool = True, optimizer=None,
               accum_steps: int = 1):
    """Compile the full scanned-layers artifact (the deliverable: proves
    sharding coherence + memory fit), then — because XLA cost analysis
    counts loop bodies once — compile unrolled L=2 / L=4 variants and fit
    cost(L) = a + b·L to report true full-depth roofline terms.

    Returns (record dict, compiled or None)."""
    cfg = get_config(arch)
    if extra_config:
        cfg = dataclasses.replace(cfg, **extra_config)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    plan = sh.make_plan(
        mesh, shard_sequence=(shape_name == "long_500k"
                              and cfg.family in ("ssm", "hybrid")))
    cell = build_cell(cfg, arch, shape_name, mesh=mesh, remat=remat)
    record = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }
    if cell.skip_reason:
        record["status"] = "skip"
        record["reason"] = cell.skip_reason
        return record, None

    # 1. Full-depth scanned artifact: compile proof + memory analysis +
    #    collective schedule.
    t0 = time.time()
    compiled = _compile_one(cfg, arch, shape_name, mesh, plan,
                            remat=remat, donate=donate, scan_layers=True,
                            optimizer=optimizer, accum_steps=accum_steps)
    record["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    record["memory_analysis"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    sched = roofline_lib.parse_collectives(compiled.as_text())
    record["collective_schedule"] = sched.count_by_kind

    # Analytic HBM model (CPU-backend scheduling is not TPU-representative
    # — see analysis/memtraffic.py).
    opt_bytes = 12.0
    if optimizer is not None and getattr(optimizer, "state_dtype", None) \
            is not None:
        import jax.numpy as _jnp
        if optimizer.state_dtype == _jnp.bfloat16:
            opt_bytes = 8.0          # f32 params + bf16 m/v
    mem = memtraffic.analyze_memory(
        cfg, shape, n_devices=record["n_devices"], dp=plan.dp_size,
        tp=mesh.shape["model"], kind=cell.kind,
        accum_steps=accum_steps, opt_bytes_per_param=opt_bytes)
    record["fits_hbm"] = mem.fits_hbm
    record["hbm_residency_bytes"] = mem.residency_bytes
    record["memory_detail"] = mem.detail

    # 2. Roofline terms via depth extrapolation (unrolled L=2, L=4).
    if extrapolate:
        costs = {}
        for lvar in (2, 4):
            cvar = _compile_one(_with_layers(cfg, lvar), arch, shape_name,
                                mesh, plan, remat=remat, donate=False,
                                scan_layers=False, optimizer=optimizer,
                                accum_steps=accum_steps)
            roof = roofline_lib.analyze(cvar)
            costs[lvar] = roof
        L = cfg.n_layers

        def fit(f2, f4):
            slope = (f4 - f2) / 2.0
            return max(f2 + slope * (L - 2), 0.0)

        flops = fit(costs[2].flops_per_device, costs[4].flops_per_device)
        nbytes = fit(costs[2].bytes_per_device, costs[4].bytes_per_device)
        cbytes = fit(costs[2].collective_bytes_per_device,
                     costs[4].collective_bytes_per_device)
        if accum_steps > 1:
            # the microbatch lax.scan body is counted once by XLA cost
            # analysis (same loop-body issue as layers): scale by the
            # accumulation factor (optimizer-update costs outside the
            # scan are <1% of a step for these models)
            flops *= accum_steps
            cbytes *= accum_steps
    else:
        roof = roofline_lib.analyze(compiled)
        flops, nbytes, cbytes = (roof.flops_per_device,
                                 roof.bytes_per_device,
                                 roof.collective_bytes_per_device)

    compute_s = flops / 197e12
    memory_s = mem.traffic_bytes / 819e9
    collective_s = cbytes / 50e9
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    record["roofline"] = {
        "flops_per_device": flops,
        "bytes_per_device": mem.traffic_bytes,
        "bytes_hlo_unfused": nbytes,
        "collective_bytes_per_device": cbytes,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
    }
    mf = roofline_lib.model_flops(get_config(arch), SHAPES[shape_name],
                                  train=(cell.kind == "train"))
    record["model_flops_global"] = mf
    hlo_global = flops * record["n_devices"]
    record["useful_flops_ratio"] = mf / hlo_global if hlo_global else 0.0
    bound = max(compute_s, memory_s, collective_s)
    record["roofline_fraction"] = (
        (mf / record["n_devices"] / 197e12) / bound if bound else 0.0)
    record["status"] = "ok"
    return record, compiled


def dryrun_queries(sf: float = 0.01, out: str | None = None) -> int:
    """Plan (never execute) all TPC-H queries; returns failure count."""
    from repro.api import connect
    from repro.sql.queries import QUERIES

    session = connect(tier="local")
    session.ensure_tpch(sf=sf, n_parts=4)
    out_f = open(out, "a") if out else None
    failures = 0
    for qname, sql in QUERIES.items():
        try:
            text = session.explain(sql)
            n_pipes = text.splitlines()[0]
            print(f"[ok]   {qname}: {n_pipes}")
            rec = {"query": qname, "sf": sf, "status": "ok",
                   "plan": text}
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"[FAIL] {qname}: {e!r}")
            rec = {"query": qname, "sf": sf, "status": "error",
                   "error": repr(e)}
        if out_f:
            out_f.write(json.dumps(rec) + "\n")
    if out_f:
        out_f.close()
    assert session.platform.invocations == 0, "dry-run invoked workers"
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default=None)
    # §Perf optimization knobs (EXPERIMENTS.md) — off = paper-faithful
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron sequence parallelism")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient accumulation steps")
    ap.add_argument("--bf16-adam", action="store_true",
                    help="bf16 optimizer moments")
    ap.add_argument("--queries", action="store_true",
                    help="SQL mode: compile all TPC-H plans (no "
                         "execution) through the repro.api session")
    ap.add_argument("--sf", type=float, default=0.01,
                    help="TPC-H scale factor for --queries")
    args = ap.parse_args()

    if args.queries:
        failures = dryrun_queries(sf=args.sf, out=args.out)
        if failures:
            raise SystemExit(f"{failures} query plans failed")
        return

    extra = {}
    if args.seq_parallel:
        extra["seq_parallel"] = True
    optimizer = None
    if args.bf16_adam:
        import jax.numpy as jnp
        from repro.optim import AdamW
        optimizer = AdamW(state_dtype=jnp.bfloat16)

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_f = open(args.out, "a") if args.out else None
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch} × {shape} × " \
                      f"{'2x16x16' if multi else '16x16'}"
                try:
                    rec, _ = lower_cell(arch, shape, multi_pod=multi,
                                        remat=not args.no_remat,
                                        extra_config=extra or None,
                                        optimizer=optimizer,
                                        accum_steps=args.accum)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "error", "error": repr(e)}
                    failures += 1
                    traceback.print_exc()
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"[ok]   {tag}: compile={rec['compile_s']}s "
                          f"dominant={r['dominant']} "
                          f"compute={r['compute_s']:.4f}s "
                          f"memory={r['memory_s']:.4f}s "
                          f"collective={r['collective_s']:.4f}s "
                          f"roofline_frac={rec['roofline_fraction']:.3f}")
                elif rec["status"] == "skip":
                    print(f"[skip] {tag}: {rec['reason']}")
                else:
                    print(f"[FAIL] {tag}: {rec['error']}")
                if out_f:
                    out_f.write(json.dumps(rec) + "\n")
                    out_f.flush()
    if out_f:
        out_f.close()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
