"""Elastic training driver on the serverless runtime model.

Training is expressed as a recurring "query": each *stage* is K optimizer
steps under jit; stage results (checkpoints) are content-addressed objects
in the store; a restarted driver resumes from the last complete stage —
the same idempotent, storage-checkpointed execution model the SQL
coordinator uses for pipelines (DESIGN.md §4). Stage-level fault injection
exercises the recovery path.

CPU example (reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 60 --stage-steps 20 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (latest_step, load_checkpoint,
                                    save_checkpoint)
from repro.configs import get_config, get_reduced
from repro.models.model import init_params
from repro.models.steps import make_train_step
from repro.optim import AdamW, cosine_schedule
from repro.storage import ObjectStore


def synthetic_batch(cfg, step: int, batch: int, seq: int):
    """Deterministic per-step token stream (idempotent re-execution)."""
    rng = np.random.default_rng((1234, step))
    tokens = rng.integers(0, cfg.vocab, (batch, seq)).astype(np.int32)
    out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(
        np.roll(tokens, -1, axis=1))}
    if cfg.enc_dec:
        out["frames"] = jnp.asarray(rng.normal(
            0, 1, (batch, cfg.enc_frames, cfg.d_model)).astype(np.float32))
    return out


def run_training(*, arch: str, reduced: bool, steps: int,
                 stage_steps: int, batch: int, seq: int,
                 store: ObjectStore | None = None, run: str | None = None,
                 lr: float = 3e-3, fail_at_step: int | None = None,
                 verbose: bool = True):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    store = store or ObjectStore(tier="local")
    run = run or f"{arch}-demo"
    opt = AdamW(lr=cosine_schedule(lr, warmup=10, total=steps),
                weight_decay=0.01)
    step_fn = jax.jit(make_train_step(cfg, opt, compute_dtype=jnp.float32))

    start = latest_step(store, run)
    if start is not None:
        template = {"params": init_params(cfg, jax.random.PRNGKey(0)),
                    "opt": None}
        template["opt"] = opt.init(template["params"])
        state, start = load_checkpoint(store, run, template)
        params, opt_state = state["params"], state["opt"]
        if verbose:
            print(f"[train] resumed {run} from stage checkpoint "
                  f"step={start}")
    else:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        start = 0

    losses = []
    t0 = time.perf_counter()
    for step in range(start, steps):
        batch_data = synthetic_batch(cfg, step, batch, seq)
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        losses.append(float(metrics["loss"]))
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        if (step + 1) % stage_steps == 0 or step + 1 == steps:
            save_checkpoint(store, run, step + 1,
                            {"params": params, "opt": opt_state})
            if verbose:
                rate = (step + 1 - start) / (time.perf_counter() - t0)
                print(f"[train] stage complete @ step {step + 1} "
                      f"loss={losses[-1]:.4f} steps/s={rate:.2f}")
    return losses, params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--stage-steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    losses, _ = run_training(
        arch=args.arch, reduced=args.reduced, steps=args.steps,
        stage_steps=args.stage_steps, batch=args.batch, seq=args.seq,
        lr=args.lr)
    print(f"[train] done: loss {losses[0]:.4f} → {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
