"""Batched serving driver: prefill + KV-cache decode loop.

Serving is the paper's interactive-query story transplanted to LMs: a
stateless "coordinator" receives a batch of requests, runs prefill (the
scan-heavy stage), then streams decode steps (the small recurring
queries), with the cache as the intermediate result.

CPU example (reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --batch 4 --prompt-len 64 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.decode import prefill
from repro.models.model import init_params
from repro.models.steps import make_serve_step


def run_serving(*, arch: str, reduced: bool = True, batch: int = 4,
                prompt_len: int = 64, new_tokens: int = 32,
                verbose: bool = True):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32))
    frames = None
    if cfg.enc_dec:
        frames = jnp.asarray(rng.normal(
            0, 1, (batch, cfg.enc_frames, cfg.d_model)).astype(np.float32))

    t0 = time.perf_counter()
    logits, cache = prefill(cfg, params, prompts, frames=frames,
                            compute_dtype=jnp.float32,
                            max_len=prompt_len + new_tokens)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    serve_step = jax.jit(make_serve_step(cfg, compute_dtype=jnp.float32))
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.perf_counter()
    for _ in range(new_tokens - 1):
        tokens, _, cache = serve_step(params, cache, tokens)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.perf_counter() - t0
    out = jnp.stack(generated, axis=1)
    tps = batch * (new_tokens - 1) / max(t_decode, 1e-9)
    if verbose:
        print(f"[serve] arch={arch} batch={batch} prompt={prompt_len} "
              f"new={new_tokens}")
        print(f"[serve] prefill {t_prefill * 1e3:.1f} ms; decode "
              f"{t_decode * 1e3:.1f} ms ({tps:.1f} tok/s incl. compile)")
        print(f"[serve] sample continuation ids: "
              f"{np.asarray(out[0, :10]).tolist()}")
    return out, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tokens_per_s": tps}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()
    run_serving(arch=args.arch, reduced=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, new_tokens=args.new_tokens)


if __name__ == "__main__":
    main()
