"""Production mesh construction.

Target: TPU v5e pods — 256 chips per pod arranged (16, 16) as
(data, model); the multi-pod configuration adds a leading pure-DP "pod"
axis (2 pods = 512 chips). Defined as functions so importing this module
never touches jax device state (the dry-run pins the host device count
before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires host-device override)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants (roofline denominators).
TPU_V5E = {
    "peak_bf16_flops": 197e12,      # per chip
    "hbm_bandwidth": 819e9,         # bytes/s per chip
    "ici_link_bandwidth": 50e9,     # bytes/s per link
    "hbm_bytes": 16 * 2**30,
}
