"""Production mesh construction.

Target: TPU v5e pods — 256 chips per pod arranged (16, 16) as
(data, model); the multi-pod configuration adds a leading pure-DP "pod"
axis (2 pods = 512 chips). Defined as functions so importing this module
never touches jax device state (the dry-run pins the host device count
before first jax init).
"""

from __future__ import annotations

import jax


def auto_axis_kwargs(n_axes: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` where supported.

    ``jax.sharding.AxisType`` only exists on newer jax; older versions
    treat every axis as Auto already, so omitting the kwarg is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_kwargs(len(axes)))


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires host-device override)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **auto_axis_kwargs(2))


# TPU v5e hardware constants (roofline denominators).
TPU_V5E = {
    "peak_bf16_flops": 197e12,      # per chip
    "hbm_bandwidth": 819e9,         # bytes/s per chip
    "ici_link_bandwidth": 50e9,     # bytes/s per link
    "hbm_bytes": 16 * 2**30,
    "vmem_bytes": 16 * 2**20,       # per-core VMEM (Pallas tile budget)
}
