"""§Perf hillclimbing driver: run named optimization variants of the three
chosen (arch × shape) cells and append their roofline terms to
bench/hillclimb.jsonl (hypothesis → change → before → after log for
EXPERIMENTS.md §Perf).

Usage: PYTHONPATH=src python -m benchmarks.hillclimb --cell <name>
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

import jax.numpy as jnp

from repro.launch.dryrun import lower_cell
from repro.optim import AdamW

# variant name → (arch, shape, multi_pod, kwargs)
VARIANTS = {
    # -- granite-3-2b × train_4k (case study; iterations 0-4 were code
    #    changes logged in EXPERIMENTS.md; these are config-level) --
    "granite-base": ("granite-3-2b", "train_4k", False, {}),
    "granite-vocabpad": ("granite-3-2b", "train_4k", False,
                         {"extra_config": {"vocab_pad": 512}}),
    "granite-sp": ("granite-3-2b", "train_4k", False,
                   {"extra_config": {"vocab_pad": 512,
                                     "seq_parallel": True}}),
    # -- llama3-405b × train_4k (worst absolute cell) --
    "llama-base": ("llama3-405b", "train_4k", False, {}),
    "llama-sp": ("llama3-405b", "train_4k", False,
                 {"extra_config": {"seq_parallel": True}}),
    "llama-sp-accum4": ("llama3-405b", "train_4k", False,
                        {"extra_config": {"seq_parallel": True},
                         "accum_steps": 4}),
    "llama-sp-accum4-bf16adam": (
        "llama3-405b", "train_4k", False,
        {"extra_config": {"seq_parallel": True}, "accum_steps": 4,
         "optimizer": AdamW(state_dtype=jnp.bfloat16)}),
    "llama-multipod-full": (
        "llama3-405b", "train_4k", True,
        {"extra_config": {"seq_parallel": True}, "accum_steps": 4,
         "optimizer": AdamW(state_dtype=jnp.bfloat16)}),
    "llama-multipod-noaccum": (
        "llama3-405b", "train_4k", True,
        {"extra_config": {"seq_parallel": True},
         "optimizer": AdamW(state_dtype=jnp.bfloat16)}),
    "llama-multipod-accum2": (
        "llama3-405b", "train_4k", True,
        {"extra_config": {"seq_parallel": True}, "accum_steps": 2,
         "optimizer": AdamW(state_dtype=jnp.bfloat16)}),
    "llama-sp-bf16adam": (
        "llama3-405b", "train_4k", False,
        {"extra_config": {"seq_parallel": True},
         "optimizer": AdamW(state_dtype=jnp.bfloat16)}),
    # -- qwen3-moe × train_4k (most collective-bound / paper-representative:
    #    expert dispatch is the shuffle) --
    "moe-base": ("qwen3-moe-235b-a22b", "train_4k", False, {}),
    "moe-sp": ("qwen3-moe-235b-a22b", "train_4k", False,
               {"extra_config": {"seq_parallel": True}}),
    "moe-accum4": ("qwen3-moe-235b-a22b", "train_4k", False,
                   {"extra_config": {"seq_parallel": True},
                    "accum_steps": 4}),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=list(VARIANTS) + ["all"])
    ap.add_argument("--out", default="bench/hillclimb.jsonl")
    args = ap.parse_args()
    names = list(VARIANTS) if args.cell == "all" else [args.cell]
    with open(args.out, "a") as f:
        for name in names:
            arch, shape, multi, kw = VARIANTS[name]
            rec, _ = lower_cell(arch, shape, multi_pod=multi, **kw)
            rec["variant"] = name
            r = rec["roofline"]
            print(f"[{name}] compute={r['compute_s']:.3f}s "
                  f"memory={r['memory_s']:.3f}s "
                  f"collective={r['collective_s']:.3f}s "
                  f"dominant={r['dominant']} "
                  f"frac={rec['roofline_fraction']:.3f} "
                  f"fits={rec['fits_hbm']} "
                  f"resid={rec['hbm_residency_bytes'] / 2**30:.1f}GiB")
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
