"""Benchmark driver: one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Simulated infrastructure
quantities are labeled in the derived column; wall-clock numbers are real
measurements on this host.

  Table 2  → startup        (cold/warm starts, √W two-level dispatch)
  Table 3  → storage        (tier latency/cost models)
  Fig 5/6  → tpch           (Q1/Q6/Q12/Q3/Q14 latency + cost)
  Fig 7    → elasticity     (Q1+Q6 across scale factors)
  §3.3     → stragglers     (re-triggering on/off)
  §3.4     → cache          (recurring-query cost)
  sessions → concurrency    (multi-query shared-quota scheduling)
  dispatch → fusion         (fused Pallas path vs generic jnp, parity-checked)
  barriers → adaptive       (barrier re-optimization vs static plan,
                             parity- and worker-count-checked)
  exchange → shuffle        (wide-fanout shuffle strategies: direct vs
                             combining vs multilevel, parity- and
                             request-count-checked)
  tenants  → service        (query service tier: fair-share slot split,
                             SLO deadline misses, DAG shared-subplan
                             dedup — all asserted)
  barriers → pipelined      (barrier vs barrier-free schedule on a
                             skewed-producer join: row parity,
                             wall-clock reduction, and straggler-free
                             first byte — all asserted)
  faults   → chaos          (chaos engine off-path overhead, one-shot
                             kill-point recovery, probabilistic fault
                             storm — parity asserted throughout)
  filters  → semijoin       (build-side Bloom filter on the probe
                             exchange: row parity, probe shuffle-byte
                             reduction, and request reduction — all
                             asserted)
  kernels  → Pallas kernels (interpret mode on CPU)

``--json PATH`` additionally writes the rows as a JSON snapshot (the
BENCH_*.json files checked in per PR).
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback

from benchmarks import suites

SUITES = {
    "startup": suites.bench_startup,
    "storage": suites.bench_storage,
    "tpch": suites.bench_tpch,
    "elasticity": suites.bench_elasticity,
    "stragglers": suites.bench_stragglers,
    "cache": suites.bench_result_cache,
    "concurrency": suites.bench_concurrency,
    "fusion": suites.bench_fusion,
    "adaptive": suites.bench_adaptive,
    "shuffle": suites.bench_shuffle,
    "service": suites.bench_service,
    "pipelined": suites.bench_pipelined,
    "semijoin": suites.bench_semijoin,
    "chaos": suites.bench_chaos,
    "kernels": suites.bench_kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all"] + list(SUITES))
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken configs (CI deadlock/regression "
                         "guard); suites without a smoke mode run "
                         "unchanged")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as a JSON snapshot")
    args = ap.parse_args()
    names = list(SUITES) if args.suite == "all" else [args.suite]
    print("name,us_per_call,derived")
    failed = 0
    snapshot = []
    for name in names:
        fn = SUITES[name]
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        try:
            for row, us, derived in fn(**kwargs):
                print(f"{row},{us:.1f},{derived}")
                snapshot.append({"suite": name, "name": row,
                                 "us_per_call": round(us, 1),
                                 "derived": derived})
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "rows": snapshot}, f, indent=1)
            f.write("\n")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
