"""Benchmark suites — one per paper table/figure.

Each function returns a list of (name, us_per_call, derived) rows for the
CSV contract of ``benchmarks.run``. Simulated quantities (infrastructure
latencies, dollars) come from the calibrated models of paper Tables 1–3;
wall-clock rows are real CPU measurements of this host.
"""

from __future__ import annotations

import contextlib
import time

import numpy as np

import repro.exec  # noqa: F401 (x64)
from repro.api import CoordinatorConfig, FaasPlatform, FaultPlan, connect
from repro.core.cost import LAMBDA_COLD_START, LAMBDA_WARM_START
from repro.data import generate_tpch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import ObjectStore, TIERS

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=500_000, broadcast_threshold_bytes=250_000,
    exchange_partitions=4))


def _db(sf, seed=0, tier="s3-standard", n_parts=None):
    store = ObjectStore(tier=tier, seed=seed)
    catalog = generate_tpch(store, sf=sf, seed=0, n_parts=n_parts)
    return store, catalog


def _session(sf, *, cfg=CFG, seed=0, tier="s3-standard", n_parts=None,
             platform_seed=0, faults=None, quota=1000, **kw):
    store, catalog = _db(sf, seed=seed, tier=tier, n_parts=n_parts)
    # session-built platform → close() also shuts down its thread pool
    return connect(store, catalog, quota=quota, faults=faults,
                   seed=platform_seed, config=cfg, **kw)


# -- Table 2: startup latencies -----------------------------------------------------

def bench_startup():
    plat = FaasPlatform(seed=0)
    colds = [plat._start_latency(True) for _ in range(2000)]
    plat2 = FaasPlatform(seed=1)
    plat2._warm_sandboxes = 1
    warms = [plat2._start_latency(False) for _ in range(2000)]
    rows = [
        ("startup/lambda_cold_avg", np.mean(colds) * 1e6,
         f"paper_avg={LAMBDA_COLD_START['avg'] * 1e6:.0f}us"),
        ("startup/lambda_warm_avg", np.mean(warms) * 1e6,
         f"paper_avg={LAMBDA_WARM_START['avg'] * 1e6:.0f}us"),
    ]
    for w in (64, 1024, 2500):
        flat = plat.dispatch_time_s(w, two_level=False)
        tree = plat.dispatch_time_s(w, two_level=True)
        rows.append((f"startup/dispatch_flat_w{w}", flat * 1e6,
                     f"two_level={tree * 1e6:.0f}us "
                     f"speedup={flat / tree:.1f}x"))
    return rows


# -- Table 3: storage tiers ----------------------------------------------------------

def bench_storage():
    rows = []
    rng = np.random.default_rng(0)
    for name in ("s3-standard", "s3-express", "dynamodb", "efs"):
        t = TIERS[name]
        reads = [t.draw_latency_s(rng, write=False) for _ in range(3000)]
        writes = [t.draw_latency_s(rng, write=True) for _ in range(3000)]
        cost_1m_rw = (t.read_request_cents_per_1m
                      + t.write_request_cents_per_1m)
        rows.append((
            f"storage/{name}_read_median",
            float(np.median(reads)) * 1e6,
            f"write_median_us={np.median(writes) * 1e6:.0f};"
            f"req_cents_per_1M_rw={cost_1m_rw:.0f};"
            f"p99_read_us={np.quantile(reads, 0.99) * 1e6:.0f}"))
    return rows


# -- Fig 5 + Fig 6: TPC-H latency and cost -------------------------------------------

def bench_tpch(sf: float = 0.05, *, smoke: bool = False):
    if smoke:
        sf = 0.02
    cfg = CoordinatorConfig(planner=CFG.planner, use_result_cache=False)
    rows = []
    n_parts = 6 if smoke else 8
    with _session(sf, cfg=cfg, n_parts=n_parts, platform_seed=4) as session:
        for qname in ("q1", "q6", "q12", "q3", "q14"):
            t0 = time.perf_counter()
            res = session.sql(QUERIES[qname])
            wall = time.perf_counter() - t0
            s = res.stats
            rows.append((
                f"tpch/sf{sf:g}_{qname}", wall * 1e6,
                f"sim_latency_s={s.sim_latency_s:.2f};"
                f"cost_cents={s.cost.total_cents:.4f};"
                f"workers={sum(p.n_fragments for p in s.pipelines)};"
                f"bytes_read={sum(p.bytes_read for p in s.pipelines)};"
                f"requests={sum(p.requests for p in s.pipelines)};"
                f"footer_cache_hits="
                f"{sum(p.footer_cache_hits for p in s.pipelines)};"
                f"kernel_fragments="
                f"{sum(p.kernel_fragments for p in s.pipelines)}"))
    return rows


# -- Fig 7: elasticity ----------------------------------------------------------------

def bench_elasticity(scale_factors=(0.01, 0.04, 0.16), *,
                     smoke: bool = False):
    if smoke:
        scale_factors = (0.01, 0.04)
    rows = []
    for sf in scale_factors:
        with _session(
                sf, n_parts=max(2, int(sf * 200)), platform_seed=5,
                cfg=CoordinatorConfig(
                    planner=PlannerConfig(bytes_per_worker=400_000),
                    use_result_cache=False)) as session:
            sim_total = 0.0
            workers = 0
            for qname in ("q1", "q6"):
                res = session.sql(QUERIES[qname])
                sim_total += res.stats.sim_latency_s
                workers += sum(p.n_fragments
                               for p in res.stats.pipelines)
        rows.append((f"elasticity/sf{sf:g}_q1q6", sim_total * 1e6,
                     f"sim_latency_s={sim_total:.2f};workers={workers}"))
    return rows


# -- Section 3.3: straggler mitigation --------------------------------------------------

def bench_stragglers():
    rows = []
    for label, detect in (("on", 3.0), ("off", 1e9)):
        with _session(
                0.02, n_parts=6, platform_seed=6,
                faults=FaultPlan(
                    straggle_fragments=((0, 1, 0), (0, 3, 0)),
                    straggler_factor=25.0, seed=8),
                cfg=CoordinatorConfig(
                    planner=CFG.planner,
                    straggler_detect_factor=detect,
                    use_result_cache=False)) as session:
            res = session.sql(QUERIES["q6"])
        s = res.stats
        rows.append((
            f"stragglers/retrigger_{label}", s.sim_latency_s * 1e6,
            f"sim_latency_s={s.sim_latency_s:.2f};"
            f"retriggered={sum(p.stragglers_retriggered for p in s.pipelines)};"
            f"cost_cents={s.cost.total_cents:.4f}"))
    return rows


# -- Section 3.4: result cache -----------------------------------------------------------

def bench_result_cache():
    rows = []
    with _session(0.02, n_parts=6, platform_seed=7) as session:
        for i, label in ((0, "cold"), (1, "warm")):
            t0 = time.perf_counter()
            res = session.sql(QUERIES["q12"])
            wall = time.perf_counter() - t0
            s = res.stats
            rows.append((
                f"cache/q12_{label}", wall * 1e6,
                f"sim_latency_s={s.sim_latency_s:.3f};"
                f"cost_cents={s.cost.total_cents:.5f};"
                f"cache_hits={s.cache_hits}"))
    return rows


# -- SkyriseSession: cross-query admission over one shared quota --------------------------

def bench_concurrency(n_queries: int = 4, quota: int = 8, *,
                      smoke: bool = False):
    """Multi-query sessions: N queries through one shared platform.

    Sequential = one query at a time (the old one-coordinator-per-query
    pattern); concurrent = all submitted up front, their fragments
    running wall-clock-parallel on the threaded backend under the shared
    admission quota (per-fragment slot release). The dedup row submits
    one query N× concurrently: in-flight claim/publish sharing keeps the
    invocation count at one solo execution.

    ``smoke`` shrinks the config for CI deadlock detection.
    """
    sf, n_parts = (0.01, 4) if smoke else (0.02, 6)
    if smoke:
        n_queries, quota = min(n_queries, 2), min(quota, 4)
    qnames = ("q1", "q6", "q12", "q14")[:n_queries]
    rows = []
    cfg = CoordinatorConfig(planner=CFG.planner, use_result_cache=False)

    # warmup: pay in-process JIT compilation once so neither timed run
    # benefits from the other's compile cache
    with _session(sf, cfg=cfg, n_parts=n_parts, quota=quota) as warm:
        for q in qnames:
            warm.sql(QUERIES[q])

    with _session(sf, cfg=cfg, n_parts=n_parts, quota=quota,
                  max_concurrent_queries=1) as session:
        t0 = time.perf_counter()
        for q in qnames:
            session.sql(QUERIES[q])
        seq_wall = time.perf_counter() - t0
        rows.append((f"concurrency/{n_queries}q_sequential",
                     seq_wall * 1e6,
                     f"invocations={session.platform.invocations};"
                     f"peak_in_flight="
                     f"{session.platform.admission.max_in_flight}"))

    with _session(sf, cfg=cfg, n_parts=n_parts, quota=quota,
                  max_concurrent_queries=n_queries) as session:
        t0 = time.perf_counter()
        handles = [session.submit(QUERIES[q]) for q in qnames]
        for h in handles:
            h.result()
        conc_wall = time.perf_counter() - t0
        st = session.stats()
    rows.append((f"concurrency/{n_queries}q_concurrent", conc_wall * 1e6,
                 f"speedup={seq_wall / conc_wall:.2f}x;"
                 f"peak_in_flight={st['max_workers_in_flight']};"
                 f"quota={quota}"))

    # in-flight dedup: N concurrent submissions of one query share a
    # single execution (cache enabled; claims span the whole session)
    with _session(sf, cfg=CoordinatorConfig(planner=CFG.planner),
                  n_parts=n_parts, quota=quota,
                  max_concurrent_queries=n_queries) as session:
        t0 = time.perf_counter()
        handles = [session.submit(QUERIES[qnames[0]])
                   for _ in range(n_queries)]
        for h in handles:
            h.result()
        dedup_wall = time.perf_counter() - t0
        st = session.stats()
    rows.append((
        f"concurrency/{n_queries}x_same_query_dedup", dedup_wall * 1e6,
        f"invocations={st['platform_invocations']};"
        f"claims={st['registry_claims']};"
        f"inflight_dedup_hits={st['inflight_dedup_hits']}"))
    return rows


# -- adaptive re-optimization: barrier re-planning vs the static plan -----------------------

ADAPTIVE_SKEWED_SQL = """
select o_orderpriority, count(*) as n, sum(l_extendedprice) as rev
from lineitem, orders
where l_orderkey = o_orderkey
    and l_extendedprice * l_discount > 9000
group by o_orderpriority
order by o_orderpriority
"""


def bench_adaptive(smoke: bool = False):
    """Adaptive vs static execution on a skewed-selectivity join.

    The probe-side predicate (``l_extendedprice * l_discount > 9000``,
    ~0.1% selective) is an expression no zone map can estimate, so the
    planner falls back to its constant selectivity guess and sizes the
    repartition-join fleet for ~300× more data than arrives. At the
    stage barrier the adaptive path re-sizes that fleet cost-optimally
    from the observed exchange manifests, prunes empty partitions, and
    downgrades the join to broadcast when the observed build side fits
    the memory budget.

    Asserts — failing the CI bench-smoke job on regression — that the
    adaptive path (a) never invokes more workers than the static plan
    and (b) returns identical rows.
    """
    sf, n_parts = (0.01, 4) if smoke else (0.02, 6)
    store, catalog = _db(sf, n_parts=n_parts)
    # thresholds sized so the plan repartitions the join even at smoke
    # scale (the adaptation under test needs an exchange to re-plan)
    planner = PlannerConfig(bytes_per_worker=40_000,
                            broadcast_threshold_bytes=50_000)
    runs = {}
    for mode, adaptive in (("static", False), ("adaptive", True)):
        cfg = CoordinatorConfig(
            planner=planner, use_result_cache=False, adaptive=adaptive,
            # deterministic invocation counts: no wall-clock-noise
            # straggler re-triggers in CI
            straggler_min_timeout_s=100.0)
        with connect(store, catalog, quota=1000, config=cfg,
                     seed=9) as session:
            t0 = time.perf_counter()
            res = session.sql(ADAPTIVE_SKEWED_SQL)
            wall = time.perf_counter() - t0
            runs[mode] = (wall, res, res.fetch(store),
                          session.platform.invocations)
    s_wall, s_res, s_cols, s_inv = runs["static"]
    a_wall, a_res, a_cols, a_inv = runs["adaptive"]
    for k in s_cols:
        np.testing.assert_allclose(
            np.asarray(a_cols[k], np.float64),
            np.asarray(s_cols[k], np.float64), rtol=1e-9, atol=1e-9,
            err_msg=f"adaptive-vs-static parity regression: {k}")
    assert a_inv <= s_inv, \
        f"adaptive invoked more workers than static: {a_inv} > {s_inv}"
    a_stats, s_stats = a_res.stats, s_res.stats
    adaptations = [x for p in a_stats.pipelines for x in p.adaptations]
    resized = [x for x in adaptations if x["kind"] == "fleet_resize"]
    return [(
        "adaptive/skewed_join_static_vs_adaptive", a_wall * 1e6,
        f"static_us={s_wall * 1e6:.1f};"
        f"invocations_static={s_inv};invocations_adaptive={a_inv};"
        f"workers_static={sum(p.n_fragments for p in s_stats.pipelines)};"
        f"workers_adaptive={sum(p.n_fragments for p in a_stats.pipelines)};"
        f"adaptations={len(adaptations)};"
        f"fleet_resizes={[(x['from'], x['to']) for x in resized]};"
        f"cents_static={s_stats.cost.total_cents:.4f};"
        f"cents_adaptive={a_stats.cost.total_cents:.4f};"
        f"requests_static={sum(p.requests for p in s_stats.pipelines)};"
        f"requests_adaptive={sum(p.requests for p in a_stats.pipelines)};"
        f"parity=ok")]


# -- exchange subsystem: shuffle strategies at wide fan-out ---------------------------------

SHUFFLE_SQL = """
select o_orderpriority, count(*) as n, sum(l_extendedprice) as rev
from lineitem, orders
where l_orderkey = o_orderkey
group by o_orderpriority
order by o_orderpriority
"""


def bench_shuffle(smoke: bool = False):
    """Wide-fanout repartition join under each shuffle strategy.

    16 producers × 16 hash partitions per exchange side — the regime
    where the direct producer×partition grid issues O(n·m) storage
    requests. Reports storage requests, exchange objects, cents, and
    wall per strategy, asserting — failing the CI bench-smoke job on
    regression — that (a) all three strategies return identical rows
    and (b) the multi-level exchange issues strictly fewer storage
    requests and lower cost than the direct grid.
    """
    import dataclasses as _dc

    sf = 0.02 if smoke else 0.05
    base = PlannerConfig(bytes_per_worker=1, broadcast_threshold_bytes=1,
                         exchange_partitions=16, max_workers=16)
    rows, runs = [], {}
    for strategy in ("direct", "combining", "multilevel"):
        store, catalog = _db(sf, n_parts=16)
        cfg = CoordinatorConfig(
            planner=_dc.replace(base, exchange_strategy=strategy),
            use_result_cache=False, adaptive=False,
            # deterministic request counts: no wall-clock-noise
            # straggler re-triggers in CI
            straggler_min_timeout_s=100.0)
        with connect(store, catalog, quota=1000, config=cfg,
                     seed=11) as session:
            t0 = time.perf_counter()
            res = session.sql(SHUFFLE_SQL)
            wall = time.perf_counter() - t0
        s = res.stats
        reqs = store.stats.get_requests + store.stats.put_requests
        runs[strategy] = (res.fetch(store), s, reqs)
        x_reqs = sum(p.exchange_requests for p in s.pipelines)
        # exchange objects only (grid/combined/l0) — result objects
        # (f*/out.spax) are not part of any exchange and excluded so the
        # count is comparable to the strategies' written_objects() math
        x_objects = len([k for k in store.list("results/")
                         if k.endswith(".spax")
                         and not k.endswith("/out.spax")])
        merge = sum(p.merge_fragments for p in s.pipelines)
        rows.append((
            f"shuffle/16x16_{strategy}", wall * 1e6,
            f"requests={reqs};exchange_requests={x_reqs};"
            f"exchange_objects={x_objects};merge_workers={merge};"
            f"cost_cents={s.cost.total_cents:.4f};"
            f"sim_latency_s={s.sim_latency_s:.2f}"))
    d_cols, d_stats, d_reqs = runs["direct"]
    for strategy in ("combining", "multilevel"):
        cols, stats_, reqs_ = runs[strategy]
        for k in d_cols:
            np.testing.assert_allclose(
                np.asarray(cols[k], np.float64),
                np.asarray(d_cols[k], np.float64), rtol=1e-9, atol=1e-9,
                err_msg=f"shuffle parity regression: {strategy}.{k}")
        assert reqs_ < d_reqs, \
            f"{strategy} issued {reqs_} requests ≥ direct's {d_reqs}"
    assert runs["multilevel"][1].cost.total_cents \
        < d_stats.cost.total_cents, "multilevel cents regression"
    return rows


# -- query service tier: fair share, SLO deadlines, multi-query DAGs ------------------------

def bench_service(smoke: bool = False):
    """Two tenants × mixed-priority TPC-H through one ``QueryService``.

    gold (weight 3, SLO deadline) and bronze (weight 1) flood one 8-slot
    quota with a TPC-H mix; asserted invariants: the fair-share admitted
    *slot* split lands within tolerance of the 3:1 weights, the high-SLO
    tenant misses no deadline, and a DAG whose two nodes share a subplan
    materializes it exactly once (registry hit on the dependent node).
    """
    from repro.service import QueryService, TenantConfig

    sf, n_parts, rounds = (0.01, 4, 1) if smoke else (0.02, 6, 2)
    quota = 8
    qnames = ("q1", "q6", "q12", "q14") * rounds
    rows = []

    # fair share needs sustained slot contention: result cache off so
    # every query runs a real fleet, narrow bytes_per_worker so fleets
    # dwarf the quota, session scheduler wide open so the platform's
    # admission ledger is the only bottleneck
    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=50_000,
                              broadcast_threshold_bytes=250_000,
                              exchange_partitions=4),
        use_result_cache=False)
    store, catalog = _db(sf, n_parts=n_parts)
    platform = FaasPlatform(quota=quota, seed=0)
    session = connect(store, catalog, platform=platform, config=cfg,
                      max_concurrent_queries=2 * len(qnames))
    svc = QueryService(session, tenants=(
        TenantConfig("gold", weight=3.0, priority=1),
        TenantConfig("bronze", weight=1.0)))
    t0 = time.perf_counter()
    handles = [svc.submit(QUERIES[q], tenant=t)
               for q in qnames for t in ("gold", "bronze")]
    # identical finite workloads equalize the *totals* once the lighter
    # tenant drains its backlog, so the split is sampled mid-flight: at
    # the first instant both tenants hold grants and two quotas' worth
    # of slots have been handed out, the deficit scheduler is pacing
    # admissions at the weight ratio
    snap = {}
    while True:
        snap = dict(platform.admission.admitted_by_group)
        if snap.get("bronze", 0) >= 2 \
                and sum(snap.values()) >= 2 * quota:
            break
        if time.perf_counter() - t0 > 300:
            break
        time.sleep(0.005)
    for h in handles:
        h.wait(timeout=600)
    wall = time.perf_counter() - t0
    st = svc.stats()
    svc.close()
    session.close()

    gold_slots = snap.get("gold", 0)
    bronze_slots = snap.get("bronze", 0)
    ratio = gold_slots / max(bronze_slots, 1)
    rows.append((f"service/fair_share_{2 * len(qnames)}q_quota{quota}",
                 wall * 1e6,
                 f"gold_slots_mid={gold_slots};"
                 f"bronze_slots_mid={bronze_slots};"
                 f"ratio={ratio:.2f};weights=3:1;"
                 f"final_gold={st['tenants']['gold']['admitted_slots']};"
                 f"final_bronze="
                 f"{st['tenants']['bronze']['admitted_slots']}"))
    # weights 3:1 — grants are batched, so the sampled ratio wobbles
    # around 3; the synthetic ±20% convergence proof lives in
    # tests/test_service.py::test_fair_share_converges_to_weight_ratio
    assert 1.5 <= ratio <= 6.0, \
        f"fair-share split off 3:1: {ratio:.2f} ({snap})"

    # SLO run: the gold mix under a per-request deadline — stage
    # budgets size every fleet so no request misses
    store, catalog = _db(sf, n_parts=n_parts)
    session = connect(store, catalog, quota=quota, config=cfg,
                      max_concurrent_queries=len(qnames))
    svc = QueryService(session, tenants=(
        TenantConfig("gold", weight=3.0, deadline_s=10.0),))
    t0 = time.perf_counter()
    handles = [svc.submit(QUERIES[q], tenant="gold") for q in qnames]
    results = [h.result(timeout=600) for h in handles]
    slo_wall = time.perf_counter() - t0
    misses = svc.stats()["deadline_misses"]
    worst = max(r.sim_latency_s for r in results)
    svc.close()
    session.close()
    assert misses == 0, f"high-SLO tenant missed {misses} deadlines"
    assert all(not r.deadline_missed for r in results)
    rows.append(("service/gold_slo_deadline", slo_wall * 1e6,
                 f"misses={misses};worst_sim_latency_s={worst:.2f};"
                 f"deadline_s=10"))

    # DAG: node1 depends on node0 and shares its whole plan — the
    # subplan materializes once, the dependent reads published results
    store, catalog = _db(sf, n_parts=n_parts)
    session = connect(store, catalog, quota=quota,
                      config=CoordinatorConfig(planner=CFG.planner),
                      max_concurrent_queries=4)
    svc = QueryService(session)
    t0 = time.perf_counter()
    h0, h1 = svc.submit_dag([QUERIES["q6"], QUERIES["q6"]], {1: [0]})
    e1 = h1.wait(timeout=600)
    dag_wall = time.perf_counter() - t0
    e0 = h0.entry()
    svc.close()
    session.close()
    shared_hits = e1.result["cache_hits"] + e1.result["deduped"]
    assert shared_hits >= 1, "DAG shared subplan re-executed"
    assert e1.started_at >= e0.finished_at, "DAG dependency order broken"
    rows.append(("service/dag_shared_subplan", dag_wall * 1e6,
                 f"node1_hits={shared_hits};"
                 f"ordered={e1.started_at >= e0.finished_at}"))
    return rows


# -- barrier-free pipelined execution vs stage barriers -------------------------------------

def bench_pipelined(smoke: bool = False):
    """Skewed-producer join: stage barriers vs pipelined admission.

    One fragment of *each* join side's scan fleet straggles with a real
    wall-clock sleep. The barrier schedule pays both sleeps serially
    (same-stage pipelines run back to back, and every downstream stage
    waits for the slowest producer); the pipelined schedule runs the
    sibling scans concurrently and admits the join consumers on the
    configured partition fraction, topping up the straggler tails from
    the incremental manifests. Asserted — failing the CI bench-smoke
    job on regression: (a) identical rows, (b) pipelined wall-clock
    strictly below barrier wall-clock, and (c) the consumer's sim
    window opens before the slowest producer's finish (first byte is
    not gated on the straggler).
    """
    import dataclasses as _dc

    sf, n_parts, sleep_s = (0.01, 8, 0.25) if smoke \
        else (0.02, 8, 0.4)
    planner = PlannerConfig(bytes_per_worker=1,
                            broadcast_threshold_bytes=1,
                            exchange_partitions=8, max_workers=8)
    # fragment 0 of each scan fleet straggles on every attempt (the
    # range covers retries and would-be duplicates); re-triggering is
    # disabled so both modes pay exactly one sleep per straggler
    faults = FaultPlan(
        straggle_fragments=tuple((p, 0, a) for p in (0, 1)
                                 for a in range(300)),
        straggler_factor=5.0, straggle_wall_s=sleep_s)
    runs = {}
    for mode in ("barrier", "pipelined"):
        store, catalog = _db(sf, n_parts=n_parts)
        cfg = CoordinatorConfig(
            planner=planner, use_result_cache=False,
            pipelined=(mode == "pipelined"),
            straggler_min_timeout_s=100.0)
        with connect(store, catalog,
                     platform=FaasPlatform(seed=7, faults=faults),
                     config=cfg) as session:
            t0 = time.perf_counter()
            res = session.sql(SHUFFLE_SQL)
            wall = time.perf_counter() - t0
        runs[mode] = (res.fetch(store), res.stats, wall)

    b_cols, b_stats, b_wall = runs["barrier"]
    p_cols, p_stats, p_wall = runs["pipelined"]
    for k in b_cols:
        np.testing.assert_allclose(
            np.asarray(p_cols[k], np.float64),
            np.asarray(b_cols[k], np.float64), rtol=1e-9, atol=1e-9,
            err_msg=f"pipelined parity regression: {k}")
    assert p_wall < b_wall, \
        f"pipelined wall {p_wall:.3f}s ≥ barrier wall {b_wall:.3f}s"

    producers = {r.pid: r for r in p_stats.pipelines}
    consumers = [r for r in p_stats.pipelines if r.pipelined]
    assert consumers, "no pipeline consumed partial input"
    slowest = max(producers[p].sim_end_s for p in (0, 1))
    for c in consumers:
        assert c.sim_start_s < slowest, \
            f"consumer p{c.pid} first byte gated on the straggler"

    first_input = min((c.first_input_s for c in consumers
                       if c.first_input_s > 0), default=0.0)
    return [(
        f"pipelined/skewed_join_sleep{int(sleep_s * 1000)}ms",
        p_wall * 1e6,
        f"barrier_us={b_wall * 1e6:.1f};"
        f"speedup={b_wall / p_wall:.2f}x;"
        f"consumers={len(consumers)};"
        f"first_input_s={first_input:.3f};"
        f"topups={sum(c.topups for c in consumers)};"
        f"overlap_saved_s="
        f"{sum(c.overlap_saved_s for c in consumers):.3f};"
        f"sim_latency_s={p_stats.sim_latency_s:.2f};"
        f"barrier_sim_latency_s={b_stats.sim_latency_s:.2f};"
        f"parity=ok")]


# -- kernel dispatch: fused Pallas path vs generic jnp path ---------------------------------

def bench_fusion(smoke: bool = False):
    """Fused kernel dispatch vs the generic jnp operator chain, same data.

    One row per fused kernel — Q6 (→ ``filter_agg``), Q1
    (→ ``groupby_onehot``), Q12 (→ ``join_probe_agg``), a grouped
    min/max (→ ``segmented_minmax``), a non-dict group-by
    (→ ``sort_agg``), and Q3 (→ ``topk`` on the final stage) — with the
    dispatch layer on and off, *asserting numeric parity and kernel
    coverage* — a regression raises and fails the CI bench-smoke job.
    On CPU the kernels execute in Pallas interpret mode, so wall clock
    there measures dispatch overhead rather than TPU speedup; the
    storage request reductions (footer cache + range coalescing) and
    the kernel-path coverage counts are backend-independent.
    """
    from repro.exec import lower

    sf, n_parts = (0.01, 4) if smoke else (0.02, 6)
    cfg = CoordinatorConfig(planner=CFG.planner, use_result_cache=False)
    store, catalog = _db(sf, n_parts=n_parts)
    items = [
        ("q6", QUERIES["q6"], "filter_agg"),
        ("q1", QUERIES["q1"], "groupby_onehot"),
        ("q12", QUERIES["q12"], "join_probe_agg"),
        ("minmax", "select l_returnflag, min(l_quantity) as mq, "
                   "max(l_tax) as mt from lineitem "
                   "group by l_returnflag order by l_returnflag",
         "segmented_minmax"),
        ("sortagg", "select l_orderkey, sum(l_quantity) as s, "
                    "count(*) as c from lineitem "
                    "group by l_orderkey order by l_orderkey",
         "sort_agg"),
        ("q3", QUERIES["q3"], "topk"),
    ]
    rows = []
    for qname, sql, kernel in items:
        runs = {}
        for mode in ("fused", "jnp"):
            ctx = contextlib.nullcontext() if mode == "fused" \
                else lower.disabled()
            with ctx, connect(store, catalog, quota=1000, config=cfg,
                              seed=3) as session:
                session.sql(sql)                    # pay JIT tracing once
                t0 = time.perf_counter()
                res = session.sql(sql)
                wall = time.perf_counter() - t0
                runs[mode] = (wall, res, res.fetch(store))
        fused_wall, fused, fdata = runs["fused"]
        jnp_wall, generic, jdata = runs["jnp"]
        for k in jdata:
            np.testing.assert_allclose(
                np.asarray(fdata[k], np.float64),
                np.asarray(jdata[k], np.float64), rtol=1e-9, atol=1e-9,
                err_msg=f"fused-vs-jnp parity regression: {qname}.{k}")
        fs, js = fused.stats, generic.stats
        assert any(p.kernel == kernel and p.kernel_fragments
                   for p in fs.pipelines), \
            f"kernel coverage regression: {qname} no longer runs {kernel}"
        rows.append((
            f"fusion/{qname}_fused_vs_jnp", fused_wall * 1e6,
            f"jnp_us={jnp_wall * 1e6:.1f};"
            f"kernel={kernel};"
            f"kernel_fragments="
            f"{sum(p.kernel_fragments for p in fs.pipelines)};"
            f"requests_fused={sum(p.requests for p in fs.pipelines)};"
            f"requests_jnp={sum(p.requests for p in js.pipelines)};"
            f"footer_cache_hits="
            f"{sum(p.footer_cache_hits for p in fs.pipelines)};"
            f"parity=ok"))
    return rows


# -- robustness: chaos engine --------------------------------------------------------------

def bench_chaos(smoke: bool = False):
    """Chaos engine: off-path overhead, kill-point recovery, fault storm.

    Three asserted rows (failing the CI bench-smoke job on regression):

      * ``off_overhead`` — q6 with a zero-probability ``ChaosEngine``
        attached vs no engine at all: parity asserted, zero injections
        asserted; the derived column reports the wall-clock cost of the
        hooks themselves.
      * ``kill_recovery`` — q3 with a one-shot kill at every registry
        protocol step (claim / begin_partial / publish_partial /
        finish_partial): every kill must actually fire, and the
        TTL-steal + partial-stream recovery must converge to identical
        rows.
      * ``storm`` — a probabilistic schedule (transient GET/PUT errors,
        503 throttles, latency spikes, torn PUTs, cold-start storms,
        worker kills) swept over several seeds, parity asserted per
        seed.
    """
    from repro.api import ChaosConfig, ChaosEngine
    from repro.core.registry import ResultRegistry

    sf, n_parts = 0.01, 4
    # fresh store per run, so the (registry-backed) result cache never
    # crosses runs; it must stay ON — the claim protocol under kill is
    # half of what this suite exercises
    cfg = CoordinatorConfig(planner=CFG.planner,
                            calibrate_selectivity=False, max_attempts=6)

    def run(qname, chaos, seed=0):
        store, catalog = _db(sf, n_parts=n_parts)
        registry = ResultRegistry(store, claim_ttl_s=0.25)
        with connect(store, catalog, quota=64, seed=seed, config=cfg,
                     registry=registry, chaos=chaos) as session:
            t0 = time.perf_counter()
            res = session.sql(QUERIES[qname])
            wall = time.perf_counter() - t0
            ctx = chaos.pause() if chaos is not None \
                else contextlib.nullcontext()
            with ctx:
                cols = res.fetch(store)
        return cols, wall

    def sorted_rows(cols):
        keys = sorted(cols)
        arrs = [np.asarray(cols[k], np.float64) for k in keys]
        order = np.lexsort(arrs[::-1])
        return keys, [a[order] for a in arrs]

    def assert_parity(ref, got, label):
        rkeys, rarrs = sorted_rows(ref)
        gkeys, garrs = sorted_rows(got)
        assert rkeys == gkeys, f"{label}: column mismatch"
        for k, ra, ga in zip(rkeys, rarrs, garrs):
            np.testing.assert_allclose(
                ga, ra, rtol=1e-9, atol=1e-9,
                err_msg=f"chaos parity regression: {label}.{k}")

    rows = []
    run("q6", None)                     # pay JIT tracing once
    run("q3", None)

    # -- off-path overhead: hooks attached but every probability zero
    ref6, base_wall = run("q6", None)
    idle = ChaosEngine(ChaosConfig(seed=0))
    cols, idle_wall = run("q6", idle)
    assert_parity(ref6, cols, "off_overhead")
    assert not idle.injected, f"zero-prob engine injected: {idle.injected}"
    rows.append(("chaos/off_overhead", idle_wall * 1e6,
                 f"baseline_us={base_wall * 1e6:.1f};"
                 f"overhead={idle_wall / base_wall:.2f}x;"
                 f"injected=0;parity=ok"))

    # -- one-shot kills at every registry protocol step
    ref3, clean_wall = run("q3", None)
    sites = ("registry.claim", "registry.begin_partial",
             "registry.publish_partial", "registry.finish_partial")
    chaos = ChaosEngine(ChaosConfig(seed=1, kill_points=sites))
    cols, kill_wall = run("q3", chaos)
    for site in sites:
        assert chaos.injected.get(f"kill:{site}") == 1, \
            f"kill point never fired: {site}"
    assert_parity(ref3, cols, "kill_recovery")
    rows.append(("chaos/kill_recovery_4sites", kill_wall * 1e6,
                 f"clean_us={clean_wall * 1e6:.1f};"
                 f"recovery_cost={kill_wall / clean_wall:.2f}x;"
                 f"kills={len(sites)};parity=ok"))

    # -- probabilistic storm across seeds
    seeds = range(2) if smoke else range(5)
    walls, injected = [], 0
    for seed in seeds:
        storm = ChaosEngine(ChaosConfig(
            seed=seed, get_error_prob=0.01, put_error_prob=0.01,
            throttle_prob=0.005, latency_spike_prob=0.08,
            torn_put_prob=0.01, cold_storm_prob=0.15,
            worker_kill_prob=0.03))
        cols, wall = run("q6", storm, seed=seed)
        assert_parity(ref6, cols, f"storm_seed{seed}")
        walls.append(wall)
        injected += sum(storm.injected.values())
    rows.append((f"chaos/storm_{len(walls)}seeds",
                 float(np.mean(walls)) * 1e6,
                 f"baseline_us={base_wall * 1e6:.1f};"
                 f"injected={injected};parity=ok"))
    return rows


# -- semi-join filter pushdown: filtered vs unfiltered probe exchange -----------------------

SEMIJOIN_SQL = """
select l_orderkey, sum(l_extendedprice) as rev
from lineitem, orders
where l_orderkey = o_orderkey and o_totalprice > 500000
group by l_orderkey
"""


def bench_semijoin(smoke: bool = False):
    """Selective repartition join with and without the build-side Bloom
    filter on the probe exchange.

    The build predicate (``o_totalprice > 500000``) keeps ~2% of orders,
    so ~98% of lineitem probe rows have no join partner: unfiltered they
    are hashed, written, and shuffled only to be dropped by the exact
    join; filtered they die on the scanning worker. The filter is
    force-enabled — bench-scale data sits far below the cost gate's
    break-even (the gate's own verdicts are asserted in
    tests/test_semijoin.py) — and the probe runs in barrier mode so
    request counts are deterministic.

    Asserted — failing the CI bench-smoke job on regression: (a)
    identical result rows, (b) ≥3× fewer probe-side shuffled bytes, (c)
    strictly fewer storage requests (killed rows empty whole partitions,
    which the join fleet then never reads), and (d) EXPLAIN ANALYZE
    reporting the pushed filter with its actual kill count.
    """
    import dataclasses as _dc
    import warnings as _warnings

    from repro.core import FaasPlatform, QueryCoordinator
    from repro.core.engine import explain_analyze

    sf, n_parts = (0.01, 4) if smoke else (0.02, 6)
    planner = PlannerConfig(bytes_per_worker=250_000,
                            broadcast_threshold_bytes=1,
                            exchange_partitions=4)
    runs = {}
    for mode in ("filtered", "unfiltered"):
        store, catalog = _db(sf, n_parts=n_parts)
        cfg = CoordinatorConfig(
            planner=_dc.replace(planner, semijoin=(mode == "filtered")),
            use_result_cache=False, adaptive=False, pipelined=False,
            calibrate_selectivity=False, straggler_min_timeout_s=100.0)
        platform = FaasPlatform(seed=13)
        with _warnings.catch_warnings():
            _warnings.simplefilter("ignore", DeprecationWarning)
            coord = QueryCoordinator(store, catalog, platform=platform,
                                     config=cfg)
        plan = coord.plan_sql(SEMIJOIN_SQL)
        if mode == "filtered":
            for p in plan.pipelines.values():
                if p.params.semijoin:
                    p.params.semijoin["enabled"] = True
        t0 = time.perf_counter()
        res = coord.execute_plan(plan)
        wall = time.perf_counter() - t0
        runs[mode] = (plan, res, res.fetch(store), wall)
        platform.close()

    fplan, fres, fcols, fwall = runs["filtered"]
    _, ures, ucols, uwall = runs["unfiltered"]
    order_f = np.lexsort([fcols[k] for k in sorted(fcols)])
    order_u = np.lexsort([ucols[k] for k in sorted(ucols)])
    for k in ucols:
        np.testing.assert_allclose(
            np.asarray(fcols[k], np.float64)[order_f],
            np.asarray(ucols[k], np.float64)[order_u],
            rtol=1e-9, atol=1e-9,
            err_msg=f"semijoin parity regression: {k}")

    pf = next(p for p in fres.stats.pipelines if p.semijoin is not None)
    pu = next(p for p in ures.stats.pipelines if p.pid == pf.pid)
    assert pf.semijoin["applied"] and pf.semijoin_killed > 0, \
        "semi-join filter was not applied"
    assert pu.bytes_written >= 3 * pf.bytes_written, (
        f"probe shuffle byte reduction regression: "
        f"{pu.bytes_written} vs {pf.bytes_written}")
    f_reqs = sum(p.requests for p in fres.stats.pipelines)
    u_reqs = sum(p.requests for p in ures.stats.pipelines)
    assert f_reqs < u_reqs, \
        f"filtered run issued {f_reqs} requests ≥ unfiltered's {u_reqs}"
    assert "semijoin: pushed" in explain_analyze(fplan, fres.stats), \
        "EXPLAIN ANALYZE lost the semijoin line"

    return [(
        "semijoin/selective_join_filtered_vs_unfiltered", fwall * 1e6,
        f"unfiltered_us={uwall * 1e6:.1f};"
        f"rows_killed={pf.semijoin_killed};"
        f"probe_bytes_filtered={pf.bytes_written};"
        f"probe_bytes_unfiltered={pu.bytes_written};"
        f"byte_reduction={pu.bytes_written / max(pf.bytes_written, 1):.1f}x;"
        f"requests_filtered={f_reqs};requests_unfiltered={u_reqs};"
        f"fpr={pf.semijoin.get('fpr', 0.0):.4f};"
        f"cents_filtered={fres.stats.cost.total_cents:.4f};"
        f"cents_unfiltered={ures.stats.cost.total_cents:.4f};"
        f"parity=ok")]


# -- kernels -------------------------------------------------------------------------------

def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    rows = []

    def timeit(fn, *args, n=3, **kw):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn(*args, **kw)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n * 1e6

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (8, 1024, 64), jnp.float32)
    kv = jax.random.normal(key, (2, 1024, 64), jnp.float32)
    us = timeit(ops.flash_attention, q, kv, kv, causal=True)
    flops = 2 * 2 * 8 * 1024 * 1024 * 64 / 2
    rows.append(("kernels/flash_attention_8x1024x64", us,
                 f"interpret_gflops={flops / us / 1e3:.2f}"))

    x = jax.random.normal(key, (2, 1024, 8, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (2, 1024, 8), jnp.float32))
    B = jax.random.normal(key, (2, 1024, 32), jnp.float32)
    us = timeit(ops.ssd_scan, x, dt, jnp.zeros(8), B, B, chunk=128)
    rows.append(("kernels/ssd_scan_2x1024x8x32", us, "interpret"))

    n = 1 << 17
    ship = jax.random.randint(key, (n,), 8000, 10000)
    disc = jax.random.randint(key, (n,), 0, 11).astype(jnp.float32) / 100
    qty = jax.random.randint(key, (n,), 1, 51).astype(jnp.float32)
    price = jax.random.uniform(key, (n,), jnp.float32) * 1e4
    us = timeit(ops.filter_agg, ship, disc, qty, price, date_lo=8500,
                date_hi=9000, disc_lo=0.05, disc_hi=0.07, qty_hi=24.0)
    rows.append(("kernels/filter_agg_131072", us,
                 f"rows_per_s={n / us * 1e6:.0f}"))

    gid = jax.random.randint(key, (n,), 0, 6)
    vals = jax.random.normal(key, (n, 4), jnp.float32)
    us = timeit(ops.groupby_onehot, gid, vals, n_groups=6)
    rows.append(("kernels/groupby_onehot_131072x6", us,
                 f"rows_per_s={n / us * 1e6:.0f}"))
    return rows
