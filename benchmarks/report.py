"""Render EXPERIMENTS.md tables from bench/dryrun*.jsonl records."""

from __future__ import annotations

import json
import sys


def load(path):
    rows = [json.loads(line) for line in open(path)]
    # keep the last record per (arch, shape, mesh)
    out = {}
    for r in rows:
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return list(out.values())


def roofline_table(rows, mesh="16x16") -> str:
    hdr = ("| arch | shape | kind | compute s | memory s | collective s | "
           "dominant | useful flops | roofline frac | fits HBM |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR "
                         f"| — | — | — | — | — | — | — |")
            continue
        f = r["roofline"]
        frac = r["roofline_fraction"]
        # decode cells: the meaningful number is distance to the
        # memory-bound ideal, not MFU
        if r["kind"] == "decode":
            bound = max(f["compute_s"], f["memory_s"], f["collective_s"])
            frac = f["memory_s"] / bound if bound else 0.0
            frac_s = f"{frac:.2f}*"
        else:
            frac_s = f"{frac:.3f}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {f['compute_s']:.3f} | {f['memory_s']:.3f} "
            f"| {f['collective_s']:.3f} | {f['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} | {frac_s} "
            f"| {'✓' if r.get('fits_hbm') else '✗'} |")
    return hdr + "\n".join(lines) + "\n"


def schedule_table(rows, mesh="2x16x16") -> str:
    hdr = ("| arch | shape | all-gather | all-reduce | reduce-scatter | "
           "all-to-all | permute | compile s |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        s = r["collective_schedule"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {s.get('all-gather', 0)} "
            f"| {s.get('all-reduce', 0)} | {s.get('reduce-scatter', 0)} "
            f"| {s.get('all-to-all', 0)} "
            f"| {s.get('collective-permute', 0)} "
            f"| {r.get('compile_s', 0)} |")
    return hdr + "\n".join(lines) + "\n"


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1
                else "bench/dryrun.jsonl")
    print("## Roofline (single pod 16×16)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## Multi-pod collective schedules (2×16×16)\n")
    print(schedule_table(rows, "2x16x16"))
