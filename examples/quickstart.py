"""Quickstart: a fully serverless SQL query, end to end.

Generates TPC-H onto the (simulated) object store, runs Q6 through the
serverless coordinator/worker runtime, prints the result with its cost,
then re-runs it to show the semantic result cache.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import CoordinatorConfig, FaasPlatform, QueryCoordinator
from repro.data import generate_tpch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import TPCH_Q6
from repro.storage import ObjectStore


def main():
    store = ObjectStore(tier="s3-standard")
    print("generating TPC-H sf=0.02 …")
    catalog = generate_tpch(store, sf=0.02, n_parts=4)

    platform = FaasPlatform()          # shared warm pool across queries
    cfg = CoordinatorConfig(planner=PlannerConfig(
        bytes_per_worker=512 << 10))

    for attempt in ("cold", "warm (cached)"):
        coordinator = QueryCoordinator(store, catalog, platform=platform,
                                       config=cfg)
        res = coordinator.execute_sql(TPCH_Q6)
        cols = res.fetch(store)
        s = res.stats
        print(f"\n[{attempt}] Q6 revenue = {cols['revenue'][0]:,.2f}")
        print(f"  sim latency {s.sim_latency_s:.2f}s · "
              f"cost {s.cost.total_cents:.4f}¢ · "
              f"workers {sum(p.n_fragments for p in s.pipelines)} · "
              f"cache hits {s.cache_hits}/{len(s.pipelines)}")


if __name__ == "__main__":
    main()
