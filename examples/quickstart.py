"""Quickstart: a fully serverless SQL query, end to end.

Opens a ``SkyriseSession`` (the unified client API), generates TPC-H
onto the (simulated) object store, runs Q6 through the serverless
worker runtime, prints the result with its cost, then re-runs it to
show the semantic result cache.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import CoordinatorConfig, connect
from repro.sql.physical import PlannerConfig
from repro.sql.queries import TPCH_Q6


def main():
    session = connect(
        config=CoordinatorConfig(planner=PlannerConfig(
            bytes_per_worker=512 << 10)))
    print("generating TPC-H sf=0.02 …")
    session.ensure_tpch(sf=0.02, n_parts=4)

    with session:
        for attempt in ("cold", "warm (cached)"):
            res = session.sql(TPCH_Q6)
            cols = res.fetch(session.store)
            s = res.stats
            print(f"\n[{attempt}] Q6 revenue = {cols['revenue'][0]:,.2f}")
            print(f"  sim latency {s.sim_latency_s:.2f}s · "
                  f"cost {s.cost.total_cents:.4f}¢ · "
                  f"workers {sum(p.n_fragments for p in s.pipelines)} · "
                  f"cache hits {s.cache_hits}/{len(s.pipelines)}")


if __name__ == "__main__":
    main()
