"""The paper's evaluation in miniature: TPC-H queries on serverless
infrastructure with fault injection, straggler re-triggering, cost
accounting, and the result cache (paper sections 3.3, 3.4, 4) — now
*concurrently submitted* through one ``SkyriseSession`` so all queries
share a single function-concurrency quota, warm sandbox pool, and
semantic result cache.

    PYTHONPATH=src python examples/tpch_demo.py
"""

from repro.api import CoordinatorConfig, FaultPlan, connect
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES


def main():
    # hostile infrastructure: 10% transient worker failures, 10% stragglers
    session = connect(
        quota=32,
        faults=FaultPlan(transient_error_prob=0.1, straggler_prob=0.1,
                         straggler_factor=20.0, seed=2),
        config=CoordinatorConfig(
            planner=PlannerConfig(bytes_per_worker=512 << 10,
                                  exchange_partitions=4),
            max_attempts=6),
        max_concurrent_queries=5, seed=1)
    print("generating TPC-H sf=0.05 …")
    session.ensure_tpch(sf=0.05, n_parts=8)

    qnames = ("q1", "q6", "q12", "q3", "q14")
    with session:
        handles = {q: session.submit(QUERIES[q]) for q in qnames}

        print(f"\n{'query':>6s} {'sim s':>8s} {'cost ¢':>9s} "
              f"{'workers':>8s} {'retries':>8s} {'retrig':>7s} {'rows':>6s}")
        for qname, h in handles.items():
            cols = h.fetch()
            s = h.stats()
            n = len(next(iter(cols.values()))) if cols else 0
            print(f"{qname:>6s} {s.sim_latency_s:8.2f} "
                  f"{s.cost.total_cents:9.4f} "
                  f"{sum(p.n_fragments for p in s.pipelines):8d} "
                  f"{sum(p.transient_failures for p in s.pipelines):8d} "
                  f"{sum(p.stragglers_retriggered for p in s.pipelines):7d} "
                  f"{n:6d}")

        st = session.stats()
        print(f"\nall 5 queries shared one platform: "
              f"{st['platform_invocations']} invocations, peak "
              f"{st['max_workers_in_flight']}/{st['quota']} in flight")

        print("\nQ12 answer (codes are dictionary indices — 2=MAIL, "
              "5=SHIP):")
        cols = session.submit(QUERIES["q12"]).fetch()  # full cache hit
        for i in range(len(cols["l_shipmode"])):
            print("  " + ", ".join(f"{k}={cols[k][i]:.0f}" for k in cols))


if __name__ == "__main__":
    main()
