"""The paper's evaluation in miniature: TPC-H queries on serverless
infrastructure with fault injection, straggler re-triggering, cost
accounting, and the result cache (paper sections 3.3, 3.4, 4).

    PYTHONPATH=src python examples/tpch_demo.py
"""

import numpy as np

from repro.core import (CoordinatorConfig, FaasPlatform, FaultPlan,
                        QueryCoordinator)
from repro.data import generate_tpch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import ObjectStore


def main():
    store = ObjectStore(tier="s3-standard")
    print("generating TPC-H sf=0.05 …")
    catalog = generate_tpch(store, sf=0.05, n_parts=8)

    # hostile infrastructure: 10% transient worker failures, 10% stragglers
    platform = FaasPlatform(seed=1, faults=FaultPlan(
        transient_error_prob=0.1, straggler_prob=0.1,
        straggler_factor=20.0, seed=2))
    cfg = CoordinatorConfig(planner=PlannerConfig(
        bytes_per_worker=512 << 10, exchange_partitions=4),
        max_attempts=6)

    print(f"\n{'query':>6s} {'sim s':>8s} {'cost ¢':>9s} {'workers':>8s} "
          f"{'retries':>8s} {'retrig':>7s} {'rows':>6s}")
    for qname in ("q1", "q6", "q12", "q3", "q14"):
        coord = QueryCoordinator(store, catalog, platform=platform,
                                 config=cfg)
        res = coord.execute_sql(QUERIES[qname])
        cols = res.fetch(store)
        s = res.stats
        n = len(next(iter(cols.values()))) if cols else 0
        print(f"{qname:>6s} {s.sim_latency_s:8.2f} "
              f"{s.cost.total_cents:9.4f} "
              f"{sum(p.n_fragments for p in s.pipelines):8d} "
              f"{sum(p.transient_failures for p in s.pipelines):8d} "
              f"{sum(p.stragglers_retriggered for p in s.pipelines):7d} "
              f"{n:6d}")

    print("\nQ12 answer (codes are dictionary indices — 2=MAIL, 5=SHIP):")
    coord = QueryCoordinator(store, catalog, platform=platform, config=cfg)
    res = coord.execute_sql(QUERIES["q12"])
    cols = res.fetch(store)
    for i in range(len(cols["l_shipmode"])):
        print("  " + ", ".join(f"{k}={cols[k][i]:.0f}" for k in cols))


if __name__ == "__main__":
    main()
