"""Batched LM serving with a KV cache: prefill once, decode many.

    PYTHONPATH=src python examples/serve_llm.py [--arch hymba-1.5b]
"""

import argparse

from repro.launch.serve import run_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()
    # reduced config: full configs are exercised via the dry-run
    run_serving(arch=args.arch, reduced=True, batch=4, prompt_len=64,
                new_tokens=24)


if __name__ == "__main__":
    main()
