"""Elastic, fault-tolerant training on the serverless runtime model.

Each training stage checkpoints to the object store; an injected crash
mid-run is recovered by simply re-invoking the driver — it resumes from
the last complete stage, exactly like an aborted query resumes from its
registered pipeline results (DESIGN.md §4).

    PYTHONPATH=src python examples/elastic_train.py
"""

from repro.launch.train import run_training
from repro.storage import ObjectStore


def main():
    store = ObjectStore(tier="local")
    kwargs = dict(arch="mamba2-130m", reduced=True, steps=45,
                  stage_steps=15, batch=8, seq=64, store=store,
                  run="elastic-demo")
    print("run 1: crashes at step 25 (stages at 15/30/45)")
    try:
        run_training(fail_at_step=25, **kwargs)
    except RuntimeError as e:
        print(f"  crashed as planned: {e}")

    print("run 2: fresh driver resumes from the step-15 checkpoint")
    losses, _ = run_training(**kwargs)
    print(f"done: final loss {losses[-1]:.4f} "
          f"(ran {len(losses)} steps after resume)")


if __name__ == "__main__":
    main()
