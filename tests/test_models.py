"""Per-architecture smoke tests (reduced configs, CPU, f32 compute):
forward + one train step assert shapes and finiteness; prefill + decode
must agree with the full forward — for every assigned architecture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models.decode import decode_step, prefill
from repro.models.model import forward, init_params
from repro.models.steps import make_train_step
from repro.optim import AdamW

F32 = jnp.float32


def _batch(cfg, key, B=2, S=48):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), F32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits = forward(cfg, params, batch["tokens"], compute_dtype=F32,
                     frames=batch.get("frames"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=F32))
    params2, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 48
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    frames = (jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model), F32)
              if cfg.enc_dec else None)
    full = forward(cfg, params, tokens, compute_dtype=F32, frames=frames)
    last, cache = prefill(cfg, params, tokens[:, :S], compute_dtype=F32,
                          frames=frames, max_len=S + 1)
    dec, cache2 = decode_step(cfg, params, cache, tokens[:, S],
                              compute_dtype=F32)
    scale = np.abs(np.asarray(full[:, S - 1], np.float32)).max() + 1e-9
    assert np.abs(np.asarray(last) - np.asarray(full[:, S - 1])
                  ).max() / scale < 2e-3, "prefill mismatch"
    assert np.abs(np.asarray(dec) - np.asarray(full[:, S])
                  ).max() / scale < 2e-3, "decode mismatch"
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_decreases(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    batch = _batch(cfg, key, B=4, S=32)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt, compute_dtype=F32))
    state = opt.init(params)
    losses = []
    for _ in range(5):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_full_configs_match_assignment():
    spec = {
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("qwen3-moe-235b-a22b").n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").top_k == 8
    assert get_config("granite-moe-1b-a400m").n_experts == 32


def test_param_count_sanity():
    # llama3-405b should be ~405B params
    n = get_config("llama3-405b").param_count()
    assert 3.8e11 < n < 4.3e11, n
    # mamba2-130m ~130M
    n = get_config("mamba2-130m").param_count()
    assert 0.8e8 < n < 1.8e8, n
    # MoE active < total
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < cfg.param_count() / 5
