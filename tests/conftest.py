import numpy as np
import pytest

import repro.exec  # noqa: F401  (enables x64 for SQL arithmetic)
from repro.data import generate_tpch
from repro.storage import InputHandler, ObjectStore


@pytest.fixture(scope="session")
def tpch_store():
    store = ObjectStore(tier="local", seed=0)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    return store, catalog


@pytest.fixture(scope="session")
def tpch_tables(tpch_store):
    """Full in-memory numpy tables for oracle evaluation."""
    store, catalog = tpch_store
    ih = InputHandler(store)
    tables = {}
    for name, meta in catalog.tables.items():
        parts = [ih.read_table(f)[0] for f in meta.files]
        tables[name] = {
            c.name: np.concatenate([p[c.name] for p in parts])
            for c in meta.schema}
    return tables
