"""Chaos engine: seeded full-stack fault injection (tentpole PR).

Proves the robustness claims end to end:

  * kill-point sweep — the owner process dies at every registry protocol
    step (claim / begin_partial / publish_partial / finish_partial) and
    at the storage commit point, under background fault noise, across
    seeds: every run recovers to TPC-H parity with the fault-free
    reference;
  * exactly-once fleet work — an owner killed right after writing its
    claim leaves an orphan that is TTL-stolen and re-driven with the
    platform seeing exactly one fleet's invocations (count-proven);
  * probabilistic seed sweep — transient GET/PUT errors, 503 throttles,
    latency spikes, torn PUTs, cold-start storms, and worker kills all
    at once, 20 seeds, parity on every one;
  * torn-write protection — a sandbox dying mid-PUT leaves only an
    orphaned ``_tmp/`` object; a readable partial object never appears
    at a final key;
  * typed failure taxonomy — budget exhaustion surfaces
    ``RetryBudgetExhausted`` through the handle with the causal chain
    from the failing fragment intact;
  * claim-steal CAS (satellite) — two waiters racing a TTL-expired
    claim resolve to exactly one winner via the versioned put;
  * ledger kills + lease fencing (satellites) — instance death at each
    ledger CAS leaves a consistent record a peer recovers, and a
    slow-but-alive owner cannot renew an expired lease;
  * hedged reads — the cost model's break-even timeout replaces the
    constant straggler timeout and duplicate GETs are priced/counted.

Every chaos schedule is seeded: a failing case reproduces locally from
its ``(seed, kill_point)`` test id alone.
"""

import threading
import time

import msgpack
import numpy as np
import pytest

from repro.api import (ChaosConfig, ChaosEngine, CoordinatorConfig,
                       FaasPlatform, QueryFailedError, QueryState,
                       RetryBudgetExhausted, RetryPolicy,
                       TransientInfraError, connect)
from repro.core.chaos import ChaosKill
from repro.core.cost import CostModel
from repro.core.registry import ResultRegistry
from repro.data import generate_tpch
from repro.service import (QueryService, RequestLedger, RequestStatus,
                           ServiceHandle)
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import (ColumnSpec, FooterCache, InputHandler,
                           ObjectStore, write_pax)

PLANNER = PlannerConfig(bytes_per_worker=250_000,
                        broadcast_threshold_bytes=150_000,
                        exchange_partitions=3)


def _config(**kw):
    # calibration off: no cross-run state, so invocation counts and
    # plans are bit-deterministic between a reference and a chaos run
    return CoordinatorConfig(planner=PLANNER, calibrate_selectivity=False,
                             **kw)


def _fresh_db(seed=0):
    store = ObjectStore(tier="local", seed=seed)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    return store, catalog


def _run(qname, chaos=None, *, config=None, claim_ttl_s=0.25, quota=16):
    """One full query execution on a fresh store; returns (columns,
    platform invocation count). The parity fetch runs with injection
    paused — the verification read path is not the system under test."""
    store, catalog = _fresh_db()
    platform = FaasPlatform(quota=quota, seed=0)
    registry = ResultRegistry(store, claim_ttl_s=claim_ttl_s)
    session = connect(store, catalog, platform=platform,
                      config=config or _config(), registry=registry,
                      chaos=chaos, max_concurrent_queries=4)
    try:
        res = session.submit(QUERIES[qname]).result(timeout=300)
        if chaos is not None:
            with chaos.pause():
                cols = res.fetch(store)
        else:
            cols = res.fetch(store)
    finally:
        session.close()
        platform.close()
    return cols, platform.invocations


_REFERENCE: dict = {}


def _reference(qname, *, pipelined=True):
    """Fault-free reference columns + invocation count (cached)."""
    key = (qname, pipelined)
    if key not in _REFERENCE:
        _REFERENCE[key] = _run(qname, config=_config(pipelined=pipelined))
    return _REFERENCE[key]


def _sorted_rows(cols):
    keys = sorted(cols)
    arrs = [np.asarray(cols[k], np.float64) for k in keys]
    order = np.lexsort(arrs)
    return {k: a[order] for k, a in zip(keys, arrs)}


def _assert_same_rows(a, b, ctx=""):
    sa, sb = _sorted_rows(a), _sorted_rows(b)
    assert sorted(sa) == sorted(sb), ctx
    for k in sa:
        np.testing.assert_allclose(sa[k], sb[k], rtol=1e-9, atol=1e-9,
                                   err_msg=f"{ctx} :: {k}")


# -- chaos engine mechanics ---------------------------------------------------

def test_chaos_schedule_is_deterministic():
    cfg = ChaosConfig(seed=5, get_error_prob=0.3, put_error_prob=0.3,
                      throttle_prob=0.1, torn_put_prob=0.2)
    a, b = ChaosEngine(cfg), ChaosEngine(cfg)
    seq_a = [a.storage_fault(op, f"k{i}")
             for i in range(200) for op in ("get", "put")]
    seq_b = [b.storage_fault(op, f"k{i}")
             for i in range(200) for op in ("get", "put")]
    assert seq_a == seq_b
    assert any(f is not None for f in seq_a)
    c = ChaosEngine(ChaosConfig(seed=6, get_error_prob=0.3,
                                put_error_prob=0.3, throttle_prob=0.1,
                                torn_put_prob=0.2))
    seq_c = [c.storage_fault(op, f"k{i}")
             for i in range(200) for op in ("get", "put")]
    assert seq_a != seq_c


def test_chaos_pause_suspends_injection():
    store = ObjectStore(tier="local", seed=0)
    store.put("k", b"abc")
    store.chaos = ChaosEngine(ChaosConfig(get_error_prob=1.0))
    with store.chaos.pause():
        assert store.get("k").data == b"abc"
    with pytest.raises(TransientInfraError):
        store.get("k")


def test_kv_tier_is_exempt_from_storage_faults():
    store = ObjectStore(tier="local", seed=0)
    store.chaos = ChaosEngine(ChaosConfig(get_error_prob=1.0,
                                          put_error_prob=1.0))
    kv = store.with_tier("dynamodb")
    kv.put("ledger/x", b"entry")          # would raise on a data tier
    assert kv.get("ledger/x").data == b"entry"
    with pytest.raises(TransientInfraError):
        store.put("data/x", b"payload")


# -- torn-write protection ----------------------------------------------------

def test_put_committed_kill_before_commit_leaves_no_final_object():
    store = ObjectStore(tier="local", seed=0)
    store.chaos = ChaosEngine(ChaosConfig(kill_points=("storage.commit",)))
    with pytest.raises(TransientInfraError):
        store.put_committed("data/x", b"hello world")
    # the upload finished but the commit never ran: final key absent,
    # one whole orphan under _tmp/ that nobody will ever read
    assert not store.exists("data/x")
    orphans = store.list("_tmp/")
    assert len(orphans) == 1
    assert store.get(orphans[0]).data == b"hello world"
    # the kill point is one-shot: the retry commits
    store.put_committed("data/x", b"hello world")
    assert store.get("data/x").data == b"hello world"


def test_torn_put_leaves_prefix_only_under_tmp():
    store = ObjectStore(tier="local", seed=0)
    store.chaos = ChaosEngine(ChaosConfig(seed=3, torn_put_prob=1.0))
    payload = bytes(range(200)) * 10
    with pytest.raises(TransientInfraError):
        store.put_committed("data/x", payload)
    assert not store.exists("data/x")
    orphans = store.list("_tmp/")
    assert len(orphans) == 1
    torn = store.get(orphans[0]).data       # list/get are chaos-free here
    assert 0 < len(torn) < len(payload)
    assert payload.startswith(torn)         # a strict prefix, as modeled


def test_memory_backend_put_if_version_cas():
    store = ObjectStore(tier="local", seed=0)
    assert store.put_if_version("k", b"v1", None)        # create-if-absent
    assert not store.put_if_version("k", b"x", None)     # exists now
    tok = store.version("k")
    assert store.put_if_version("k", b"v2", tok)         # matching token
    assert not store.put_if_version("k", b"v3", tok)     # stale token
    assert store.get("k").data == b"v2"


# -- registry claim-steal CAS (satellite) -------------------------------------

def test_claim_steal_is_versioned_cas():
    """Two waiters observe the same TTL-expired claim and both decide to
    steal: the conditional put lets exactly one land; the loser's put —
    conditioned on the version it observed before the winner moved it —
    must fail instead of silently overwriting the winner's claim."""
    store = ObjectStore(tier="local", seed=0)
    reg1 = ResultRegistry(store, claim_ttl_s=0.05)
    assert reg1.claim("h")
    time.sleep(0.08)                     # owner dies silently: claim stale

    key = reg1._key("h")
    kv = reg1.store
    stale_token = kv.version(key)        # both stealers observed this
    reg2 = ResultRegistry(store, claim_ttl_s=0.05)
    assert reg2.claim("h")               # stealer 1 wins the CAS
    # stealer 2 still holds the pre-steal version: its conditional put
    # loses (this is the seam the old check-then-put raced on)
    blob = msgpack.packb({"complete": False, "claimed_at": time.time(),
                          "owner": "stealer-2"})
    assert not kv.put_if_version(key, blob, stale_token)
    entry = msgpack.unpackb(kv.get(key).data)
    assert entry["owner"] == reg2._owned["h"]   # winner's claim intact
    # and a live claim is not claimable
    assert not ResultRegistry(store, claim_ttl_s=0.05).claim("h")


def test_claim_storm_exactly_one_winner():
    store = ObjectStore(tier="local", seed=0)
    stale = ResultRegistry(store, claim_ttl_s=0.05)
    assert stale.claim("h")
    time.sleep(0.08)
    barrier = threading.Barrier(8)
    wins = []

    def steal():
        reg = ResultRegistry(store, claim_ttl_s=0.05)
        barrier.wait()
        wins.append(reg.claim("h"))

    threads = [threading.Thread(target=steal) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(wins) == 1


# -- ledger kill points + lease fencing (satellites) --------------------------

@pytest.mark.parametrize("die_at", [RequestStatus.ADMITTED,
                                    RequestStatus.RUNNING])
def test_ledger_kill_after_cas_is_recoverable(die_at):
    """The service instance dies right after the CAS that landed the
    ``die_at`` transition: the entry is consistent (the write happened),
    the owner is gone, and lease expiry hands it back to QUEUED."""
    store = ObjectStore(tier="local", seed=0)
    store.chaos = ChaosEngine(
        ChaosConfig(kill_points=(f"ledger.{die_at.value}",)))
    led = RequestLedger(store, lease_ttl_s=0.05)
    led.submit("q", request_id="r")
    if die_at is RequestStatus.ADMITTED:
        with pytest.raises(ChaosKill):
            led.claim("r", "svc-dead")
    else:
        led.claim("r", "svc-dead")
        with pytest.raises(ChaosKill):
            led.transition("r", RequestStatus.RUNNING, if_owner="svc-dead")
    entry = led.get("r")
    assert entry.status is die_at        # the CAS landed before the death
    assert entry.owner == "svc-dead"
    time.sleep(0.08)
    recovered = led.recover_expired()
    assert [e.request_id for e in recovered] == ["r"]
    e = led.get("r")
    assert e.status is RequestStatus.QUEUED
    assert e.owner is None and e.attempt == 1
    assert led.claim("r", "svc-peer") is not None    # a peer takes over


def test_ledger_kill_after_terminal_cas_keeps_result():
    """Death right after the SUCCEEDED CAS: the terminal record (and its
    result pointer) survives; recovery has nothing to do."""
    store = ObjectStore(tier="local", seed=0)
    store.chaos = ChaosEngine(ChaosConfig(kill_points=("ledger.SUCCEEDED",)))
    led = RequestLedger(store, lease_ttl_s=0.05)
    led.submit("q", request_id="r")
    led.claim("r", "svc")
    led.transition("r", RequestStatus.RUNNING, if_owner="svc")
    with pytest.raises(ChaosKill):
        led.transition("r", RequestStatus.SUCCEEDED, if_owner="svc",
                       result={"prefix": "results/h"})
    time.sleep(0.08)
    assert led.recover_expired() == []   # terminal states are final
    e = led.get("r")
    assert e.status is RequestStatus.SUCCEEDED
    assert e.result == {"prefix": "results/h"}


def test_late_lease_renewal_is_fenced():
    """``recover_expired`` racing a slow-but-alive owner: once the lease
    deadline passed, the owner's renewal must fail (fencing) whether it
    arrives before or after recovery actually re-queues the entry —
    renewing after expiry would resurrect ownership a peer may already
    hold and run the query twice."""
    store = ObjectStore(tier="local", seed=0)
    led = RequestLedger(store, lease_ttl_s=0.05)
    led.submit("q", request_id="r")
    led.claim("r", "svc-slow")
    time.sleep(0.08)
    # the slow owner wakes up *before* any recovery ran: already fenced
    assert not led.renew_lease("r", "svc-slow")
    assert led.get("r").lease_expires < time.time()   # not extended
    # recovery then re-queues exactly once
    assert [e.request_id for e in led.recover_expired()] == ["r"]
    assert led.get("r").owner is None
    # and the fenced owner stays dead after recovery too
    assert not led.renew_lease("r", "svc-slow")


# -- kill-point sweep (tentpole acceptance) -----------------------------------

KILL_SITES = ("registry.claim", "registry.begin_partial",
              "registry.publish_partial", "registry.finish_partial",
              "storage.commit")


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("site", KILL_SITES)
def test_kill_point_sweep_recovers_to_parity(site, seed):
    """Owner death at every protocol step, under background fault noise,
    across seeds: recovery (TTL steal, partial-stream reset, fragment
    retry) must reconverge to the fault-free TPC-H answer. A failure
    reproduces from the (site, seed) in the test id."""
    ref_cols, _ = _reference("q3")
    chaos = ChaosEngine(ChaosConfig(
        seed=seed, kill_points=(site,),
        get_error_prob=0.003, put_error_prob=0.003,
        worker_kill_prob=0.01))
    # noise means fragments legitimately fail sometimes; give the
    # retry machinery headroom so the test asserts *recovery*, not the
    # max-attempts abort policy (covered by the taxonomy tests)
    cols, _ = _run("q3", chaos, config=_config(max_attempts=6))
    assert chaos.injected.get(f"kill:{site}") == 1, \
        f"kill point {site} never fired (seed={seed})"
    _assert_same_rows(ref_cols, cols, f"site={site} seed={seed}")


def test_claim_owner_death_runs_fleet_exactly_once():
    """An owner killed right after writing its claim (before invoking
    anything) leaves an orphan. The re-drive TTL-steals it and runs the
    fleet — the platform must see exactly the fault-free invocation
    count: zero duplicate fleet work, count-proven. Barrier mode makes
    the schedule sequential, so the count comparison is exact."""
    ref_cols, ref_inv = _reference("q6", pipelined=False)
    chaos = ChaosEngine(ChaosConfig(kill_points=("registry.claim",)))
    cols, inv = _run("q6", chaos, config=_config(pipelined=False))
    assert chaos.injected.get("kill:registry.claim") == 1
    _assert_same_rows(ref_cols, cols, "claim-kill")
    assert inv == ref_inv, \
        f"duplicate fleet work: {inv} invocations vs reference {ref_inv}"


# -- probabilistic seed sweep (tentpole acceptance) ---------------------------

@pytest.mark.parametrize("seed", range(20))
def test_probabilistic_chaos_sweep_parity(seed):
    """All fault classes at once — transient GET/PUT errors, throttles,
    latency spikes, torn PUTs, cold-start storms, worker kills — across
    20 seeds. Every schedule must recover to the fault-free answer
    within the default retry budget."""
    ref_cols, _ = _reference("q6")
    chaos = ChaosEngine(ChaosConfig(
        seed=seed, get_error_prob=0.005, put_error_prob=0.005,
        throttle_prob=0.003, latency_spike_prob=0.05, torn_put_prob=0.005,
        cold_storm_prob=0.10, worker_kill_prob=0.02))
    cols, _ = _run("q6", chaos, config=_config(max_attempts=6))
    _assert_same_rows(ref_cols, cols, f"seed={seed}")


def test_torn_puts_under_load_never_reach_final_keys():
    ref_cols, _ = _reference("q6")
    chaos = ChaosEngine(ChaosConfig(seed=11, torn_put_prob=0.25))
    store, catalog = _fresh_db()
    platform = FaasPlatform(quota=16, seed=0)
    session = connect(store, catalog, platform=platform,
                      config=_config(max_attempts=6),
                      registry=ResultRegistry(store, claim_ttl_s=0.25),
                      chaos=chaos, max_concurrent_queries=4)
    try:
        res = session.submit(QUERIES["q6"]).result(timeout=300)
        with chaos.pause():
            cols = res.fetch(store)
            # the run tore real writes, and every torn object is an
            # orphan under _tmp/ — never promoted to a final key
            assert chaos.injected.get("storage.put.torn", 0) > 0
            assert len(store.list("_tmp/")) > 0
    finally:
        session.close()
        platform.close()
    _assert_same_rows(ref_cols, cols, "torn-put")


def test_cold_start_storm_forces_cold_invocations():
    ref_cols, _ = _reference("q6")
    chaos = ChaosEngine(ChaosConfig(seed=2, cold_storm_prob=1.0))
    store, catalog = _fresh_db()
    platform = FaasPlatform(quota=16, seed=0)
    session = connect(store, catalog, platform=platform, config=_config(),
                      registry=ResultRegistry(store, claim_ttl_s=0.25),
                      chaos=chaos, max_concurrent_queries=4)
    try:
        res = session.submit(QUERIES["q6"]).result(timeout=300)
        with chaos.pause():
            cols = res.fetch(store)
    finally:
        session.close()
        platform.close()
    assert platform.cold_starts == platform.invocations
    _assert_same_rows(ref_cols, cols, "cold-storm")


# -- typed failure taxonomy ---------------------------------------------------

def test_retry_budget_exhaustion_surfaces_typed_error():
    """With a zero retry budget and every worker killed, the first
    fragment retry is refused: the handle must surface
    ``RetryBudgetExhausted`` (a ``QueryFailedError``) with the causal
    chain from the failing fragment preserved."""
    store, catalog = _fresh_db()
    chaos = ChaosEngine(ChaosConfig(seed=0, worker_kill_prob=1.0))
    platform = FaasPlatform(quota=16, seed=0)
    config = _config(retry=RetryPolicy(budget=0, base_delay_s=1e-4,
                                       max_delay_s=1e-3),
                     pilot_scan_min_units=10_000)
    session = connect(store, catalog, platform=platform, config=config,
                      registry=ResultRegistry(store, claim_ttl_s=0.25),
                      chaos=chaos, max_concurrent_queries=4)
    try:
        handle = session.submit(QUERIES["q6"])
        with pytest.raises(RetryBudgetExhausted) as ei:
            handle.result(timeout=120)
        err = ei.value
        assert isinstance(err, QueryFailedError)     # permanent, typed
        assert err.last_error is not None            # the final transient
        assert isinstance(err.last_error, TransientInfraError)
        assert err.__cause__ is not None             # causal chain intact
        assert handle.state is QueryState.FAILED
        assert handle.error() is err                 # re-raised as-is
    finally:
        session.close()
        platform.close()


def test_retry_policy_backoff_is_bounded_full_jitter():
    policy = RetryPolicy(base_delay_s=0.010, max_delay_s=0.050,
                         multiplier=2.0)
    rng = np.random.default_rng(0)
    for attempt in range(1, 10):
        cap = min(0.050, 0.010 * 2.0 ** (attempt - 1))
        for _ in range(20):
            d = policy.backoff_s(attempt, rng=rng)
            assert 0.0 <= d <= cap


# -- hedged reads -------------------------------------------------------------

def test_hedged_reads_use_cost_model_break_even_timeout():
    cm = CostModel()
    # s3-standard: median first byte + (request cents) / (GiB-s rate)
    t = cm.hedge_timeout_s("s3-standard")
    assert 0.027 < t < 0.2      # above the median, far below the 0.2s
    store = ObjectStore(tier="local", seed=0)
    schema = [ColumnSpec("x", "num", "<i8")]
    store.put("db/t.spax",
              write_pax({"x": np.arange(256, dtype=np.int64)}, schema))
    hedged = InputHandler(store, footer_cache=FooterCache(), cost_model=cm)
    assert hedged.hedged
    assert hedged.straggler_timeout_s == pytest.approx(
        cm.hedge_timeout_s(store.tier))
    plain = InputHandler(store, footer_cache=FooterCache())
    assert not plain.hedged and plain.straggler_timeout_s == 0.2
    # a latency spike pushes the simulated first byte past the hedge
    # timeout: the duplicate GET is issued and counted
    store.chaos = ChaosEngine(ChaosConfig(latency_spike_prob=1.0,
                                          latency_spike_factor=1e9))
    cols, _footer, st = hedged.read_table("db/t.spax")
    np.testing.assert_array_equal(cols["x"], np.arange(256))
    assert st.hedges > 0
    assert st.retriggers >= st.hedges


def test_hedged_reads_keep_query_parity():
    ref_cols, _ = _reference("q6")
    chaos = ChaosEngine(ChaosConfig(seed=4, latency_spike_prob=0.2))
    cols, _ = _run("q6", chaos, config=_config(hedged_reads=True))
    _assert_same_rows(ref_cols, cols, "hedged")


# -- service instance death (end to end) --------------------------------------

def test_service_dispatcher_death_recovered_by_second_instance():
    """The dispatcher dies by chaos kill right after the ledger CAS that
    admitted a request (the instance-crash analog): the first service
    stops cold, the lease expires, and a second instance over the same
    ledger re-queues and finishes the query — with exactly one fleet's
    invocations on the shared platform."""
    # fault-free invocation count for the same query/config
    store0, catalog0 = _fresh_db()
    p0 = FaasPlatform(quota=16, seed=0)
    with connect(store0, catalog0, platform=p0, config=_config(),
                 max_concurrent_queries=4) as s0:
        s0.sql(QUERIES["q6"])
    solo = p0.invocations
    p0.close()

    store, catalog = _fresh_db()
    chaos = ChaosEngine(ChaosConfig(kill_points=("ledger.ADMITTED",)))
    store.chaos = chaos              # before the ledger snapshots its view
    ledger = RequestLedger(store, lease_ttl_s=0.2)
    platform = FaasPlatform(quota=16, seed=0)
    s1 = connect(store, catalog, platform=platform, config=_config(),
                 max_concurrent_queries=4)
    svc1 = QueryService(s1, ledger=ledger, lease_ttl_s=0.2)
    h = svc1.submit(QUERIES["q6"])
    deadline = time.monotonic() + 30
    while not svc1._closing.is_set() and time.monotonic() < deadline:
        time.sleep(0.005)
    assert svc1._closing.is_set()                  # the instance died
    assert chaos.injected.get("kill:ledger.ADMITTED") == 1
    entry = ledger.get(h.request_id)
    assert entry.status is RequestStatus.ADMITTED  # the CAS landed
    assert platform.invocations == 0               # ...before any worker
    svc1.kill()
    time.sleep(0.25)                               # lease expires

    s2 = connect(store, catalog, platform=platform, config=_config(),
                 max_concurrent_queries=4)
    svc2 = QueryService(s2, ledger=ledger, lease_ttl_s=0.2)
    try:
        entry = ServiceHandle(h.request_id, svc2).wait(timeout=120)
        assert entry.status is RequestStatus.SUCCEEDED
        assert entry.attempt == 1                  # recovery was recorded
        assert platform.invocations == solo        # exactly one fleet
        cols = ServiceHandle(h.request_id, svc2).fetch(timeout=30)
        assert len(cols["revenue"]) == 1
    finally:
        svc2.close()
        s2.close()
        s1.close()
        platform.close()
