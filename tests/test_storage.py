"""Storage layer: PAX roundtrip, zone-map pruning, tiers, retriggering."""

import numpy as np
import pytest

from repro.storage import (ColumnSpec, FilesystemBackend, InputHandler,
                           ObjectStore, OutputHandler, TIERS,
                           ZonePredicate, write_pax)

SCHEMA = [
    ColumnSpec("a", "num", "<i8"),
    ColumnSpec("b", "num", "<f8"),
    ColumnSpec("c", "dict", "<i4", ("X", "Y", "Z")),
    ColumnSpec("d", "bytes", "S4"),
]


def _columns(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": np.arange(n, dtype=np.int64),
        "b": rng.random(n),
        "c": rng.integers(0, 3, n).astype(np.int32),
        "d": np.array([b"abcd"] * n, dtype="S4"),
    }


def test_pax_roundtrip():
    store = ObjectStore(tier="local")
    cols = _columns(10_000)
    store.put("t.spax", write_pax(cols, SCHEMA, row_group_rows=4096))
    out, footer, _ = InputHandler(store).read_table("t.spax")
    assert footer.n_rows == 10_000
    assert len(footer.row_groups) == 3
    for name in cols:
        assert np.array_equal(out[name], cols[name]), name


def test_pax_empty():
    store = ObjectStore(tier="local")
    cols = {k: v[:0] for k, v in _columns(4).items()}
    store.put("e.spax", write_pax(cols, SCHEMA))
    out, footer, _ = InputHandler(store).read_table("e.spax")
    assert footer.n_rows == 0
    assert len(out["a"]) == 0


def test_column_projection_reads_fewer_bytes():
    store = ObjectStore(tier="local")
    store.put("t.spax", write_pax(_columns(50_000), SCHEMA))
    ih = InputHandler(store)
    _, _, st_all = ih.read_table("t.spax")
    _, _, st_one = ih.read_table("t.spax", ["a"])
    assert st_one.bytes < st_all.bytes / 2
    assert st_one.requests < st_all.requests


def test_zone_map_pruning():
    store = ObjectStore(tier="local")
    store.put("t.spax", write_pax(_columns(40_000), SCHEMA,
                                  row_group_rows=10_000))
    ih = InputHandler(store)
    out, _, st = ih.read_table("t.spax", ["a"],
                               [ZonePredicate("a", ">=", 35_000)])
    assert st.row_groups_pruned == 3
    assert st.row_groups_read == 1
    assert out["a"].min() == 30_000  # whole surviving row group returned


def test_zone_map_in_predicate_on_dict():
    store = ObjectStore(tier="local")
    cols = _columns(20_000)
    cols["c"] = np.zeros(20_000, np.int32)
    cols["c"][10_000:] = 2
    store.put("t.spax", write_pax(cols, SCHEMA, row_group_rows=10_000))
    _, _, st = InputHandler(store).read_table(
        "t.spax", ["c"], [ZonePredicate("c", "in", (1, 2))])
    assert st.row_groups_pruned == 1


def test_filesystem_backend(tmp_path):
    store = ObjectStore(FilesystemBackend(str(tmp_path)), tier="local")
    store.put("x/y/z.bin", b"hello world")
    assert store.exists("x/y/z.bin")
    assert store.get("x/y/z.bin", (6, 5)).data == b"world"
    assert store.list("x/") == ["x/y/z.bin"]
    store.delete("x/y/z.bin")
    assert not store.exists("x/y/z.bin")


def test_tier_cost_model():
    std, exp = TIERS["s3-standard"], TIERS["s3-express"]
    # Table 3: express halves request costs but adds transfer costs
    assert exp.read_request_cents_per_1m == std.read_request_cents_per_1m / 2
    gib = 2**30
    assert exp.request_cost_cents(write=False, nbytes=gib) > \
        exp.read_request_cents_per_1m / 1e6
    assert std.request_cost_cents(write=False, nbytes=gib) == \
        pytest.approx(std.read_request_cents_per_1m / 1e6)


def test_tier_latency_ordering():
    rng = np.random.default_rng(0)
    std = np.median([TIERS["s3-standard"].draw_latency_s(rng, write=False)
                     for _ in range(500)])
    exp = np.median([TIERS["s3-express"].draw_latency_s(rng, write=False)
                     for _ in range(500)])
    assert exp < std
    assert abs(std - 0.027) / 0.027 < 0.35  # near the paper's median


def test_straggler_retriggering_charges_requests():
    store = ObjectStore(tier="s3-standard", seed=42)
    store.put("t.spax", write_pax(_columns(1000), SCHEMA))
    ih = InputHandler(store, straggler_timeout_s=1e-4, max_retriggers=2)
    _, _, st = ih.read_table("t.spax", ["a"])
    assert st.retriggers > 0            # tiny timeout → everything lags
    assert st.requests > 3              # duplicates were charged


def test_output_handler_single_object():
    store = ObjectStore(tier="local")
    out = OutputHandler(store)
    cols = _columns(100)
    out.append({k: v[:50] for k, v in cols.items()})
    out.append({k: v[50:] for k, v in cols.items()})
    st = out.finish("r.spax", SCHEMA)
    assert st.requests == 1             # one object per worker (paper 3.4)
    back, _, _ = InputHandler(store).read_table("r.spax")
    assert np.array_equal(back["a"], cols["a"])


def test_tier_views_share_backend_and_stats():
    store = ObjectStore(tier="s3-standard")
    hot = store.with_tier("s3-express")
    hot.put("k", b"x" * 100)
    assert store.exists("k")
    assert store.stats.put_requests == 1
