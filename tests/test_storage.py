"""Storage layer: PAX roundtrip, zone-map pruning, tiers, retriggering,
range coalescing, and the shared footer cache."""

import numpy as np
import pytest

from repro.storage import (ColumnSpec, FilesystemBackend, FooterCache,
                           InputHandler, ObjectStore, OutputHandler, TIERS,
                           ZonePredicate, coalesce_ranges,
                           plan_chunk_requests, write_pax)
from repro.storage.pax import ChunkRequest

SCHEMA = [
    ColumnSpec("a", "num", "<i8"),
    ColumnSpec("b", "num", "<f8"),
    ColumnSpec("c", "dict", "<i4", ("X", "Y", "Z")),
    ColumnSpec("d", "bytes", "S4"),
]


def _columns(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": np.arange(n, dtype=np.int64),
        "b": rng.random(n),
        "c": rng.integers(0, 3, n).astype(np.int32),
        "d": np.array([b"abcd"] * n, dtype="S4"),
    }


def test_pax_roundtrip():
    store = ObjectStore(tier="local")
    cols = _columns(10_000)
    store.put("t.spax", write_pax(cols, SCHEMA, row_group_rows=4096))
    out, footer, _ = InputHandler(store).read_table("t.spax")
    assert footer.n_rows == 10_000
    assert len(footer.row_groups) == 3
    for name in cols:
        assert np.array_equal(out[name], cols[name]), name


def test_pax_empty():
    store = ObjectStore(tier="local")
    cols = {k: v[:0] for k, v in _columns(4).items()}
    store.put("e.spax", write_pax(cols, SCHEMA))
    out, footer, _ = InputHandler(store).read_table("e.spax")
    assert footer.n_rows == 0
    assert len(out["a"]) == 0


def test_column_projection_reads_fewer_bytes():
    store = ObjectStore(tier="local")
    store.put("t.spax", write_pax(_columns(50_000), SCHEMA))
    ih = InputHandler(store)
    _, _, st_all = ih.read_table("t.spax")
    _, _, st_one = ih.read_table("t.spax", ["a"])
    assert st_one.bytes < st_all.bytes / 2
    assert st_one.requests < st_all.requests


def test_zone_map_pruning():
    store = ObjectStore(tier="local")
    store.put("t.spax", write_pax(_columns(40_000), SCHEMA,
                                  row_group_rows=10_000))
    ih = InputHandler(store)
    out, _, st = ih.read_table("t.spax", ["a"],
                               [ZonePredicate("a", ">=", 35_000)])
    assert st.row_groups_pruned == 3
    assert st.row_groups_read == 1
    assert out["a"].min() == 30_000  # whole surviving row group returned


def test_zone_map_in_predicate_on_dict():
    store = ObjectStore(tier="local")
    cols = _columns(20_000)
    cols["c"] = np.zeros(20_000, np.int32)
    cols["c"][10_000:] = 2
    store.put("t.spax", write_pax(cols, SCHEMA, row_group_rows=10_000))
    _, _, st = InputHandler(store).read_table(
        "t.spax", ["c"], [ZonePredicate("c", "in", (1, 2))])
    assert st.row_groups_pruned == 1


def test_filesystem_backend(tmp_path):
    store = ObjectStore(FilesystemBackend(str(tmp_path)), tier="local")
    store.put("x/y/z.bin", b"hello world")
    assert store.exists("x/y/z.bin")
    assert store.get("x/y/z.bin", (6, 5)).data == b"world"
    assert store.list("x/") == ["x/y/z.bin"]
    store.delete("x/y/z.bin")
    assert not store.exists("x/y/z.bin")


def test_tier_cost_model():
    std, exp = TIERS["s3-standard"], TIERS["s3-express"]
    # Table 3: express halves request costs but adds transfer costs
    assert exp.read_request_cents_per_1m == std.read_request_cents_per_1m / 2
    gib = 2**30
    assert exp.request_cost_cents(write=False, nbytes=gib) > \
        exp.read_request_cents_per_1m / 1e6
    assert std.request_cost_cents(write=False, nbytes=gib) == \
        pytest.approx(std.read_request_cents_per_1m / 1e6)


def test_tier_latency_ordering():
    rng = np.random.default_rng(0)
    std = np.median([TIERS["s3-standard"].draw_latency_s(rng, write=False)
                     for _ in range(500)])
    exp = np.median([TIERS["s3-express"].draw_latency_s(rng, write=False)
                     for _ in range(500)])
    assert exp < std
    assert abs(std - 0.027) / 0.027 < 0.35  # near the paper's median


def test_straggler_retriggering_charges_requests():
    store = ObjectStore(tier="s3-standard", seed=42)
    store.put("t.spax", write_pax(_columns(1000), SCHEMA))
    ih = InputHandler(store, straggler_timeout_s=1e-4, max_retriggers=2)
    _, _, st = ih.read_table("t.spax", ["a"])
    assert st.retriggers > 0            # tiny timeout → everything lags
    assert st.requests > 3              # duplicates were charged
    # retriggered duplicates occupy the request pool: the read's makespan
    # covers them instead of only the winning requests
    assert st.sim_time_s > 0


# -- range coalescing ---------------------------------------------------------

def test_coalesce_ranges_unit():
    reqs = [ChunkRequest(0, "a", 0, 100), ChunkRequest(0, "b", 100, 50),
            ChunkRequest(0, "c", 180, 20), ChunkRequest(1, "a", 1000, 10)]
    merged = coalesce_ranges(reqs, gap=64)
    assert [(off, length) for off, length, _ in merged] == \
        [(0, 200), (1000, 10)]          # a+b adjacent, c within gap
    assert [len(m) for _, _, m in merged] == [3, 1]
    # gap 0 still merges strictly adjacent ranges
    merged0 = coalesce_ranges(reqs, gap=0)
    assert [(off, length) for off, length, _ in merged0] == \
        [(0, 150), (180, 20), (1000, 10)]


def test_coalesced_read_fewer_requests_same_data():
    store = ObjectStore(tier="local")
    cols = _columns(30_000)
    store.put("t.spax", write_pax(cols, SCHEMA, row_group_rows=10_000))
    fine = InputHandler(store, coalesce_gap=-1,   # negative gap: one GET
                        footer_cache=FooterCache())  # per chunk (disabled)
    wide = InputHandler(store, footer_cache=FooterCache())
    out_f, footer, st_f = fine.read_table("t.spax")
    out_w, _, st_w = wide.read_table("t.spax")
    n_chunks = len(plan_chunk_requests(
        footer, [c.name for c in footer.columns], range(3)))
    assert n_chunks == 12               # 3 row groups × 4 columns
    assert st_w.requests < st_f.requests
    assert st_w.coalesced_chunks > 0
    for name in cols:                   # byte-identical data either way
        assert np.array_equal(out_w[name], cols[name]), name
        assert np.array_equal(out_f[name], cols[name]), name


# -- shared footer cache ------------------------------------------------------

def test_footer_cache_shared_across_handlers():
    store = ObjectStore(tier="local")
    store.put("t.spax", write_pax(_columns(5000), SCHEMA))
    cache = FooterCache()
    a = InputHandler(store, footer_cache=cache)
    b = InputHandler(store, footer_cache=cache)
    _, _, st_a = a.read_table("t.spax", ["a"])
    _, _, st_b = b.read_table("t.spax", ["a"])
    assert st_a.footer_hits == 0 and st_b.footer_hits == 1
    assert st_b.requests == st_a.requests - 2   # tail + footer GETs saved
    assert cache.hits == 1


def test_footer_cache_invalidated_by_overwrite():
    store = ObjectStore(tier="local")
    ih = InputHandler(store, footer_cache=FooterCache())
    store.put("t.spax", write_pax(_columns(100, seed=1), SCHEMA))
    out1, _, _ = ih.read_table("t.spax", ["b"])
    store.put("t.spax", write_pax(_columns(200, seed=2), SCHEMA))
    out2, _, st = ih.read_table("t.spax", ["b"])
    assert st.footer_hits == 0          # etag changed → fresh footer
    assert len(out2["b"]) == 200
    assert not np.array_equal(out1["b"][:100], out2["b"][:100])


def test_empty_partition_skips_chunk_requests():
    store = ObjectStore(tier="s3-standard", seed=0)
    cols = {k: v[:0] for k, v in _columns(4).items()}
    store.put("e.spax", write_pax(cols, SCHEMA))
    ih = InputHandler(store)
    out, footer, st1 = ih.read_table("e.spax")
    assert footer.n_rows == 0 and len(out["a"]) == 0
    assert st1.requests == 2            # the two footer GETs, no chunks
    # footer-only reads are *timed*: before the makespan fix their
    # latency accumulated as += 0.0
    assert st1.sim_time_s > 0
    _, _, st2 = ih.read_table("e.spax")
    assert st2.requests == 0            # cached footer: free empty-check
    assert st2.footer_hits == 1


def test_output_handler_single_object():
    store = ObjectStore(tier="local")
    out = OutputHandler(store)
    cols = _columns(100)
    out.append({k: v[:50] for k, v in cols.items()})
    out.append({k: v[50:] for k, v in cols.items()})
    st = out.finish("r.spax", SCHEMA)
    assert st.requests == 1             # one object per worker (paper 3.4)
    back, _, _ = InputHandler(store).read_table("r.spax")
    assert np.array_equal(back["a"], cols["a"])


def test_tier_views_share_backend_and_stats():
    store = ObjectStore(tier="s3-standard")
    hot = store.with_tier("s3-express")
    hot.put("k", b"x" * 100)
    assert store.exists("k")
    assert store.stats.put_requests == 1
