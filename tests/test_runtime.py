"""Coordinator runtime: caching, fault tolerance, stragglers, restart,
elastic sizing, cost accounting (paper sections 3.3, 3.4)."""

import numpy as np
import pytest

from repro.core import (CoordinatorConfig, FaasPlatform, FaultPlan,
                        QueryAborted, QueryCoordinator)
from repro.data import generate_tpch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import ObjectStore

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=250_000, broadcast_threshold_bytes=150_000,
    exchange_partitions=3))


def _fresh_db(seed=0, tier="local"):
    store = ObjectStore(tier=tier, seed=seed)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    return store, catalog


def test_result_cache_skips_pipelines():
    store, catalog = _fresh_db()
    platform = FaasPlatform(seed=0)
    c1 = QueryCoordinator(store, catalog, platform=platform, config=CFG)
    r1 = c1.execute_sql(QUERIES["q12"])
    assert r1.stats.cache_hits == 0
    inv_before = platform.invocations
    c2 = QueryCoordinator(store, catalog, platform=platform, config=CFG)
    r2 = c2.execute_sql(QUERIES["q12"])
    assert r2.stats.cache_hits == len(r2.stats.pipelines)
    assert platform.invocations == inv_before  # zero new workers
    assert r2.stats.cost.total_cents < r1.stats.cost.total_cents / 10


def test_cache_shared_across_physical_configs():
    """Semantic matching (3.4): a different worker/exchange layout reuses
    the cached scans."""
    store, catalog = _fresh_db()
    platform = FaasPlatform(seed=0)
    QueryCoordinator(store, catalog, platform=platform,
                     config=CFG).execute_sql(QUERIES["q1"])
    other = CoordinatorConfig(planner=PlannerConfig(
        bytes_per_worker=2_000_000))
    r = QueryCoordinator(store, catalog, platform=platform,
                         config=other).execute_sql(QUERIES["q1"])
    assert r.stats.cache_hits == len(r.stats.pipelines)


def test_cache_disabled():
    store, catalog = _fresh_db()
    cfg = CoordinatorConfig(planner=CFG.planner, use_result_cache=False)
    platform = FaasPlatform(seed=0)
    QueryCoordinator(store, catalog, platform=platform,
                     config=cfg).execute_sql(QUERIES["q6"])
    r = QueryCoordinator(store, catalog, platform=platform,
                         config=cfg).execute_sql(QUERIES["q6"])
    assert r.stats.cache_hits == 0


def test_transient_failures_are_retried_and_result_identical():
    store, catalog = _fresh_db(tier="s3-standard")
    clean = QueryCoordinator(store, catalog, platform=FaasPlatform(seed=0),
                             config=CFG).execute_sql(QUERIES["q12"])
    want = clean.fetch(store)

    store2, catalog2 = _fresh_db(tier="s3-standard")
    faulty = FaasPlatform(seed=1, faults=FaultPlan(
        transient_error_prob=0.25, seed=3))
    cfg = CoordinatorConfig(planner=CFG.planner, max_attempts=6)
    r = QueryCoordinator(store2, catalog2, platform=faulty,
                         config=cfg).execute_sql(QUERIES["q12"])
    got = r.fetch(store2)
    assert sum(p.transient_failures for p in r.stats.pipelines) > 0
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k], np.float64),
                                   np.asarray(want[k], np.float64))


def test_straggler_retriggering_reduces_latency_and_is_idempotent():
    store, catalog = _fresh_db(tier="s3-standard")
    plat = FaasPlatform(seed=3, faults=FaultPlan(
        straggle_fragments=((0, 1, 0),), straggler_factor=50.0, seed=5))
    r = QueryCoordinator(store, catalog, platform=plat,
                         config=CFG).execute_sql(QUERIES["q1"])
    retriggered = sum(p.stragglers_retriggered for p in r.stats.pipelines)
    assert retriggered >= 1
    # the duplicate raced the straggler: completion ≈ detection + fresh run,
    # far below the 50× straggled runtime
    straggled_pipe = r.stats.pipelines[0]
    assert straggled_pipe.sim_s < 30.0
    # effective completion beats the straggler runtime by construction
    for p in r.stats.pipelines:
        assert p.sim_s < 1000


def test_abort_and_restart_from_checkpoint():
    """Aborted queries continue from any complete stage (section 3.3)."""
    store, catalog = _fresh_db(tier="local")
    # every attempt of pipeline-1 fragment-0 dies → abort after the
    # sibling pipeline of the stage has completed and registered
    kills = tuple((1, 0, a) for a in range(10))
    plat = FaasPlatform(seed=0, faults=FaultPlan(kill_fragments=kills))
    coord = QueryCoordinator(store, catalog, platform=plat, config=CFG)
    with pytest.raises(QueryAborted) as e:
        coord.execute_sql(QUERIES["q12"])
    assert e.value.post_mortem["fragment"] == 0

    # a fresh coordinator on a healthy platform resumes: the completed
    # sibling pipeline (the lineitem scan) acts as the stage checkpoint
    coord2 = QueryCoordinator(store, catalog, platform=FaasPlatform(seed=0),
                              config=CFG)
    r = coord2.execute_sql(QUERIES["q12"])
    assert r.stats.cache_hits >= 1
    got = r.fetch(store)
    assert len(got["l_shipmode"]) == 2


def test_reassignment_splits_fragment_inputs():
    store, catalog = _fresh_db(tier="local")
    # fragment 0 of pipeline 0 fails twice, succeeds on 3rd attempt;
    # with >1 scan unit this triggers reassignment to an extra worker
    plat = FaasPlatform(seed=0, faults=FaultPlan(
        kill_fragments=((0, 0, 0), (0, 0, 1))))
    cfg = CoordinatorConfig(planner=PlannerConfig(
        bytes_per_worker=2_000_000), max_attempts=4)
    r = QueryCoordinator(store, catalog, platform=plat,
                         config=cfg).execute_sql(QUERIES["q6"])
    assert sum(p.reassignments for p in r.stats.pipelines) == 1


def test_elastic_worker_sizing():
    """Worker count follows input size (section 3.2)."""
    store, catalog = _fresh_db()
    small = PlannerConfig(bytes_per_worker=10 << 20)
    big = PlannerConfig(bytes_per_worker=100_000)
    from repro.sql.logical import Binder
    from repro.sql.parser import parse
    from repro.sql.physical import compile_query
    from repro.sql.rules import optimize
    lqp, _ = Binder(catalog).bind(parse(QUERIES["q6"]))
    lqp = optimize(lqp)
    ps = compile_query(lqp, catalog, small)
    pb = compile_query(lqp, catalog, big)
    frags_small = ps.pipelines[0].n_fragments
    frags_big = pb.pipelines[0].n_fragments
    assert frags_small < frags_big
    assert frags_big <= len(catalog.table("lineitem").files)


def test_cold_starts_only_initial(tpch_store):
    """Paper 3.2: cold starts are negligible and only occur in the initial
    query stage — the warm pool persists across stages."""
    store, catalog = _fresh_db(tier="local")
    plat = FaasPlatform(seed=0)
    QueryCoordinator(store, catalog, platform=plat,
                     config=CFG).execute_sql(QUERIES["q12"])
    first_query_colds = plat.cold_starts
    store.delete_prefix("registry/")
    QueryCoordinator(store, catalog, platform=plat,
                     config=CFG).execute_sql(QUERIES["q12"])
    assert plat.cold_starts == first_query_colds  # all warm now


def test_cost_accounting_components():
    store, catalog = _fresh_db(tier="s3-standard")
    r = QueryCoordinator(store, catalog, platform=FaasPlatform(seed=0),
                         config=CFG).execute_sql(QUERIES["q6"])
    c = r.stats.cost
    assert c.compute_cents > 0
    assert c.invoke_cents > 0
    assert c.storage_request_cents > 0
    assert c.total_cents == pytest.approx(
        c.compute_cents + c.invoke_cents + c.messaging_cents
        + c.storage_request_cents + c.storage_transfer_cents)


def test_two_level_invocation_dispatch_scaling():
    plat = FaasPlatform(seed=0)
    flat = plat.dispatch_time_s(1024, two_level=False)
    tree = plat.dispatch_time_s(1024, two_level=True)
    assert tree < flat / 10  # ~2·√W vs W invocations


def test_quota_waves():
    """The admission ledger partitions demand into quota-bounded waves
    (the single-tenant case: acquire/release with no other holders)."""
    from repro.core import AdmissionController
    adm = FaasPlatform(seed=0, quota=100).admission
    assert isinstance(adm, AdmissionController)
    waves, n = [], 250
    while n:
        g = adm.acquire(n)
        adm.release(g)
        waves.append(g)
        n -= g
    assert waves == [100, 100, 50]
    assert adm.max_in_flight == 100
    with pytest.raises(ValueError):
        AdmissionController(quota=0)
