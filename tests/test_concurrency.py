"""Wall-clock parallel execution backend (threaded ``invoke_many`` with
per-fragment slot release), in-flight cross-query dedup
(claim/publish/await_complete), straggler detection on runtimes,
reassignment critical-path accounting, and warm-pool bookkeeping."""

import threading
import time

import numpy as np
import pytest

from repro.api import (CoordinatorConfig, FaasPlatform, FaultPlan,
                       QueryObserver, connect)
from repro.core.engine import QueryAborted, QueryEngine
from repro.core.registry import ResultRegistry
from repro.core.worker import make_worker_handler
from repro.data import generate_tpch
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.storage import ObjectStore

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=250_000, broadcast_threshold_bytes=150_000,
    exchange_partitions=3))


def _fresh_db(seed=0, tier="local", n_parts=4):
    store = ObjectStore(tier=tier, seed=seed)
    catalog = generate_tpch(store, sf=0.01, n_parts=n_parts, seed=0)
    return store, catalog


# -- tentpole: fragments truly overlap in wall-clock --------------------------

def test_fragments_overlap_in_wall_clock():
    """With quota ≥ fleet size, a pipeline's wall-clock is measurably
    below the sum of its fragment handler times."""
    store, catalog = _fresh_db()
    real = make_worker_handler(store)
    handler_walls = []

    def slow_handler(payload):
        t0 = time.perf_counter()
        resp, rt = real(payload)
        time.sleep(0.15)
        handler_walls.append(time.perf_counter() - t0)
        return resp, rt

    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=50_000),
        use_result_cache=False)
    engine = QueryEngine(store, catalog,
                         platform=FaasPlatform(quota=64, seed=0),
                         config=cfg, handler=slow_handler)
    res = engine.execute_sql(
        "select l_quantity, l_extendedprice from lineitem")
    assert res.stats.pipelines[-1].n_fragments >= 3
    assert res.stats.wall_s < 0.6 * sum(handler_walls)


def test_quota_never_exceeded_under_threaded_backend():
    """Stress: 16 concurrent queries × quota 8 — the combined in-flight
    fleet never exceeds the quota, and every slot is returned."""
    store, catalog = _fresh_db()
    quota = 8
    platform = FaasPlatform(quota=quota, seed=0)
    cfg = CoordinatorConfig(planner=CFG.planner, use_result_cache=False)
    with connect(store, catalog, platform=platform, config=cfg,
                 max_concurrent_queries=16) as session:
        qnames = ("q1", "q6", "q12", "q14")
        handles = [session.submit(QUERIES[qnames[i % len(qnames)]])
                   for i in range(16)]
        for h in handles:
            h.result(timeout=600)
    adm = platform.admission
    assert 1 <= adm.max_in_flight <= quota
    assert adm.in_flight == 0


# -- tentpole: in-flight dedup ------------------------------------------------

class _StartRecorder(QueryObserver):
    def __init__(self):
        self.lock = threading.Lock()
        self.started = []

    def on_pipeline_start(self, query_id, pid, sem_hash, n_fragments):
        with self.lock:
            self.started.append(sem_hash)


def _slow(handler, delay=0.2):
    def slow_handler(payload):
        resp, rt = handler(payload)
        time.sleep(delay)
        return resp, rt
    return slow_handler


def test_inflight_dedup_two_concurrent_identical_queries():
    """Two concurrent identical queries trigger exactly one pipeline
    execution: the registry records one claim per pipeline and the
    second query blocks on await_complete."""
    store, catalog = _fresh_db()
    platform = FaasPlatform(quota=32, seed=0)
    rec = _StartRecorder()
    with connect(store, catalog, platform=platform, config=CFG,
                 max_concurrent_queries=2, observers=(rec,)) as session:
        session.handler = _slow(session.handler)
        h1 = session.submit(QUERIES["q6"])
        h2 = session.submit(QUERIES["q6"])
        r1 = h1.result(timeout=300)
        r2 = h2.result(timeout=300)
        st = session.stats()
    # no sem_hash was executed twice → one set of worker invocations
    assert len(rec.started) == len(set(rec.started))
    assert st["registry_claims"] == len(rec.started)
    # one of the two shared the other's in-flight execution
    assert st["inflight_dedup_hits"] >= 1
    # every executed sem_hash ran exactly once across both queries
    executed = [p.sem_hash for r in (r1, r2)
                for p in r.stats.pipelines if not p.cache_hit]
    assert len(executed) == len(set(executed))
    assert any(p.deduped for r in (r1, r2) for p in r.stats.pipelines)
    # both clients still get identical full results
    c1, c2 = r1.fetch(store), r2.fetch(store)
    for k in c1:
        np.testing.assert_allclose(np.asarray(c1[k], np.float64),
                                   np.asarray(c2[k], np.float64))


def test_inflight_dedup_across_sessions_sharing_one_store():
    """Claims live in the store's KV tier, so dedup spans sessions: two
    sessions submitting the same query concurrently produce exactly one
    set of worker invocations for the shared pipelines."""
    # reference: how many invocations one solo execution needs
    ref_store, ref_catalog = _fresh_db()
    ref_platform = FaasPlatform(quota=32, seed=0)
    with connect(ref_store, ref_catalog, platform=ref_platform,
                 config=CFG) as ref:
        ref.sql(QUERIES["q12"])
    solo_invocations = ref_platform.invocations

    store, catalog = _fresh_db()
    platform = FaasPlatform(quota=32, seed=0)
    s1 = connect(store, catalog, platform=platform, config=CFG)
    s2 = connect(store, catalog, platform=platform, config=CFG)
    try:
        s1.handler = _slow(s1.handler)
        s2.handler = _slow(s2.handler)
        h1 = s1.submit(QUERIES["q12"])
        h2 = s2.submit(QUERIES["q12"])
        r1 = h1.result(timeout=300)
        r2 = h2.result(timeout=300)
    finally:
        s1.close()
        s2.close()
    assert platform.invocations == solo_invocations
    assert s1.registry.claims + s2.registry.claims == \
        len(r1.stats.pipelines)
    for k1, k2 in zip(sorted(r1.fetch(store)), sorted(r2.fetch(store))):
        assert k1 == k2


def test_failed_query_abandons_claim_so_others_can_run():
    """A claim owner that aborts must release the claim — a later query
    for the same sem_hash re-claims and executes instead of hanging."""
    store, catalog = _fresh_db()
    kills = tuple((0, 0, a) for a in range(10))
    plat = FaasPlatform(seed=0, faults=FaultPlan(kill_fragments=kills))
    engine = QueryEngine(store, catalog, platform=plat, config=CFG)
    with pytest.raises(QueryAborted):
        engine.execute_sql(QUERIES["q6"])

    engine2 = QueryEngine(store, catalog, platform=FaasPlatform(seed=0),
                          config=CFG)
    res = engine2.execute_sql(QUERIES["q6"])   # hangs if the claim leaked
    assert len(res.fetch(store)["revenue"]) == 1


def test_orphaned_claim_is_stolen_after_ttl():
    """A claim whose owner died without abandoning (e.g. process kill)
    must not hang waiters forever: past the TTL the next claimant
    steals it and executes (idempotent workers make the race safe)."""
    store, catalog = _fresh_db()
    engine = QueryEngine(
        store, catalog, platform=FaasPlatform(seed=0), config=CFG,
        registry=ResultRegistry(store, claim_ttl_s=0.25))
    plan = engine.plan_sql(QUERIES["q6"])
    # simulate a dead owner: claim with a long-TTL registry, never finish
    assert ResultRegistry(store).claim(
        plan.pipelines[plan.root_pid].sem_hash)
    res = engine.execute_plan(plan)       # must steal, not hang
    assert len(res.fetch(store)["revenue"]) == 1


def test_session_closes_owned_platform_executor():
    store, catalog = _fresh_db()
    session = connect(store, catalog, config=CFG, quota=4)
    session.sql(QUERIES["q6"])
    assert session.platform._executor is not None
    session.close()
    assert session.platform._executor is None   # pool torn down

    # an externally shared platform stays up across a session close
    platform = FaasPlatform(quota=4, seed=0)
    with connect(store, catalog, platform=platform, config=CFG) as s2:
        s2.sql(QUERIES["q1"])
    assert platform._executor is not None
    platform.close()
    assert platform._executor is None


# -- satellite: straggler detection on runtimes, not wave offsets -------------

def test_no_straggler_misdetection_when_quota_below_fleet():
    """quota=2, 8 fragments, no fault injection: fragments admitted
    after the first quota-full batch are NOT stragglers — detection
    runs on per-fragment runtimes, never on slot-offset completions."""
    store, catalog = _fresh_db(n_parts=8)
    real = make_worker_handler(store)

    def handler(payload):
        resp, _ = real(payload)
        return resp, 1.0            # uniform simulated runtime

    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=10_000),
        use_result_cache=False)
    engine = QueryEngine(store, catalog,
                         platform=FaasPlatform(quota=2, seed=0),
                         config=cfg, handler=handler)
    res = engine.execute_sql("select l_quantity from lineitem")
    scan = res.stats.pipelines[0]
    assert scan.n_fragments == 8    # precondition: fleet ≫ quota
    assert sum(p.stragglers_retriggered
               for p in res.stats.pipelines) == 0
    # per-slot release: 8 × ~1s runtimes over 2 slots ≈ 4s+ of
    # simulated critical path (list-scheduling makespan, not one wave)
    assert res.stats.sim_latency_s > 3.5


# -- satellite: reassigned fragment joins the critical path -------------------

def test_reassigned_fragment_extends_critical_path():
    """The extra worker spawned by reassignment runs in parallel with
    the retry; when it is the slower of the two it must dominate the
    pipeline's simulated time (max(retry, extra), not +0)."""
    store, catalog = _fresh_db()
    real = make_worker_handler(store)

    def handler(payload):
        resp, _ = real(payload)
        extra = payload["fragment"] >= payload["n_fragments"]
        return resp, (5.0 if extra else 0.05)

    plat = FaasPlatform(seed=0, faults=FaultPlan(
        kill_fragments=((0, 0, 0), (0, 0, 1))))
    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=2_000_000),
        max_attempts=4, use_result_cache=False)
    engine = QueryEngine(store, catalog, platform=plat, config=cfg,
                         handler=handler)
    res = engine.execute_sql(QUERIES["q6"])
    p0 = res.stats.pipelines[0]
    assert p0.reassignments == 1
    assert p0.sim_s >= 5.0          # the extra worker is the slow path


def test_straggler_retrigger_after_reassignment_no_duplicate_rows():
    """A reassigned fragment's spec is narrowed in place: if the slow
    (reassignment-inflated) fragment is then re-triggered as a
    straggler, the duplicate must re-run the *split* inputs — re-running
    the pre-split spec would overwrite the fragment's output with rows
    the extra fragment also produced."""
    store, catalog = _fresh_db()
    real = make_worker_handler(store)

    def handler(payload):
        resp, _ = real(payload)
        extra = payload["fragment"] >= payload["n_fragments"]
        return resp, (5.0 if extra else 1.0)

    plat = FaasPlatform(seed=0, faults=FaultPlan(
        kill_fragments=((0, 0, 0), (0, 0, 1))))
    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=50_000),
        max_attempts=4, use_result_cache=False)
    engine = QueryEngine(store, catalog, platform=plat, config=cfg,
                         handler=handler)
    res = engine.execute_sql("select l_quantity from lineitem")
    p0 = res.stats.pipelines[0]
    assert p0.reassignments == 1
    assert p0.stragglers_retriggered >= 1   # the regression's trigger
    cols = res.fetch(store)
    assert len(cols["l_quantity"]) == catalog.table("lineitem").rows
    # the duplicate's payload must not double-count reported output
    assert p0.rows_out == catalog.table("lineitem").rows


def test_abandon_after_ttl_steal_keeps_stealers_claim():
    """abandon() only removes the claim its own registry wrote: an owner
    that lost its claim to a TTL steal must not delete the stealer's
    live claim."""
    store = ObjectStore(tier="local", seed=0)
    owner = ResultRegistry(store, claim_ttl_s=0.1)
    stealer = ResultRegistry(store, claim_ttl_s=0.1)
    assert owner.claim("h")
    time.sleep(0.15)
    assert stealer.claim("h")       # TTL steal of the orphaned claim
    owner.abandon("h")              # stale owner fails afterwards
    # the stealer's claim is still in force: nobody else can claim
    assert not ResultRegistry(store, claim_ttl_s=60.0).claim("h")


# -- satellite: dead sandboxes must not rejoin the warm pool ------------------

def test_failed_sandbox_does_not_rejoin_warm_pool():
    plat = FaasPlatform(seed=0, quota=4,
                        faults=FaultPlan(kill_fragments=((0, 0, 0),)))

    def handler(payload):
        return {}, 0.01

    r0 = plat.invoke(handler, {}, pipeline=0, fragment=0, attempt=0)
    assert r0.error is not None and r0.cold
    assert plat.cold_starts == 1
    # the dead sandbox is gone: the retry pays a cold start again
    r1 = plat.invoke(handler, {}, pipeline=0, fragment=0, attempt=1)
    assert r1.error is None and r1.cold
    assert plat.cold_starts == 2
    # a sandbox that finished successfully does rejoin the pool
    r2 = plat.invoke(handler, {}, pipeline=0, fragment=1, attempt=0)
    assert not r2.cold
    assert plat.cold_starts == 2
