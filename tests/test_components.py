"""Component-level tests: MoE dispatch, SSD layer, optimizer, TPC-H data
generator invariants, exec operators, roofline/memtraffic analysis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.exec  # noqa: F401 (x64)
from repro.analysis.roofline import parse_collectives
from repro.data import generate_tpch
from repro.exec.operators import (hash64_jnp, hash64_np, make_direct_agg,
                                  make_pk_join_probe, make_sort_agg)
from repro.models.moe import load_balance_loss, moe_capacity, moe_ffn
from repro.models.ssm import causal_conv, ssd_chunked, ssd_decode_step
from repro.optim import AdamW, cosine_schedule
from repro.sql import ast
from repro.storage import ObjectStore


# -- MoE ------------------------------------------------------------------------

def test_moe_matches_per_token_reference():
    T, D, E, F, k = 48, 8, 4, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (T, D), jnp.float32)
    params = {
        "router": jax.random.normal(ks[1], (D, E), jnp.float32) * 0.5,
        "w1": jax.random.normal(ks[2], (E, D, F), jnp.float32) * 0.2,
        "w3": jax.random.normal(ks[3], (E, D, F), jnp.float32) * 0.2,
        "w2": jax.random.normal(ks[4], (E, F, D), jnp.float32) * 0.2,
    }
    y, probs = moe_ffn(x, params, top_k=k, capacity_factor=100.0)
    logits = x @ params["router"]
    p = jax.nn.softmax(logits, -1)
    tv, ti = jax.lax.top_k(p, k)
    tv = tv / tv.sum(-1, keepdims=True)
    want = np.zeros((T, D), np.float32)
    for t in range(T):
        for j in range(k):
            e = int(ti[t, j])
            h = jax.nn.silu(x[t] @ params["w1"][e]) * \
                (x[t] @ params["w3"][e])
            want[t] += float(tv[t, j]) * np.asarray(h @ params["w2"][e])
    np.testing.assert_allclose(np.asarray(y), want, atol=2e-5)


def test_moe_capacity_drops_tokens():
    T, D, E = 64, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jnp.abs(jax.random.normal(ks[0], (T, D), jnp.float32)) + 0.1
    params = {
        "router": jnp.zeros((D, E)).at[:, 0].set(10.0),  # all → expert 0
        # (x is strictly positive so expert 0 wins for every token)
        "w1": jnp.ones((E, D, 8)) * 0.1,
        "w3": jnp.ones((E, D, 8)) * 0.1,
        "w2": jnp.ones((E, 8, D)) * 0.1,
    }
    y, _ = moe_ffn(x, params, top_k=1, capacity_factor=0.25)
    # capacity = max(8, T·1·0.25/4 = 4) = 8 slots on expert 0 → ≥ T-8 rows 0
    zero_rows = int((np.abs(np.asarray(y)).sum(axis=1) == 0).sum())
    assert zero_rows >= T - 8


def test_load_balance_loss_uniform_is_one():
    probs = jnp.full((128, 8), 1.0 / 8)
    assert float(load_balance_loss(probs)) == pytest.approx(1.0)
    assert moe_capacity(1024, 8, 2, 1.0) == 256


# -- SSM ------------------------------------------------------------------------

def test_ssd_chunked_vs_sequential_decode():
    b, S, H, P, N = 1, 40, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H), jnp.float32))
    A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.2
    B = jax.random.normal(ks[3], (b, S, N), jnp.float32) * 0.3
    C = jax.random.normal(ks[4], (b, S, N), jnp.float32) * 0.3
    y_chunk = ssd_chunked(x, dt, A_log, B, C, chunk=8)
    state = jnp.zeros((b, H, N, P))
    ys = []
    for t in range(S):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], A_log,
                                   B[:, t], C[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4)


def test_causal_conv_streaming_matches_full():
    b, S, D, K = 2, 20, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = jax.random.normal(ks[0], (b, S, D), jnp.float32)
    w = jax.random.normal(ks[1], (K, D), jnp.float32)
    full, _ = causal_conv(x, w)
    state = jnp.zeros((b, K - 1, D))
    outs = []
    for t in range(S):
        y, state = causal_conv(x[:, t:t + 1], w, state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-5)


# -- optimizer -------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_grad_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    huge = {"w": jnp.full(3, 1e9)}
    _, _, gnorm = opt.update(huge, state, params)
    assert float(gnorm) > 1e8  # reported pre-clip norm


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(110)) == pytest.approx(0.0, abs=1e-6)


# -- data generator ---------------------------------------------------------------

def test_tpch_invariants():
    store = ObjectStore(tier="local")
    cat = generate_tpch(store, sf=0.01, n_parts=3)
    from repro.storage import InputHandler
    ih = InputHandler(store)
    orders = {}
    for f in cat.table("orders").files:
        cols, _, _ = ih.read_table(f, ["o_orderkey", "o_orderdate"])
        for k, v in cols.items():
            orders.setdefault(k, []).append(v)
    okeys = np.concatenate(orders["o_orderkey"])
    assert len(np.unique(okeys)) == len(okeys)          # PK uniqueness
    li = {}
    for f in cat.table("lineitem").files:
        cols, _, _ = ih.read_table(
            f, ["l_orderkey", "l_shipdate", "l_receiptdate",
                "l_extendedprice", "l_quantity"])
        for k, v in cols.items():
            li.setdefault(k, []).append(v)
    ship = np.concatenate(li["l_shipdate"])
    rec = np.concatenate(li["l_receiptdate"])
    assert (rec > ship).all()                           # receipt after ship
    assert set(np.concatenate(li["l_orderkey"])) <= set(okeys)  # FK
    qty = np.concatenate(li["l_quantity"])
    assert qty.min() >= 1 and qty.max() <= 50
    # deterministic regeneration (idempotent partition gen)
    store2 = ObjectStore(tier="local")
    generate_tpch(store2, sf=0.01, n_parts=3)
    a = store.get(cat.table("lineitem").files[0]).data
    b = store2.get(cat.table("lineitem").files[0]).data
    assert a == b


# -- exec operators (property) ------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=1, max_size=200),
       st.integers(0, 100))
def test_direct_agg_matches_numpy(keys, pad):
    keys = np.asarray(keys, np.int64)
    vals = (keys * 3.5 - 1.0).astype(np.float64)
    n = len(keys)
    cap = n + pad
    cols = {"k": jnp.asarray(np.pad(keys, (0, pad))),
            "v": jnp.asarray(np.pad(vals, (0, pad)))}
    mask = jnp.asarray(np.arange(cap) < n)
    op, K = make_direct_agg(["k"], [6], [("s", "sum", ast.Col("v")),
                                         ("c", "count", None)])
    out, m = op(cols, mask)
    want = np.bincount(keys, weights=vals, minlength=6)
    counts = np.bincount(keys, minlength=6)
    np.testing.assert_allclose(np.asarray(out["s"]), want, atol=1e-9)
    np.testing.assert_allclose(np.asarray(out["c"]), counts)
    assert np.array_equal(np.asarray(m), counts > 0)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=1, max_size=150))
def test_sort_agg_matches_numpy(keys):
    keys = np.asarray(keys, np.int64)
    vals = np.arange(len(keys), dtype=np.float64)
    cols = {"k": jnp.asarray(keys), "v": jnp.asarray(vals)}
    mask = jnp.ones(len(keys), bool)
    op = make_sort_agg(["k"], [("s", "sum", ast.Col("v"))])
    out, m = op(cols, mask)
    got_k = np.asarray(out["k"])[np.asarray(m)]
    got_s = np.asarray(out["s"])[np.asarray(m)]
    uniq = np.unique(keys)
    want = {k: vals[keys == k].sum() for k in uniq}
    assert np.array_equal(np.sort(got_k), uniq)
    for k, s in zip(got_k, got_s):
        assert s == pytest.approx(want[k])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**62), st.integers(0, 2**62))
def test_hash64_np_jnp_agree(a, b):
    arr = np.asarray([a, b, a ^ b], np.int64)
    assert np.array_equal(hash64_np(arr),
                          np.asarray(hash64_jnp(jnp.asarray(arr))))


def test_pk_join_probe_nulls_and_misses():
    probe = {"fk": jnp.asarray([1, 2, 3, 99], np.int64),
             "x": jnp.arange(4.0)}
    build = {"pk": jnp.asarray([2, 1, 50, 0], np.int64),
             "y": jnp.asarray([20.0, 10.0, 500.0, 0.0])}
    op = make_pk_join_probe("fk", "pk", ["y"])
    out, hit = op(probe, jnp.ones(4, bool), build,
                  jnp.asarray([True, True, True, False]))
    assert np.array_equal(np.asarray(hit), [True, True, False, False])
    assert np.asarray(out["y"])[0] == 10.0
    assert np.asarray(out["y"])[1] == 20.0


# -- analysis ---------------------------------------------------------------------

def test_collective_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={}
  %ar = (f32[8,8]{1,0}, f32[4]{0}) all-reduce(%a, %b)
  %rs = f32[2,2]{1,0} reduce-scatter(%c)
  %cp-start = bf16[4,4] collective-permute-start(%d)
  %other = f32[7]{0} add(%e, %f)
"""
    st_ = parse_collectives(hlo)
    assert st_.count_by_kind["all-gather"] == 1
    assert st_.bytes_by_kind["all-gather"] == 16 * 1024 * 2
    assert st_.bytes_by_kind["all-reduce"] == 8 * 8 * 4 + 4 * 4
    assert st_.count_by_kind["collective-permute"] == 1


def test_memtraffic_residency():
    from repro.analysis.memtraffic import analyze_memory
    from repro.configs import get_config
    from repro.models.config import SHAPES
    # llama3-405b serve at 32k decode must fit 16 GiB/chip on 256 chips
    m = analyze_memory(get_config("llama3-405b"), SHAPES["decode_32k"],
                       n_devices=256, dp=16, tp=16, kind="decode")
    assert m.fits_hbm, m.residency_bytes / 2**30
    # and clearly cannot fit on a single chip
    m1 = analyze_memory(get_config("llama3-405b"), SHAPES["decode_32k"],
                        n_devices=1, dp=1, tp=1, kind="decode")
    assert not m1.fits_hbm
