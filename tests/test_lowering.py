"""Kernel dispatch layer: pattern match, kernel/jnp parity, clean fallback."""

import numpy as np
import pytest

from repro.api import connect
from repro.core import CoordinatorConfig, FaasPlatform, QueryCoordinator
from repro.exec import lower
from repro.exec.batch import from_numpy
from repro.exec.fragment import _build, fn_cache_stats
from repro.sql import oracle
from repro.sql.logical import Binder
from repro.sql.parser import parse
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.sql.rules import optimize

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=250_000, broadcast_threshold_bytes=150_000,
    exchange_partitions=3), use_result_cache=False)


def _plan(store, catalog, sql, cfg=CFG):
    coord = QueryCoordinator(store, catalog,
                             platform=FaasPlatform(seed=1), config=cfg)
    return coord.plan_sql(sql)


def _scan_pipeline(plan):
    return next(p for p in plan.pipelines.values() if p.scan_units)


def _oracle(catalog, tables, sql):
    lqp, _ = Binder(catalog).bind(parse(sql))
    return oracle.run(optimize(lqp), tables)


# -- pattern matching ---------------------------------------------------------

def test_q6_matches_filter_agg(tpch_store):
    store, catalog = tpch_store
    p = _scan_pipeline(_plan(store, catalog, QUERIES["q6"]))
    assert p.kernel == "filter_agg"
    assert lower.match_kernel(p.op) == "filter_agg"


def test_q1_matches_groupby_onehot(tpch_store):
    store, catalog = tpch_store
    p = _scan_pipeline(_plan(store, catalog, QUERIES["q1"]))
    assert p.kernel == "groupby_onehot"


def test_join_fragments_do_not_match(tpch_store):
    store, catalog = tpch_store
    plan = _plan(store, catalog, QUERIES["q12"])
    assert all(p.kernel is None for p in plan.pipelines.values())


def test_grouped_min_does_not_match(tpch_store):
    store, catalog = tpch_store
    sql = ("select l_returnflag, min(l_quantity) as mq from lineitem "
           "group by l_returnflag")
    p = _scan_pipeline(_plan(store, catalog, sql))
    assert p.kernel is None          # one-hot matmul cannot min/max
    assert lower.lower_fragment(p.op) is None


def test_disabled_scope_skips_annotation_and_lowering(tpch_store):
    store, catalog = tpch_store
    with lower.disabled():
        p = _scan_pipeline(_plan(store, catalog, QUERIES["q6"]))
        assert p.kernel is None
    assert lower.enabled()


# -- block-level parity across capacity buckets -------------------------------

@pytest.mark.parametrize("qname,n_rows", [
    ("q6", 900), ("q6", 3000), ("q6", 12000),     # caps 1024/4096/16384
    ("q1", 900), ("q1", 3000), ("q1", 12000),
])
def test_lowered_matches_generic_per_capacity(qname, n_rows, tpch_store,
                                              tpch_tables):
    store, catalog = tpch_store
    p = _scan_pipeline(_plan(store, catalog, QUERIES[qname]))
    lowered = lower.lower_fragment(p.op)
    assert lowered is not None and lowered.kernel == p.kernel
    leaves: list = []
    generic = _build(p.op, leaves)
    (leaf_id, leaf_op), = lowered.leaves
    assert leaves[0][1] is leaf_op

    li = tpch_tables["lineitem"]
    cols = {c: li[c][:n_rows] for c in leaf_op["columns"]}
    blk = from_numpy(cols)
    blocks = {leaf_id: (blk.columns, blk.mask)}

    k_cols, k_mask = lowered.fn(blocks)
    g_cols, g_mask = generic(blocks)
    assert set(k_cols) == set(g_cols)
    np.testing.assert_array_equal(np.asarray(k_mask), np.asarray(g_mask))
    for name in g_cols:
        np.testing.assert_allclose(
            np.asarray(k_cols[name], np.float64),
            np.asarray(g_cols[name], np.float64),
            rtol=1e-12, atol=1e-12, err_msg=f"{qname}.{name}@{n_rows}")


# -- end-to-end engine parity -------------------------------------------------

@pytest.mark.parametrize("qname", ["q1", "q6"])
def test_engine_kernel_path_matches_jnp_and_oracle(qname, tpch_store,
                                                   tpch_tables):
    store, catalog = tpch_store
    with connect(store, catalog, config=CFG) as session:
        fused = session.sql(QUERIES[qname])
        scan = next(p for p in fused.stats.pipelines
                    if p.kernel)
        assert scan.kernel_fragments == scan.n_fragments
        got_fused = fused.fetch(store)
        with lower.disabled():
            got_jnp = session.sql(QUERIES[qname]).fetch(store)
    want = _oracle(catalog, tpch_tables, QUERIES[qname])
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got_fused[k], np.float64),
            np.asarray(want[k], np.float64), rtol=1e-9, atol=1e-9,
            err_msg=f"{qname}.{k} (fused vs oracle)")
        np.testing.assert_allclose(
            np.asarray(got_fused[k], np.float64),
            np.asarray(got_jnp[k], np.float64), rtol=1e-12, atol=1e-12,
            err_msg=f"{qname}.{k} (fused vs jnp)")


def test_unmatched_plan_falls_back_cleanly(tpch_store, tpch_tables):
    store, catalog = tpch_store
    with connect(store, catalog, config=CFG) as session:
        res = session.sql(QUERIES["q12"])
        assert all(p.kernel_fragments == 0 for p in res.stats.pipelines)
        got = res.fetch(store)
    want = _oracle(catalog, tpch_tables, QUERIES["q12"])
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k], np.float64),
                                   np.asarray(want[k], np.float64))


def test_compiled_program_cache_shared_across_queries(tpch_store):
    store, catalog = tpch_store
    with connect(store, catalog, config=CFG) as session:
        session.sql(QUERIES["q6"])
        before = fn_cache_stats()
        session.sql(QUERIES["q6"])          # same plan → cached programs
        after = fn_cache_stats()
    assert after["hits"] > before["hits"]
    assert after["misses"] == before["misses"]
    assert after["entries"] == before["entries"]
