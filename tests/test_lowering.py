"""Kernel dispatch layer: pattern match, kernel/jnp parity, clean fallback."""

import numpy as np
import pytest

from repro.api import connect
from repro.core import CoordinatorConfig, FaasPlatform, QueryCoordinator
from repro.exec import lower
from repro.exec.batch import from_numpy
from repro.exec.fragment import _build, fn_cache_stats
from repro.sql import oracle
from repro.sql.logical import Binder
from repro.sql.parser import parse
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.sql.rules import optimize

CFG = CoordinatorConfig(planner=PlannerConfig(
    bytes_per_worker=250_000, broadcast_threshold_bytes=150_000,
    exchange_partitions=3), use_result_cache=False)


def _plan(store, catalog, sql, cfg=CFG):
    coord = QueryCoordinator(store, catalog,
                             platform=FaasPlatform(seed=1), config=cfg)
    return coord.plan_sql(sql)


def _scan_pipeline(plan):
    return next(p for p in plan.pipelines.values() if p.scan_units)


def _oracle(catalog, tables, sql):
    lqp, _ = Binder(catalog).bind(parse(sql))
    return oracle.run(optimize(lqp), tables)


# -- pattern matching ---------------------------------------------------------

def test_q6_matches_filter_agg(tpch_store):
    store, catalog = tpch_store
    p = _scan_pipeline(_plan(store, catalog, QUERIES["q6"]))
    assert p.kernel == "filter_agg"
    assert lower.match_kernel(p.op) == "filter_agg"


def test_q1_matches_groupby_onehot(tpch_store):
    store, catalog = tpch_store
    p = _scan_pipeline(_plan(store, catalog, QUERIES["q1"]))
    assert p.kernel == "groupby_onehot"


def test_q12_matches_join_probe_agg(tpch_store):
    store, catalog = tpch_store
    plan = _plan(store, catalog, QUERIES["q12"])
    p = next(p for p in plan.pipelines.values()
             if p.kernel == "join_probe_agg")
    assert p.kernel_miss_reason is None
    assert p.kernel_roofline["resident_rows"] >= 128


def test_grouped_minmax_matches_segmented_kernel(tpch_store):
    store, catalog = tpch_store
    sql = ("select l_returnflag, min(l_quantity) as mq, "
           "max(l_tax) as mt from lineitem group by l_returnflag")
    p = _scan_pipeline(_plan(store, catalog, sql))
    assert p.kernel == "segmented_minmax"
    assert lower.lower_fragment(p.op) is not None


def test_groupby_nondict_matches_sort_agg(tpch_store):
    store, catalog = tpch_store
    sql = ("select l_orderkey, sum(l_quantity) as s, count(*) as c "
           "from lineitem group by l_orderkey")
    # On interpreted (non-TPU) backends the compute-bound bitonic sort
    # kernel loses to jnp — dispatch declines with a named reason.
    p = _scan_pipeline(_plan(store, catalog, sql))
    assert p.kernel is None
    assert p.kernel_miss_reason == "interpret_cost"
    with lower.interpret_gate_disabled():
        p = _scan_pipeline(_plan(store, catalog, sql))
        assert p.kernel == "sort_agg"    # no dict sizes → sort strategy


def test_q3_final_matches_topk(tpch_store):
    store, catalog = tpch_store
    plan = _plan(store, catalog, QUERIES["q3"])
    p = next(p for p in plan.pipelines.values() if p.op["t"] == "final")
    assert p.kernel is None
    assert p.kernel_miss_reason == "interpret_cost"
    with lower.interpret_gate_disabled():
        plan = _plan(store, catalog, QUERIES["q3"])
        p = next(p for p in plan.pipelines.values()
                 if p.op["t"] == "final")
        assert p.kernel == "topk"
        m, miss = lower.match_fragment_ex(p.op)
    assert miss is None and m.limit == 10
    assert m.sort_keys and m.sort_keys[0][1]     # revenue desc


def test_miss_reasons_name_the_blocker(tpch_store):
    store, catalog = tpch_store
    plan = _plan(store, catalog,
                 "select l_orderkey, l_quantity from lineitem "
                 "where l_quantity < 3")
    reasons = [p.kernel_miss_reason for p in plan.pipelines.values()]
    assert all(p.kernel is None for p in plan.pipelines.values())
    assert any("no fusible root" in r for r in reasons if r)
    assert lower.kernel_miss_reason(
        {"t": "final", "sort_keys": [], "limit": None,
         "child": {"t": "scan_exchange"}}) == \
        "final lacks ORDER BY + LIMIT (no top-k)"


def test_disabled_scope_skips_annotation_and_lowering(tpch_store):
    store, catalog = tpch_store
    with lower.disabled():
        p = _scan_pipeline(_plan(store, catalog, QUERIES["q6"]))
        assert p.kernel is None
    assert lower.enabled()


# -- block-level parity across capacity buckets -------------------------------

_SQLS = {
    "q6": QUERIES["q6"],                           # filter_agg
    "q1": QUERIES["q1"],                           # groupby_onehot
    "minmax": ("select l_returnflag, min(l_quantity) as mq, "
               "max(l_tax) as mt from lineitem "
               "where l_quantity < 30 group by l_returnflag"),
    "sortagg": ("select l_orderkey, sum(l_quantity) as s, "
                "count(*) as c, min(l_extendedprice) as m "
                "from lineitem group by l_orderkey"),
}


@pytest.mark.parametrize("qname,n_rows", [
    ("q6", 900), ("q6", 3000), ("q6", 12000),     # caps 1024/4096/16384
    ("q1", 900), ("q1", 3000), ("q1", 12000),
    ("minmax", 900), ("minmax", 3000), ("minmax", 12000),
    ("sortagg", 900), ("sortagg", 3000), ("sortagg", 12000),
])
def test_lowered_matches_generic_per_capacity(qname, n_rows, tpch_store,
                                              tpch_tables):
    store, catalog = tpch_store
    with lower.interpret_gate_disabled():
        p = _scan_pipeline(_plan(store, catalog, _SQLS[qname]))
        lowered = lower.lower_fragment(p.op)
    assert lowered is not None and lowered.kernel == p.kernel
    leaves: list = []
    generic = _build(p.op, leaves)
    (leaf_id, leaf_op), = lowered.leaves
    assert leaves[0][1] is leaf_op

    li = tpch_tables["lineitem"]
    cols = {c: li[c][:n_rows] for c in leaf_op["columns"]}
    blk = from_numpy(cols)
    blocks = {leaf_id: (blk.columns, blk.mask)}

    k_cols, k_mask = lowered.fn(blocks)
    g_cols, g_mask = generic(blocks)
    assert set(k_cols) == set(g_cols)
    np.testing.assert_array_equal(np.asarray(k_mask), np.asarray(g_mask))
    for name in g_cols:
        np.testing.assert_allclose(
            np.asarray(k_cols[name], np.float64),
            np.asarray(g_cols[name], np.float64),
            rtol=1e-12, atol=1e-12, err_msg=f"{qname}.{name}@{n_rows}")


@pytest.mark.parametrize("n_probe", [900, 3000, 12000])
def test_join_probe_block_parity(n_probe, tpch_store, tpch_tables):
    """Fused join-probe+agg vs the generic jnp join chain, two leaves,
    swept across probe capacity buckets."""
    store, catalog = tpch_store
    plan = _plan(store, catalog, QUERIES["q12"])
    p = next(p for p in plan.pipelines.values()
             if p.kernel == "join_probe_agg")
    lowered = lower.lower_fragment(p.op)
    g_leaves: list = []
    generic = _build(p.op, g_leaves)

    jop = p.op
    while jop["t"] != "join":
        jop = jop["child"] if "child" in jop else jop["probe"]
    li, orders = tpch_tables["lineitem"], tpch_tables["orders"]
    build_names = [jop["build_key"]] + [c for c in jop["payload"]
                                        if c in orders]

    def leaf_block(leaf_op):
        if leaf_op["t"] == "scan_table":
            cols = {c: li[c][:n_probe] for c in leaf_op["columns"]}
        else:                       # build-side exchange scan
            cols = {c: orders[c][:1500] for c in build_names}
        return from_numpy(cols)

    k_blocks, g_blocks = {}, {}
    for leaf_id, leaf_op in lowered.leaves:
        blk = leaf_block(leaf_op)
        k_blocks[leaf_id] = (blk.columns, blk.mask)
        gid = next(i for i, op in g_leaves if op is leaf_op)
        g_blocks[gid] = (blk.columns, blk.mask)

    k_cols, k_mask = lowered.fn(k_blocks)
    g_cols, g_mask = generic(g_blocks)
    assert set(k_cols) == set(g_cols)
    np.testing.assert_array_equal(np.asarray(k_mask), np.asarray(g_mask))
    for name in g_cols:
        np.testing.assert_allclose(
            np.asarray(k_cols[name], np.float64),
            np.asarray(g_cols[name], np.float64),
            rtol=1e-12, atol=1e-12, err_msg=f"q12.{name}@{n_probe}")


@pytest.mark.parametrize("n_rows", [900, 3000, 12000])
def test_topk_block_parity(n_rows, tpch_store):
    """Fused top-k vs generic passthrough + host sort/limit: after the
    coordinator's final-stage host ops both paths must agree exactly."""
    store, catalog = tpch_store
    with lower.interpret_gate_disabled():
        plan = _plan(store, catalog, QUERIES["q3"])
        p = next(q for q in plan.pipelines.values()
                 if q.op["t"] == "final")
        assert p.kernel == "topk"
        m, _ = lower.match_fragment_ex(p.op)
        lowered = lower.lower_fragment(p.op)
    g_leaves: list = []
    generic = _build(p.op["child"], g_leaves)

    rng = np.random.default_rng(7)
    cols = {name: rng.integers(0, 50, n_rows).astype(np.float64)
            if desc else rng.integers(0, 50, n_rows)
            for name, desc in m.sort_keys}
    cols["carry"] = rng.integers(0, 10_000, n_rows)
    blk = from_numpy(cols)
    blocks = {"in0": (blk.columns, blk.mask)}

    def host_final(out_cols, out_mask):
        keep = np.asarray(out_mask)
        named = {c: np.asarray(v)[keep] for c, v in out_cols.items()}
        order = np.lexsort([-named[k] if desc else named[k]
                            for k, desc in reversed(m.sort_keys)])
        return {c: v[order][:m.limit] for c, v in named.items()}

    k_out = host_final(*lowered.fn(blocks))
    g_out = host_final(*generic(blocks))
    assert set(k_out) == set(g_out)
    for name in g_out:
        np.testing.assert_array_equal(k_out[name], g_out[name],
                                      err_msg=f"topk.{name}@{n_rows}")


# -- roofline-driven tiling ---------------------------------------------------

def test_roofline_tilings_are_pow2_and_fit_budget():
    from repro.analysis import roofline
    budget = roofline.vmem_budget_bytes()
    grid = [
        roofline.filter_agg_tiling(n_cols=6, n_aggs=2),
        roofline.groupby_tiling("groupby_onehot", n_cols=8, n_aggs=4,
                                n_groups=12),
        roofline.groupby_tiling("segmented_minmax", n_cols=4, n_aggs=2,
                                n_groups=6),
        roofline.join_probe_tiling(n_cols=7, n_payload=2, n_aggs=3,
                                   n_groups=14),
    ]
    resident = [
        roofline.resident_sort_tiling("sort_agg", n_arrays=6),
        roofline.resident_sort_tiling("topk", n_arrays=5),
    ]
    for t in grid:
        assert 128 <= t.block_rows <= 8192, t
    for t in resident:
        # fully-resident kernels: capacity cap IS the block
        assert t.block_rows == t.resident_rows, t
        assert t.vmem_bytes <= budget, t
    for t in grid + resident:
        assert t.block_rows & (t.block_rows - 1) == 0, t
        assert t.resident_rows & (t.resident_rows - 1) == 0, t
        assert t.vmem_bytes <= 2 * budget, t
        assert t.dominant in ("compute", "memory")
        assert t.key == (t.kernel, t.block_rows, t.resident_rows)
    # deterministic: same shape → identical tiling (cache keys depend on it)
    again = roofline.filter_agg_tiling(n_cols=6, n_aggs=2)
    assert again == grid[0]
    # the one-hot group cap is the roofline's, not a hand constant
    assert lower.MAX_KERNEL_GROUPS == roofline.onehot_group_capacity()


def test_tiling_joins_compiled_cache_key(tpch_store):
    store, catalog = tpch_store
    p = _scan_pipeline(_plan(store, catalog, QUERIES["q6"]))
    kernel, tkey, _ = lower.dispatch_signature(p.op)
    lowered = lower.lower_fragment(p.op)
    assert kernel == "filter_agg" == lowered.kernel
    assert tkey == lowered.tiling.key
    assert p.kernel_roofline["block_rows"] == lowered.tiling.block_rows


# -- end-to-end engine parity -------------------------------------------------

@pytest.mark.parametrize("qname", ["q1", "q6", "q3"])
def test_engine_kernel_path_matches_jnp_and_oracle(qname, tpch_store,
                                                   tpch_tables):
    store, catalog = tpch_store
    # gate bypass: q3's only fused op is the top-k final, which the
    # interpret-cost gate declines on CPU — parity still needs to run it
    with connect(store, catalog, config=CFG) as session, \
            lower.interpret_gate_disabled():
        fused = session.sql(QUERIES[qname])
        scan = next(p for p in fused.stats.pipelines
                    if p.kernel)
        assert scan.kernel_fragments == scan.n_fragments
        got_fused = fused.fetch(store)
        with lower.disabled():
            got_jnp = session.sql(QUERIES[qname]).fetch(store)
    want = _oracle(catalog, tpch_tables, QUERIES[qname])
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got_fused[k], np.float64),
            np.asarray(want[k], np.float64), rtol=1e-9, atol=1e-9,
            err_msg=f"{qname}.{k} (fused vs oracle)")
        np.testing.assert_allclose(
            np.asarray(got_fused[k], np.float64),
            np.asarray(got_jnp[k], np.float64), rtol=1e-12, atol=1e-12,
            err_msg=f"{qname}.{k} (fused vs jnp)")


@pytest.mark.parametrize("qname", ["q12", "q14", "q19"])
def test_join_queries_run_on_fused_kernels(qname, tpch_store, tpch_tables):
    """TPC-H joins beyond Q1/Q6 now execute fused fragments, and the
    fused path agrees with the jnp fallback and the oracle."""
    store, catalog = tpch_store
    with connect(store, catalog, config=CFG) as session:
        res = session.sql(QUERIES[qname])
        assert sum(p.kernel_fragments for p in res.stats.pipelines) > 0
        got = res.fetch(store)
        with lower.disabled():
            got_jnp = session.sql(QUERIES[qname]).fetch(store)
    want = _oracle(catalog, tpch_tables, QUERIES[qname])
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k], np.float64),
            np.asarray(want[k], np.float64), rtol=1e-9, atol=1e-9,
            err_msg=f"{qname}.{k} (fused vs oracle)")
        np.testing.assert_allclose(
            np.asarray(got[k], np.float64),
            np.asarray(got_jnp[k], np.float64), rtol=1e-9, atol=1e-9,
            err_msg=f"{qname}.{k} (fused vs jnp)")


def test_unmatched_plan_falls_back_cleanly(tpch_store, tpch_tables):
    sql = ("select l_orderkey, l_quantity from lineitem "
           "where l_quantity < 3")
    store, catalog = tpch_store
    with connect(store, catalog, config=CFG) as session:
        res = session.sql(sql)
        assert all(p.kernel_fragments == 0 for p in res.stats.pipelines)
        got = res.fetch(store)
    want = _oracle(catalog, tpch_tables, sql)
    order_g = np.lexsort((got["l_quantity"], got["l_orderkey"]))
    order_w = np.lexsort((want["l_quantity"], want["l_orderkey"]))
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k], np.float64)[order_g],
            np.asarray(want[k], np.float64)[order_w])


def test_compiled_program_cache_shared_across_queries(tpch_store):
    store, catalog = tpch_store
    with connect(store, catalog, config=CFG) as session:
        session.sql(QUERIES["q6"])
        before = fn_cache_stats()
        session.sql(QUERIES["q6"])          # same plan → cached programs
        after = fn_cache_stats()
    assert after["hits"] > before["hits"]
    assert after["misses"] == before["misses"]
    assert after["entries"] == before["entries"]
