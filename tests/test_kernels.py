"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernel body executes on CPU; Mosaic compiles the same code on
TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.filter_agg import filter_agg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.groupby_onehot import groupby_onehot
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("S,hd,heads,kv_heads", [
    (128, 64, 4, 2), (256, 128, 2, 2), (200, 64, 8, 2), (64, 64, 4, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(S, hd, heads, kv_heads, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (heads, S, hd), dtype)
    k = jax.random.normal(ks[1], (kv_heads, S, hd), dtype)
    v = jax.random.normal(ks[2], (kv_heads, S, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_windowed(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 192, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 192, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 192, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,H,P,N,chunk", [
    (64, 2, 16, 8, 16), (96, 3, 32, 16, 32), (128, 1, 64, 128, 64),
])
def test_ssd_scan_sweep(S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    b = 2
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H), jnp.float32))
    A_log = jax.random.normal(ks[2], (H,), jnp.float32) * 0.3
    B = jax.random.normal(ks[3], (b, S, N), jnp.float32) * 0.5
    C = jax.random.normal(ks[0], (b, S, N), jnp.float32) * 0.5
    out = ssd_scan(x, dt, A_log, B, C, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, A_log, B, C)
    err = np.abs(np.asarray(out) - np.asarray(want)).max()
    scale = np.abs(np.asarray(want)).max() + 1e-9
    assert err / scale < 5e-5


def test_ssd_matches_model_layer():
    """Kernel agrees with the model's jnp ssd_chunked implementation."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    b, S, H, P, N = 1, 64, 2, 16, 8
    x = jax.random.normal(ks[0], (b, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H), jnp.float32))
    A_log = jnp.zeros((H,), jnp.float32)
    B = jax.random.normal(ks[2], (b, S, N), jnp.float32)
    C = jax.random.normal(ks[3], (b, S, N), jnp.float32)
    a = ssd_scan(x, dt, A_log, B, C, chunk=32, interpret=True)
    m = ssd_chunked(x, dt, A_log, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(m), atol=2e-4)


@pytest.mark.parametrize("n", [100, 4096, 10_000])
@pytest.mark.parametrize("block", [512, 2048])
def test_filter_agg_sweep(n, block):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    ship = jax.random.randint(ks[0], (n,), 8000, 10000)
    disc = jax.random.randint(ks[1], (n,), 0, 11).astype(jnp.float32) / 100
    qty = jax.random.randint(ks[2], (n,), 1, 51).astype(jnp.float32)
    price = jax.random.uniform(ks[3], (n,), jnp.float32) * 1e4
    out = filter_agg(ship, disc, qty, price, date_lo=8500, date_hi=9500,
                     disc_lo=0.05, disc_hi=0.07, qty_hi=24.0,
                     block=block, interpret=True)
    want = ref.filter_agg_ref(ship, disc, qty, price, date_lo=8500,
                              date_hi=9500, disc_lo=0.05, disc_hi=0.07,
                              qty_hi=24.0)
    np.testing.assert_allclose(float(out[0]), float(want), rtol=1e-4)


@pytest.mark.parametrize("n,K,A", [(100, 6, 2), (5000, 6, 4),
                                   (3000, 120, 1)])
def test_groupby_onehot_sweep(n, K, A):
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    gid = jax.random.randint(ks[0], (n,), 0, K)
    vals = jax.random.normal(ks[1], (n, A), jnp.float32)
    out = groupby_onehot(gid, vals, n_groups=K, block=512, interpret=True)
    want = ref.groupby_agg_ref(gid, vals, K)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


def test_groupby_counts_via_ones_column():
    gid = jnp.array([0, 1, 0, 2, 0], jnp.int32)
    vals = jnp.stack([jnp.arange(5.0, dtype=jnp.float32),
                      jnp.ones(5, jnp.float32)], axis=1)
    out = groupby_onehot(gid, vals, n_groups=3, block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 1]), [3, 1, 1])
