"""End-to-end system behaviour: the full Skyrise lifecycle — generate
TPC-H onto serverless object storage, process SQL through the serverless
coordinator/worker runtime under injected infrastructure faults, verify
results, costs, caching, and elastic scaling across scale factors."""

import numpy as np

from repro.core import (CoordinatorConfig, FaasPlatform, FaultPlan,
                        QueryCoordinator)
from repro.data import generate_tpch
from repro.sql import oracle
from repro.sql.logical import Binder
from repro.sql.parser import parse
from repro.sql.physical import PlannerConfig
from repro.sql.queries import QUERIES
from repro.sql.rules import optimize
from repro.storage import InputHandler, ObjectStore


def test_end_to_end_lifecycle():
    store = ObjectStore(tier="s3-standard", seed=11)
    catalog = generate_tpch(store, sf=0.02, n_parts=5, seed=3)
    cfg = CoordinatorConfig(planner=PlannerConfig(
        bytes_per_worker=400_000, broadcast_threshold_bytes=200_000,
        exchange_partitions=4))
    platform = FaasPlatform(
        seed=9, faults=FaultPlan(transient_error_prob=0.05,
                                 straggler_prob=0.1, seed=13))

    # oracle tables
    ih = InputHandler(store)
    tables = {}
    for name, meta in catalog.tables.items():
        parts = [ih.read_table(f)[0] for f in meta.files]
        tables[name] = {
            c.name: np.concatenate([p[c.name] for p in parts])
            for c in meta.schema}

    total_cost = 0.0
    for qname in ("q1", "q6", "q12"):
        coord = QueryCoordinator(store, catalog, platform=platform,
                                 config=cfg)
        res = coord.execute_sql(QUERIES[qname])
        got = res.fetch(store)
        plan, _ = Binder(catalog).bind(parse(QUERIES[qname]))
        want = oracle.run(optimize(plan), tables)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k], np.float64),
                np.asarray(want[k], np.float64), rtol=1e-9,
                err_msg=f"{qname}.{k}")
        assert res.stats.sim_latency_s > 0
        total_cost += res.stats.cost.total_cents
    assert total_cost > 0

    # second round: full cache hits, near-zero marginal cost
    rerun_cost = 0.0
    for qname in ("q1", "q6", "q12"):
        coord = QueryCoordinator(store, catalog, platform=platform,
                                 config=cfg)
        res = coord.execute_sql(QUERIES[qname])
        assert res.stats.cache_hits == len(res.stats.pipelines)
        rerun_cost += res.stats.cost.total_cents
    assert rerun_cost < total_cost / 20


def test_elasticity_worker_scaling():
    """Fig. 7's mechanism: worker fleets grow with input size while
    latency stays within an order of magnitude."""
    latencies = {}
    workers = {}
    for sf in (0.005, 0.02):
        store = ObjectStore(tier="s3-standard", seed=1)
        catalog = generate_tpch(store, sf=sf,
                                n_parts=max(1, int(sf * 400)), seed=0)
        cfg = CoordinatorConfig(planner=PlannerConfig(
            bytes_per_worker=150_000))
        coord = QueryCoordinator(store, catalog,
                                 platform=FaasPlatform(seed=2), config=cfg)
        res = coord.execute_sql(QUERIES["q6"])
        latencies[sf] = res.stats.sim_latency_s
        workers[sf] = res.stats.pipelines[0].n_fragments
    assert workers[0.02] > workers[0.005]
    assert latencies[0.02] < latencies[0.005] * 10
