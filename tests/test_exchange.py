"""Exchange subsystem (repro.exec.exchange):

* tentpole invariant — every TPC-H query returns identical rows under
  all three shuffle strategies (direct / combining / multilevel), with
  every join forced to repartition so the exchanges really run;
* strategy selection on ``CostModel.exchange_cost`` (request math,
  direct hysteresis at trivial scale, combining in the middle,
  multilevel at wide fan-out and under latency budgets);
* regression — at 16 producers × 16 partitions the multi-level exchange
  issues strictly fewer storage requests and lower cents than direct;
* straggler-aware LPT weights, the merge wave's partial-state combine,
  the Reoptimizer's barrier re-pick, and cross-query selectivity
  calibration through the KV tier.
"""

import numpy as np
import pytest

import repro.exec  # noqa: F401  (x64)
from repro.api import CoordinatorConfig, connect
from repro.core.adaptive import (Reoptimizer, _lpt_assignment,
                                 straggler_skew_weights)
from repro.core.cost import CostModel
from repro.data import generate_tpch
from repro.data.catalog import Catalog, TableMeta
from repro.exec import exchange
from repro.exec.operators import np_combine_partials
from repro.sql.calibration import (SelectivityCalibration, predicate_key,
                                   scan_filter_signature)
from repro.sql.physical import (ExecutionParams, Partitioning, Pipeline,
                                PlannerConfig)
from repro.sql.queries import QUERIES
from repro.storage import ColumnSpec, ObjectStore, write_pax

STRATEGIES = ("direct", "combining", "multilevel")


def _planner(strategy=None, **kw):
    base = dict(bytes_per_worker=100_000, broadcast_threshold_bytes=1,
                exchange_partitions=4, exchange_strategy=strategy)
    base.update(kw)
    return PlannerConfig(**base)


def _run(store, catalog, sql, *, planner, adaptive=False, quota=1000):
    cfg = CoordinatorConfig(planner=planner, use_result_cache=False,
                            adaptive=adaptive,
                            straggler_min_timeout_s=100.0)
    with connect(store, catalog, config=cfg, quota=quota) as session:
        res = session.submit(sql).result(timeout=300)
        cols = res.fetch(store)
    return cols, res.stats


def _sorted_rows(cols):
    keys = sorted(cols)
    arrs = [np.asarray(cols[k], np.float64) for k in keys]
    order = np.lexsort(arrs)
    return {k: a[order] for k, a in zip(keys, arrs)}


def _assert_same_rows(a, b, ctx=""):
    sa, sb = _sorted_rows(a), _sorted_rows(b)
    assert sorted(sa) == sorted(sb), ctx
    for k in sa:
        np.testing.assert_allclose(sa[k], sb[k], rtol=1e-9, atol=1e-9,
                                   err_msg=f"{ctx} :: {k}")


# -- tentpole: row parity across all strategies on every TPC-H query -----------

@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_strategy_parity_tpch(tpch_store, qname):
    store, catalog = tpch_store
    runs = {s: _run(store, catalog, QUERIES[qname],
                    planner=_planner(s))[0] for s in STRATEGIES}
    for s in ("combining", "multilevel"):
        _assert_same_rows(runs["direct"], runs[s], f"{qname} · {s}")


def test_adaptive_runs_keep_parity_under_forced_strategy(tpch_store):
    """The Reoptimizer never re-picks a forced strategy, and adaptive
    execution stays row-identical under each."""
    store, catalog = tpch_store
    base, _ = _run(store, catalog, QUERIES["q3"], planner=_planner())
    for s in STRATEGIES:
        cols, stats = _run(store, catalog, QUERIES["q3"],
                           planner=_planner(s), adaptive=True)
        _assert_same_rows(base, cols, f"adaptive · {s}")
        ran = [p.exchange_strategy for p in stats.pipelines
               if p.exchange_strategy]
        assert ran and all(x == s for x in ran), (s, ran)


# -- request math + strategy selection -----------------------------------------

def test_strategy_request_math():
    d = exchange.get_strategy("direct")
    c = exchange.get_strategy("combining")
    m = exchange.get_strategy("multilevel")
    assert d.written_objects(16, 16) == 256
    assert c.written_objects(16, 16) == 16
    assert exchange.merge_group_count(16) == 4
    assert m.written_objects(16, 16) == 16 + 4 * 16
    assert m.merge_workers(16) == 4
    # producer-side request estimates (what EXPLAIN ANALYZE compares)
    assert d.producer_requests(16, 16) == 256
    assert c.producer_requests(16, 16) == 16
    assert m.producer_requests(16, 16) == 16 + 3 * 16 + 4 * 16
    # consumers read O(√n·m) objects instead of O(n·m)
    assert m.consumer_requests(16, 16) < d.consumer_requests(16, 16)


def test_choose_exchange_strategy_regimes():
    cm = CostModel()
    tier = "s3-standard"
    tiny, _ = cm.choose_exchange_strategy(2, 2, 1e5, tier_for=tier)
    assert tiny.strategy == "direct"          # hysteresis keeps default
    mid, _ = cm.choose_exchange_strategy(16, 16, 5e6, tier_for=tier)
    assert mid.strategy == "combining"
    wide, costs = cm.choose_exchange_strategy(256, 16, 1e7, tier_for=tier)
    assert wide.strategy == "multilevel"
    assert costs["multilevel"].cents < costs["direct"].cents
    assert costs["multilevel"].requests < costs["direct"].requests


def test_choose_exchange_strategy_latency_budget():
    cm = CostModel()
    free, _ = cm.choose_exchange_strategy(1024, 32, 1e7,
                                          tier_for="s3-standard")
    budget, _ = cm.choose_exchange_strategy(1024, 32, 1e7,
                                            tier_for="s3-standard",
                                            latency_budget_s=1.0)
    assert budget.strategy == "multilevel"
    assert budget.makespan_s <= 1.0
    assert free.cents <= budget.cents + 1e-12


def test_exchange_cost_monotone_in_bytes():
    cm = CostModel()
    costs = [cm.exchange_cost(16, 16, b, strategy="combining").cents
             for b in (0, 1e6, 1e8, 1e9)]
    assert costs == sorted(costs)


# -- 16×16 wide-fanout regression ----------------------------------------------

@pytest.fixture(scope="module")
def wide_runs():
    runs = {}
    for strategy in STRATEGIES:
        store = ObjectStore(tier="local", seed=0)
        catalog = generate_tpch(store, sf=0.02, n_parts=16, seed=0)
        planner = _planner(strategy, bytes_per_worker=1,
                           exchange_partitions=16, max_workers=16)
        sql = ("select o_orderpriority, count(*) as n, "
               "sum(l_extendedprice) as rev from lineitem, orders "
               "where l_orderkey = o_orderkey group by o_orderpriority "
               "order by o_orderpriority")
        cols, stats = _run(store, catalog, sql, planner=planner)
        runs[strategy] = (cols, stats, store.stats.get_requests
                         + store.stats.put_requests)
    return runs


def test_multilevel_fewer_requests_than_direct_at_16x16(wide_runs):
    _, d_stats, d_reqs = wide_runs["direct"]
    _, m_stats, m_reqs = wide_runs["multilevel"]
    # 16 producers × 16 partitions per exchange side: the merge wave
    # collapses the request grid
    assert any(p.n_fragments >= 16 for p in d_stats.pipelines)
    assert any(p.merge_fragments == 4 for p in m_stats.pipelines)
    assert m_reqs < d_reqs, (m_reqs, d_reqs)
    assert m_stats.cost.total_cents < d_stats.cost.total_cents
    # per-exchange producer-side observation beats direct's too
    d_x = sum(p.exchange_requests for p in d_stats.pipelines)
    m_x = sum(p.exchange_requests for p in m_stats.pipelines)
    assert m_x < d_x


def test_combining_fewer_requests_than_direct_at_16x16(wide_runs):
    c_reqs = wide_runs["combining"][2]
    d_reqs = wide_runs["direct"][2]
    assert c_reqs < d_reqs, (c_reqs, d_reqs)


def test_wide_fanout_row_parity(wide_runs):
    for s in ("combining", "multilevel"):
        _assert_same_rows(wide_runs["direct"][0], wide_runs[s][0],
                          f"16x16 · {s}")


def test_explain_analyze_reports_strategy_and_requests():
    store = ObjectStore(tier="local", seed=0)
    catalog = generate_tpch(store, sf=0.01, n_parts=4, seed=0)
    cfg = CoordinatorConfig(planner=_planner("multilevel"),
                            use_result_cache=False)
    with connect(store, catalog, config=cfg) as session:
        text = session.submit(QUERIES["q3"]).explain_analyze(timeout=300)
        st = session.stats()
    assert "exchange: multilevel" in text
    assert "reqs est≈" in text and "actual=" in text
    assert "merge wave ×" in text
    assert st["exchange_strategies"].get("multilevel", 0) > 0
    # plain EXPLAIN names the strategy on the dest line
    with connect(store, catalog, config=cfg) as session:
        assert "·multilevel" in session.explain(QUERIES["q3"])


# -- merge-wave combine ---------------------------------------------------------

def test_np_combine_partials_folds_states():
    cols = {"g": np.array([1, 0, 1, 0, 2], np.int64),
            "s": np.array([1.0, 2.0, 3.0, 4.0, 5.0]),
            "mn": np.array([5.0, 1.0, 2.0, 0.5, 9.0]),
            "mx": np.array([5.0, 1.0, 2.0, 0.5, 9.0])}
    out = np_combine_partials(cols, ["g"],
                              [("s", "sum"), ("mn", "min"), ("mx", "max")])
    assert out["g"].tolist() == [0, 1, 2]
    assert out["s"].tolist() == [6.0, 4.0, 5.0]
    assert out["mn"].tolist() == [0.5, 2.0, 9.0]
    assert out["mx"].tolist() == [1.0, 5.0, 9.0]
    # empty input is the identity
    empty = {k: v[:0] for k, v in cols.items()}
    assert np_combine_partials(empty, ["g"], [("s", "sum")])["s"].size == 0


def test_combining_write_matches_direct_partitions():
    """The combined per-producer object holds, per destination, exactly
    the rows the direct grid would put in that destination's object —
    in the same order — and zone maps prune foreign partitions."""
    from repro.exec.fragment import FragmentStats
    from repro.storage import InputHandler
    rng = np.random.default_rng(0)
    result = {"k": rng.integers(0, 1000, 500).astype(np.int64),
              "v": rng.normal(size=500)}
    schema = [ColumnSpec("k", "num", "<i8"), ColumnSpec("v", "num", "<f8")]
    part = {"kind": "hash", "keys": ["k"], "n_dest": 4,
            "tier": "s3-standard"}
    store = ObjectStore(tier="local", seed=0)
    exchange.get_strategy("direct").write(
        store, result, schema, part, "x/direct", 0, FragmentStats())
    exchange.get_strategy("combining").write(
        store, result, schema, part, "x/comb", 0, FragmentStats())
    ih = InputHandler(store)
    for d in range(4):
        want = ih.read_table(f"x/direct/f0000/d{d:04d}.spax")[0]
        keys, preds, lf = exchange.plan_exchange_read(
            dict(part, layout="combined"), "x/comb", 1, "partition",
            d, 4, None, None)
        assert not lf and keys == ["x/comb/f0000/all.spax"]
        got = ih.read_table(keys[0], ["k", "v"], preds)[0]
        np.testing.assert_array_equal(want["k"], got["k"])
        np.testing.assert_array_equal(want["v"], got["v"])


# -- straggler-aware LPT --------------------------------------------------------

def test_straggler_skew_weights_isolate_slow_partition():
    nbytes = {d: 100.0 for d in range(4)}
    write_s = {0: 0.1, 1: 0.1, 2: 0.1, 3: 1.0}   # 3 is 10× slower/byte
    w = straggler_skew_weights(nbytes, write_s)
    assert w[3] == max(w.values()) and w[3] >= 4 * w[0] * 0.99
    # byte-balanced LPT would bundle pairs; skew-aware LPT dedicates a
    # worker to the slow partition
    assignment = _lpt_assignment(list(range(4)), w, 2)
    assert [3] in assignment


def test_skew_weights_no_observations_fall_back_to_bytes():
    nbytes = {0: 10.0, 1: 20.0}
    assert straggler_skew_weights(nbytes, {0: 0.0, 1: 0.0}) == nbytes


# -- Reoptimizer barrier re-pick ------------------------------------------------

def _pipeline(n_frag, n_dest, strategy, est_out_bytes):
    est_xreq = exchange.get_strategy(strategy).producer_requests(n_frag,
                                                                 n_dest)
    return Pipeline(
        0, "sem", {"t": "scan_exchange", "source": "s",
                   "mode": "partition"}, [],
        ExecutionParams(n_frag,
                        Partitioning("hash", ("k",), n_dest,
                                     "s3-standard", strategy),
                        est_in_bytes=10**7, est_out_bytes=est_out_bytes,
                        est_exchange_requests=est_xreq),
        [], [])


def test_reoptimizer_replans_to_multilevel_at_wide_fanout():
    r = Reoptimizer(CostModel(), hot_shuffle_object_threshold=10**9)
    p = _pipeline(300, 16, "direct", 10**7)
    adaptations = []
    r._replan_exchange(p, {"s": {"stats": {"bytes_out": 10**7}}},
                       adaptations)
    kinds = [a["kind"] for a in adaptations]
    assert "exchange_restrategy" in kinds
    a = adaptations[kinds.index("exchange_restrategy")]
    assert a["from"] == "direct" and a["to"] == "multilevel"
    assert a["est_requests_to"] < a["est_requests_from"]
    assert p.partitioning.strategy == "multilevel"


def test_reoptimizer_hysteresis_keeps_current_strategy():
    r = Reoptimizer(CostModel())
    p = _pipeline(4, 4, "combining", 10**5)
    adaptations = []
    r._replan_exchange(p, {"s": {"stats": {"bytes_out": 10**5}}},
                       adaptations)
    assert p.partitioning.strategy == "combining"
    assert not [a for a in adaptations
                if a["kind"] == "exchange_restrategy"]


def test_reoptimizer_honors_forced_strategy():
    r = Reoptimizer(CostModel(), forced_strategy="direct",
                    hot_shuffle_object_threshold=10**9)
    p = _pipeline(300, 16, "direct", 10**7)
    adaptations = []
    r._replan_exchange(p, {"s": {"stats": {"bytes_out": 10**7}}},
                       adaptations)
    assert p.partitioning.strategy == "direct"
    assert not adaptations


# -- cross-query selectivity calibration ----------------------------------------

FACT_SCHEMA = [
    ColumnSpec("f_key", "num", "<i8"),
    ColumnSpec("f_grp", "num", "<i8"),
    ColumnSpec("f_val", "num", "<f8"),
]
DIM_SCHEMA = [
    ColumnSpec("d_key", "num", "<i8"),
    ColumnSpec("d_x", "num", "<i8"),
]

import repro.sql.logical as _logical
_logical.PRIMARY_KEYS.setdefault("cdim", "d_key")


def _calib_db(rows=6000, dim_rows=40, n_parts=4, seed=0):
    rng = np.random.default_rng(seed)
    fact = {
        "f_key": rng.integers(0, dim_rows, rows).astype(np.int64),
        "f_grp": rng.integers(0, 3, rows).astype(np.int64),
        "f_val": np.round(rng.normal(0, 10, rows), 3),
    }
    dim = {"d_key": np.arange(dim_rows, dtype=np.int64),
           "d_x": rng.integers(0, 5, dim_rows).astype(np.int64)}
    store = ObjectStore(tier="local", seed=seed)
    catalog = Catalog()
    files = []
    for p in range(n_parts):
        sel = slice(p * rows // n_parts, (p + 1) * rows // n_parts)
        key = f"db/cfact/part-{p:05d}.spax"
        store.put(key, write_pax({k: v[sel] for k, v in fact.items()},
                                 FACT_SCHEMA))
        files.append(key)
    catalog.add(TableMeta("cfact", FACT_SCHEMA, files, rows, 400_000))
    store.put("db/cdim/part-00000.spax", write_pax(dim, DIM_SCHEMA))
    catalog.add(TableMeta("cdim", DIM_SCHEMA, ["db/cdim/part-00000.spax"],
                          dim_rows, 300_000))
    return store, catalog


CALIB_SQL = ("select d_x, count(*) as n from cfact, cdim "
             "where f_key = d_key and f_val + f_key < -30 "
             "group by d_x order by d_x")


def test_calibration_converges_recurring_predicate():
    from repro.core.engine import QueryEngine
    store, catalog = _calib_db()
    cfg = CoordinatorConfig(
        planner=PlannerConfig(bytes_per_worker=40_000,
                              broadcast_threshold_bytes=1,
                              exchange_partitions=4),
        use_result_cache=False, straggler_min_timeout_s=100.0)
    eng = QueryEngine(store, catalog, config=cfg)

    def _has_filter(op):
        while op is not None:
            if op["t"] == "filter":
                return True
            op = op.get("child")
        return False

    try:
        plan1 = eng.plan_sql(CALIB_SQL)
        probe_pid = next(pid for pid, p in plan1.pipelines.items()
                         if p.scan_units and _has_filter(p.op))
        est1 = plan1.pipelines[probe_pid].params.est_out_rows
        res = eng.execute_plan(plan1)
        probe_rows = next(r.rows_out for r in res.stats.pipelines
                          if r.pid == probe_pid)
        # the observation landed in the KV tier
        assert store.list("calibration/cfact/")
        # a fresh compile of the same predicate seeds from it: the
        # ~0.1%-selective expression predicate (planner guess: 30%)
        # converges without waiting for a barrier
        plan2 = eng.plan_sql(CALIB_SQL)
        est2 = plan2.pipelines[probe_pid].params.est_out_rows
        assert est2 < est1
        assert est2 <= max(2 * probe_rows, 10)
    finally:
        eng.platform.close()


def test_calibration_is_downward_only():
    cal_store = ObjectStore(tier="local", seed=0)
    cal = SelectivityCalibration(cal_store)
    cal.record("t", "k", 0.9)            # observed far above the guess
    assert cal.lookup("t", "k") == pytest.approx(0.9)
    # EMA folds repeat observations
    cal.record("t", "k", 0.5)
    assert cal.lookup("t", "k") == pytest.approx(0.7)
    # the *planner* applies min(static, observed): emulated here by the
    # contract test on _est via the convergence test above; the unit
    # check is that record() floors and caps
    cal.record("t", "lo", -1.0)
    assert cal.lookup("t", "lo") == pytest.approx(1e-4)


def test_scan_filter_signature_only_pure_chains():
    scan = {"t": "scan_table", "table": "x", "columns": [],
            "zone_preds": []}
    filt = {"t": "filter", "child": scan, "pred": {"t": "col", "name": "a"}}
    proj = {"t": "project", "child": filt, "exprs": []}
    assert scan_filter_signature(filt) == ("x", predicate_key(
        [{"t": "col", "name": "a"}]))
    assert scan_filter_signature(proj) == scan_filter_signature(filt)
    assert scan_filter_signature(scan) is None            # no filter
    agg = {"t": "partial_agg", "child": filt, "group_cols": [],
           "aggs": [], "strategy": "direct", "sizes": []}
    assert scan_filter_signature(agg) is None             # not pure
